//! Fig. 10 reproduction: TCP-Store establishment time, serialized vs
//! parallelized, as cluster scale grows.
//!
//! Two planes:
//! * REAL — an actual TCP store server on localhost; n clients
//!   establish (connect + hello RTT) serially (p=1) and parallelized.
//!   Shows the same linear-vs-flat separation at single-host scale.
//! * SIMULATED — the calibrated latency model at the paper's scales
//!   (1,000 – 18,000 devices), where the serial line grows linearly
//!   and the parallel line stays nearly flat.
//!
//!     cargo bench --bench fig10_tcp_store

use flashrecovery::cluster::LatencyModel;
use flashrecovery::comms::{establish, TcpStoreServer};
use flashrecovery::metrics::bench::BenchReport;

fn main() {
    // ---- real sockets ---------------------------------------------------
    let mut real = BenchReport::new(
        "Fig. 10 (real TCP, localhost): establishment time (ms)",
        &["serial p=1", "parallel p=8"],
    );
    for n in [16usize, 32, 64, 128, 256] {
        // fresh server per row so hello counts stay interpretable
        let server = TcpStoreServer::start().expect("server");
        let (t_serial, c1) = establish(server.addr(), n, 1).expect("serial");
        drop(c1);
        let (t_par, c2) = establish(server.addr(), n, 8).expect("parallel");
        drop(c2);
        assert_eq!(server.metrics_snapshot().counter("store.hellos"), 2 * n as u64);
        real.row(
            format!("n={n}"),
            vec![t_serial.as_secs_f64() * 1e3, t_par.as_secs_f64() * 1e3],
        );
    }
    real.note("each client = TCP connect + Hello round-trip, one host");
    real.print();

    // ---- simulated paper scale -------------------------------------------
    let lat = LatencyModel::default();
    let mut sim = BenchReport::new(
        "Fig. 10 (simulated, paper scale): establishment time (s)",
        &["serialized", "parallelized p=64"],
    );
    for n in [1000usize, 2000, 4000, 8000, 12000, 16000, 18000] {
        sim.row(
            format!("n={n}"),
            vec![
                lat.tcp_store_establishment(n, 1),
                lat.tcp_store_establishment(n, 64),
            ],
        );
    }
    sim.note("serialized grows ~linearly; parallelized decoupled from scale");
    sim.print();

    // shape assertions matching the paper's figure
    let serial_ratio =
        lat.tcp_store_establishment(18000, 1) / lat.tcp_store_establishment(1000, 1);
    assert!(serial_ratio > 10.0, "serial must grow ~linearly ({serial_ratio})");
    let par_18k = lat.tcp_store_establishment(18000, 64);
    assert!(par_18k < 10.0, "parallel must stay flat ({par_18k}s)");
    println!("fig10 OK");
}
