//! Ablation: which of FlashRecovery's restart optimizations buys what
//! (DESIGN.md §2 items 17–19). Starting from the full system at the
//! headline scale (175B @ 4800 devices), disable one mechanism at a
//! time and measure the recovery-time regression:
//!
//!   * TCP-Store parallelism p: 64 -> 1 (serialized baseline)
//!   * shared-file ranktable -> original O(n) negotiation
//!   * selective recreation -> full-fleet container restart
//!   * heartbeat detection -> collective-timeout detection
//!
//!     cargo bench --bench ablation_restart

use flashrecovery::cluster::latency::LatencyModel;
use flashrecovery::cluster::scenario::{average, simulate_flash, ScenarioConfig};
use flashrecovery::metrics::bench::BenchReport;
use flashrecovery::util::Rng;

const DEVICES: usize = 4800;
const PARAMS: f64 = 175e9;
const RUNS: u64 = 32;

fn base_cfg(seed: u64) -> ScenarioConfig {
    ScenarioConfig::paper(DEVICES, PARAMS, seed)
}

fn main() {
    let full = average(RUNS, 11, |s| simulate_flash(&base_cfg(s)));

    let mut report = BenchReport::new(
        "ablation: FlashRecovery restart mechanisms, 175B @ 4800 (s)",
        &["total", "delta vs full"],
    );
    report.row("full FlashRecovery", vec![full.total_s, 0.0]);

    // --- serialize the TCP store -------------------------------------
    let no_par_tcp = average(RUNS, 11, |s| {
        let mut c = base_cfg(s);
        c.tcp_parallelism = 1;
        simulate_flash(&c)
    });
    report.row(
        "- TCP-store parallelism (p=1)",
        vec![no_par_tcp.total_s, no_par_tcp.total_s - full.total_s],
    );

    // --- original ranktable -------------------------------------------
    let orig_rt = average(RUNS, 11, |s| {
        let c = base_cfg(s);
        let mut b = simulate_flash(&c);
        let delta = c.lat.ranktable_original(DEVICES) - c.lat.ranktable_shared(DEVICES);
        b.restart_s += delta;
        b.total_s += delta;
        b
    });
    report.row(
        "- shared-file ranktable (O(n))",
        vec![orig_rt.total_s, orig_rt.total_s - full.total_s],
    );

    // --- full-fleet recreation -----------------------------------------
    // Selective recreation restarts ONE node; the ablation pays the
    // max-order-statistic of the whole fleet's container starts plus
    // the shared-storage python-env stampede.
    let lat = LatencyModel::default();
    let nodes = DEVICES / 8;
    let full_fleet = average(RUNS, 11, |s| {
        let c = base_cfg(s);
        let mut b = simulate_flash(&c);
        let mut rng = Rng::new(s ^ 0xAB1A);
        let mut fleet_max = 0.0f64;
        for _ in 0..nodes {
            fleet_max = fleet_max.max(lat.container_start(&mut rng));
        }
        let one = lat.container_start(&mut rng);
        let delta = (fleet_max - one).max(0.0) + lat.storage_load(nodes, 0.0)
            - lat.storage_load(1, 0.0);
        b.restart_s += delta;
        b.total_s += delta;
        b
    });
    report.row(
        "- selective recreation (restart all)",
        vec![full_fleet.total_s, full_fleet.total_s - full.total_s],
    );

    // --- timeout detection ----------------------------------------------
    let timeout_detect = average(RUNS, 11, |s| {
        let c = base_cfg(s);
        let mut b = simulate_flash(&c);
        let delta = c.collective_timeout_s - b.detection_s;
        b.detection_s = c.collective_timeout_s;
        b.total_s += delta;
        b
    });
    report.row(
        "- active detection (1800s timeout)",
        vec![timeout_detect.total_s, timeout_detect.total_s - full.total_s],
    );

    report.note(format!("{RUNS} Monte-Carlo runs per row; each ablation re-enables one baseline mechanism"));
    report.print();

    // sanity: every ablation regresses, detection dominates
    assert!(no_par_tcp.total_s > full.total_s + 30.0, "tcp ablation too small");
    assert!(orig_rt.total_s > full.total_s + 20.0, "ranktable ablation too small");
    assert!(full_fleet.total_s > full.total_s, "recreation ablation must regress");
    assert!(timeout_detect.total_s > full.total_s + 1000.0);
    println!("ablation_restart OK");
}
