//! Redundancy-tier sweep: erasure-stripe shipping and whole-group
//! reconstruction at several model sizes (DESIGN.md §16).
//!
//! Steady-state columns bound what the tier costs per training step —
//! a worst-case dirty ship and the delta fast path where unchanged
//! stripes degrade to 38-byte hash refreshes. Recovery columns compare
//! what it buys: stripe reconstruction (the only path that survives a
//! whole replica group dying) against a replica-sourced stream and the
//! file-checkpoint fallback.
//!
//! Emits `BENCH_redundancy.json` (via `BenchReport::write_json`), the
//! artifact CI's bench gate compares against the committed baseline in
//! `ci/BENCH_redundancy.baseline.json`.
//!
//!     cargo bench --bench redundancy

use flashrecovery::redundancy::bench::{
    check_report, redundancy_sweep, RedundancySweepConfig,
};

fn main() {
    let cfg = RedundancySweepConfig::default();
    let report = redundancy_sweep(&cfg).expect("redundancy sweep");
    report.print();
    report
        .write_json("BENCH_redundancy.json")
        .expect("write BENCH_redundancy.json");
    println!("wrote BENCH_redundancy.json");

    // ---- asserted properties: the delta fast path undercuts a full ----
    // ---- ship, and reconstruction stays in streaming territory     ----
    check_report(&cfg, &report).expect("redundancy acceptance assertions");
    println!("redundancy acceptance assertions PASS");
}
