//! Scale sweep for communication-group reconstruction: epoch-fenced
//! rendezvous over a live TCP store at 256 -> 8192 simulated ranks.
//!
//! Ranktable and group math run at full cluster scale; live TCP agents
//! (a fixed survivor sample + every replacement + the coordinator) run
//! the real protocol concurrently, so wall-clock measures the per-node
//! critical path — which the paper claims, and this bench asserts, is
//! near-constant in cluster size.
//!
//! Emits `BENCH_group_rebuild.json` (via `BenchReport::write_json`),
//! the artifact CI's bench gate compares against the committed
//! baseline in `ci/BENCH_group_rebuild.baseline.json`.
//!
//!     cargo bench --bench group_rebuild

use flashrecovery::coordinator::rendezvous::{rebuild_sweep, SweepConfig};

fn main() {
    let cfg = SweepConfig::default();
    let report = rebuild_sweep(&cfg).expect("rebuild sweep");
    report.print();
    report
        .write_json("BENCH_group_rebuild.json")
        .expect("write BENCH_group_rebuild.json");
    println!("wrote BENCH_group_rebuild.json");

    // ---- asserted properties (the paper's scale-independence claim) ----
    let min_scale = *cfg.scales.iter().min().unwrap();
    let max_scale = *cfg.scales.iter().max().unwrap();
    let p50 = |n: usize| {
        report
            .row_values(&format!("n={n}"))
            .expect("row")[0]
    };
    let (lo, hi) = (p50(min_scale), p50(max_scale));
    // near-flat: a 32x larger cluster may not cost more than 2x the
    // wall-clock (tiny absolute p50s get a 2ms noise floor)
    assert!(
        hi <= 2.0 * lo + 2.0,
        "rebuild p50 not scale-independent: {hi:.2}ms @ {max_scale} vs \
         {lo:.2}ms @ {min_scale}"
    );
    // O(1) survivor message budget at every scale (exactly 3: fenced
    // delta wait, arrive, release)
    for &n in &cfg.scales {
        let msgs = report.row_values(&format!("n={n}")).expect("row")[3];
        assert!(msgs <= 3.0, "survivor msgs {msgs} at n={n} (budget is 3)");
    }
    println!(
        "group_rebuild OK: p50 {lo:.2}ms @ {min_scale} -> {hi:.2}ms @ {max_scale} \
         (<= 2x), survivor msgs O(1)"
    );
}
