//! §II reproduction: the recovery-overhead model, eqs. (1)–(5).
//!
//! * sweeps F(t) over checkpoint intervals and shows the convex curve
//!   with its minimum at t* = sqrt(2 d k0 / m) (eq. 3);
//! * validates the closed forms against the Monte-Carlo failure
//!   simulator;
//! * compares FlashRecovery's eq. (5) against F_min across failure
//!   rates, reproducing the RPO/RTO dominance argument.
//!
//!     cargo bench --bench overhead_model

use flashrecovery::metrics::bench::BenchReport;
use flashrecovery::recovery_model::{
    monte_carlo_flash, monte_carlo_periodic, FlashParams, OverheadParams,
};

fn main() {
    // One week of training (in step units, 10 s/step), 20 failures,
    // s0 ≈ 2000 s detection+restart, k0 ≈ 50 s snapshot stall.
    let p = OverheadParams { d: 60480.0, m: 20.0, s0: 200.0, k0: 5.0 };

    // ---- eq. (1): the convex F(t) curve --------------------------------
    let t_star = p.optimal_interval();
    let mut curve = BenchReport::new(
        "Eq. (1): total overhead F(t) vs checkpoint interval t (steps)",
        &["analytic F(t)", "monte-carlo"],
    );
    for mult in [0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 10.0] {
        let t = t_star * mult;
        let mc = monte_carlo_periodic(&p, t, 300, 11);
        curve.row(
            format!("t = {:.0} ({mult}x t*)", t),
            vec![p.total_overhead(t), mc.mean_overhead],
        );
    }
    curve.note(format!("t* = {t_star:.1} steps (eq. 3), F_min = {:.1} (eq. 4)", p.min_overhead()));
    curve.print();

    // MC must agree with the closed form within 5% everywhere
    for mult in [0.25, 1.0, 4.0] {
        let t = t_star * mult;
        let mc = monte_carlo_periodic(&p, t, 500, 23);
        let rel = (mc.mean_overhead - p.total_overhead(t)).abs() / p.total_overhead(t);
        assert!(rel < 0.05, "MC mismatch at t={t}: rel {rel}");
    }

    // ---- eq. (3)/(4) observations --------------------------------------
    let mut obs = BenchReport::new(
        "Eq. (3): optimal interval t* responds to m and k0",
        &["t*", "F_min"],
    );
    for (label, params) in [
        ("baseline", p),
        ("4x failures", OverheadParams { m: p.m * 4.0, ..p }),
        ("4x snapshot cost", OverheadParams { k0: p.k0 * 4.0, ..p }),
    ] {
        obs.row(label, vec![params.optimal_interval(), params.min_overhead()]);
    }
    obs.note("t* ∝ 1/sqrt(m): more failures -> checkpoint more often");
    obs.note("t* ∝ sqrt(k0): costlier snapshots -> checkpoint less often");
    obs.print();

    // ---- eq. (5): FlashRecovery dominance -------------------------------
    let mut cmp = BenchReport::new(
        "Eq. (5): FlashRecovery vs OPTIMALLY-TUNED periodic checkpointing",
        &["F_min (periodic)", "F (flash)", "speedup"],
    );
    for m in [5.0, 20.0, 80.0, 320.0] {
        let periodic = OverheadParams { m, ..p };
        // flash: same per-failure s0 but scale-independent, one-step s1'
        let flash = FlashParams { m, s0_prime: p.s0, s1_prime: 1.0 };
        let f_min = periodic.min_overhead();
        let f_flash = flash.total_overhead();
        cmp.row(
            format!("m = {m} failures"),
            vec![f_min, f_flash, f_min / f_flash],
        );
        assert!(f_flash < f_min, "flash must dominate at m={m}");
        // MC cross-check of eq. 5
        let mc = monte_carlo_flash(&flash, p.d, 300, 31);
        let rel = (mc.mean_overhead - f_flash).abs() / f_flash;
        assert!(rel < 0.05, "flash MC mismatch: {rel}");
    }
    cmp.note("flash needs no checkpoints (k0 = 0) and redoes at most 1 step");
    cmp.print();

    println!("overhead_model OK");
}
