//! Streaming-restore sweep: shard-aware checkpoint-free restore over
//! real sockets at several model sizes x ZeRO shard counts.
//!
//! Each cell kills one rank per shard group and restores every lost
//! shard from a distinct surviving replica, transfers running in
//! parallel; the `1src` column is the same target count restored
//! through a single source — the pre-refactor whole-model broadcast
//! shape. The parallel path must beat the serialized baseline at the
//! largest cell (the point of the refactor).
//!
//! Emits `BENCH_state_restore.json` (via `BenchReport::write_json`),
//! the artifact CI's bench gate compares against the committed
//! baseline in `ci/BENCH_state_restore.baseline.json`.
//!
//!     cargo bench --bench state_restore

use flashrecovery::coordinator::restore::{restore_sweep, RestoreSweepConfig};

fn main() {
    let cfg = RestoreSweepConfig::default();
    let report = restore_sweep(&cfg).expect("restore sweep");
    report.print();
    report
        .write_json("BENCH_state_restore.json")
        .expect("write BENCH_state_restore.json");
    println!("wrote BENCH_state_restore.json");

    // ---- asserted property: parallel per-shard restore beats the ----
    // ---- single-source broadcast at the largest cell             ----
    let elems = *cfg.sizes.iter().max().unwrap();
    let shards = *cfg.shards.iter().max().unwrap();
    let row = report
        .row_values(&format!("elems={elems} shards={shards}"))
        .expect("largest row");
    let (parallel_p50, single_p50) = (row[0], row[5]);
    assert!(
        parallel_p50 < single_p50,
        "parallel restore ({parallel_p50:.2}ms) must beat single-source \
         broadcast ({single_p50:.2}ms) at elems={elems} shards={shards}"
    );
    // and the win should not be marginal at this size: the serialized
    // baseline pays ~`shards` transfers back to back
    println!(
        "state_restore OK: parallel {parallel_p50:.2}ms vs single-source \
         {single_p50:.2}ms at elems={elems} shards={shards} \
         ({:.2}x)",
        single_p50 / parallel_p50.max(1e-9)
    );
}
