//! Fig. 9 reproduction: failure type taxonomy and observed ratios.
//!
//! Draws a large failure sample from the injector and prints the
//! category shares next to the paper's published percentages.
//!
//!     cargo bench --bench fig9_failure_taxonomy

use flashrecovery::cluster::failure::{
    FailureCategory, FailureInjector, FailureKind, HARDWARE_MIX, HARDWARE_SHARE,
    SOFTWARE_MIX,
};
use flashrecovery::metrics::bench::BenchReport;
use flashrecovery::util::Rng;
use std::collections::BTreeMap;

fn main() {
    let n = 500_000u32;
    let mut rng = Rng::new(2026);
    let mut counts: BTreeMap<&'static str, u32> = BTreeMap::new();
    let mut hardware = 0u32;
    for _ in 0..n {
        let k = FailureInjector::sample_kind(&mut rng);
        *counts.entry(k.name()).or_insert(0) += 1;
        if k.category() == FailureCategory::Hardware {
            hardware += 1;
        }
    }

    let mut report = BenchReport::new(
        "Fig. 9: failure taxonomy — observed vs paper (%)",
        &["observed", "paper"],
    );
    report.row(
        "hardware (all)",
        vec![100.0 * hardware as f64 / n as f64, 100.0 * HARDWARE_SHARE],
    );
    report.row(
        "software (all)",
        vec![
            100.0 * (n - hardware) as f64 / n as f64,
            100.0 * (1.0 - HARDWARE_SHARE),
        ],
    );
    for (kind, within) in HARDWARE_MIX.iter() {
        report.row(
            format!("hw/{}", kind.name()),
            vec![
                100.0 * counts[kind.name()] as f64 / n as f64,
                100.0 * within * HARDWARE_SHARE,
            ],
        );
    }
    for (kind, within) in SOFTWARE_MIX.iter() {
        report.row(
            format!("sw/{}", kind.name()),
            vec![
                100.0 * counts[kind.name()] as f64 / n as f64,
                100.0 * within * (1.0 - HARDWARE_SHARE),
            ],
        );
    }
    report.note(format!("{n} sampled failures; paper shares from Fig. 9"));
    report.print();

    // shape check: every observed share within 0.5pp of the target
    for k in FailureKind::all() {
        let observed = counts[k.name()] as f64 / n as f64;
        assert!(
            (observed - k.overall_share()).abs() < 0.005,
            "{}: {observed} vs {}",
            k.name(),
            k.overall_share()
        );
    }
    println!("fig9 OK");
}
