//! Table III reproduction: FlashRecovery recovery time across task
//! scales and model sizes — detection within seconds, restart nearly
//! scale-independent, redone training bounded by one step, total well
//! under the vanilla baseline at every scale.
//!
//!     cargo bench --bench table3_flashrecovery

use flashrecovery::cluster::{scenario::average, simulate_flash, ScenarioConfig};
use flashrecovery::metrics::bench::BenchReport;

struct Row {
    model: &'static str,
    params: f64,
    devices: usize,
    paper_total: f64,
}

fn main() {
    let runs = 32;
    // The paper's full Tab. III grid.
    let grid = [
        Row { model: "7B", params: 7e9, devices: 32, paper_total: 97.0 },
        Row { model: "7B", params: 7e9, devices: 960, paper_total: 101.0 },
        Row { model: "70B", params: 70e9, devices: 80, paper_total: 90.0 },
        Row { model: "70B", params: 70e9, devices: 800, paper_total: 111.0 },
        Row { model: "70B", params: 70e9, devices: 960, paper_total: 98.0 },
        Row { model: "70B", params: 70e9, devices: 2880, paper_total: 120.5 },
        Row { model: "175B", params: 175e9, devices: 2880, paper_total: 139.5 },
        Row { model: "175B", params: 175e9, devices: 4800, paper_total: 147.5 },
    ];

    let mut report = BenchReport::new(
        "Tab. III: FlashRecovery recovery time (seconds)",
        &["detect", "restart", "step", "step/2", "total", "paper total"],
    );
    let mut totals = Vec::new();
    for row in &grid {
        let b = average(runs, 5, |s| {
            simulate_flash(&ScenarioConfig::paper(row.devices, row.params, s))
        });
        totals.push(b.total_s);
        report.row(
            format!("{} @ {}", row.model, row.devices),
            vec![
                b.detection_s,
                b.restart_s,
                b.step_time_s,
                b.redone_s,
                b.total_s,
                row.paper_total,
            ],
        );
    }
    report.note(format!("{runs} Monte-Carlo runs per row"));
    report.note("total = detect + restart + step/2 (paper's accounting)");
    report.print();

    // stage breakdown at the headline scale (175B @ 4800)
    let b = simulate_flash(&ScenarioConfig::paper(4800, 175e9, 1));
    let mut stages = BenchReport::new(
        "Tab. III detail: FlashRecovery stages, 175B @ 4800 devices (s)",
        &["seconds"],
    );
    for (name, v) in &b.stages {
        stages.row(name.clone(), vec![*v]);
    }
    stages.print();

    // ---- paper-shape assertions --------------------------------------
    // 1. headline: 4800-device recovery within ~150 s (we allow 2x)
    let headline = totals[totals.len() - 1];
    assert!(headline < 300.0, "175B@4800 total {headline}");
    // 2. near scale-independence: 32 -> 4800 grows < 2x (paper: 1.52x)
    let growth = headline / totals[0];
    assert!(growth < 2.0, "total grew {growth}x across the sweep");
    // 3. every total in the paper's order of magnitude
    for (t, row) in totals.iter().zip(grid.iter()) {
        let ratio = t / row.paper_total;
        assert!(
            (0.4..2.5).contains(&ratio),
            "{} @ {}: sim {t} vs paper {} ({ratio}x)",
            row.model,
            row.devices,
            row.paper_total
        );
    }
    println!("table3 OK");
}
