//! End-to-end hot-path microbenchmarks on the REAL execution plane —
//! the instrument for EXPERIMENTS.md §Perf.
//!
//! Measures, for the `tiny` and `small` models:
//!   * per-phase step cost: fwd_bwd execute, gradient flatten,
//!     allreduce, opt_step execute, snapshot encode/decode;
//!   * full-engine throughput at DP = 1 / 2 / 4;
//!   * real recovery latency (failure -> training resumed) under
//!     FlashRecovery with a fast heartbeat.
//!
//!     cargo bench --bench e2e_hotpath [-- --sizes tiny,small --dp-sweep 1,2,4]

use flashrecovery::checkpoint::{decode_snapshot, encode_snapshot};
use flashrecovery::cluster::failure::FailureKind;
use flashrecovery::comms::Collective;
use flashrecovery::coordinator::ControllerConfig;
use flashrecovery::metrics::bench::{time_fn, BenchReport};
use flashrecovery::runtime::literal_tokens;
use flashrecovery::training::worker::{flatten_grads, FailurePlan, Phase};
use flashrecovery::training::{DataConfig, DataIterator, TrainingEngine, WorkerState};
use flashrecovery::util::Args;
use std::time::Duration;

fn phase_bench(engine: &TrainingEngine, size: &str) {
    let b = &engine.bundle;
    let dims = &b.manifest.dims;
    let state = WorkerState::init(b, 0).unwrap();
    let data = DataIterator::new(DataConfig::for_model(dims.vocab, dims.seq, dims.batch, 1));
    let tokens_host = data.batch_for(0, 0);
    let tokens = literal_tokens(dims.batch, dims.seq + 1, &tokens_host).unwrap();

    let (_, grads) = b.run_fwd_bwd(&state.params, &tokens).unwrap();
    let flat = flatten_grads(&grads).unwrap();

    let mut report = BenchReport::new(
        &format!("hot path phases — {size} ({:.2}M params)", dims.param_count as f64 / 1e6),
        &["mean ms", "p95 ms"],
    );

    let h = time_fn(1, 5, || {
        let _ = b.run_fwd_bwd(&state.params, &tokens).unwrap();
    });
    report.row("fwd_bwd execute", vec![h.mean() * 1e3, h.p95() * 1e3]);

    let h = time_fn(1, 10, || {
        let _ = flatten_grads(&grads).unwrap();
    });
    report.row("grad flatten", vec![h.mean() * 1e3, h.p95() * 1e3]);

    // single-participant allreduce isolates the reduction arithmetic
    let solo = Collective::new(1, Duration::from_secs(5));
    let h = time_fn(1, 10, || {
        let mut buf = flat.clone();
        solo.allreduce_mean(&mut buf).unwrap();
    });
    report.row("allreduce (1 rank)", vec![h.mean() * 1e3, h.p95() * 1e3]);

    let h = time_fn(1, 5, || {
        let _ = b
            .run_opt_step(&state.params, &state.m, &state.v, 1.0, &grads)
            .unwrap();
    });
    report.row("opt_step execute", vec![h.mean() * 1e3, h.p95() * 1e3]);

    let h = time_fn(1, 5, || {
        let _ = b
            .run_train_step(&state.params, &state.m, &state.v, 1.0, &tokens)
            .unwrap();
    });
    report.row("fused train_step", vec![h.mean() * 1e3, h.p95() * 1e3]);

    let snap = state.to_snapshot().unwrap();
    let h = time_fn(1, 5, || {
        let _ = encode_snapshot(&snap);
    });
    report.row("snapshot encode", vec![h.mean() * 1e3, h.p95() * 1e3]);
    let bytes = encode_snapshot(&snap);
    let h = time_fn(1, 5, || {
        let _ = decode_snapshot(&bytes).unwrap();
    });
    report.row("snapshot decode", vec![h.mean() * 1e3, h.p95() * 1e3]);
    report.note(format!(
        "state = {:.1} MB; grads = {:.1} MB",
        snap.total_bytes() as f64 / 1e6,
        flat.len() as f64 * 4.0 / 1e6
    ));
    report.print();
}

fn engine_bench(engine: &TrainingEngine, size: &str, dp_sweep: &[usize], steps: u64) {
    let mut report = BenchReport::new(
        &format!("engine throughput — {size}"),
        &["s/step", "steps/s"],
    );
    for &dp in dp_sweep {
        let cfg = ControllerConfig::flash(dp, steps);
        let t0 = std::time::Instant::now();
        let rep = engine.run(cfg).unwrap();
        assert_eq!(rep.final_step, steps);
        let per = t0.elapsed().as_secs_f64() / steps as f64;
        report.row(format!("dp={dp}"), vec![per, 1.0 / per]);
    }
    report.note("single-core host: DP ranks time-share the core");
    report.print();
}

fn recovery_bench(engine: &TrainingEngine, size: &str) {
    let mut report = BenchReport::new(
        &format!("real recovery latency — {size} (seconds)"),
        &["detect", "restart", "restore", "total"],
    );
    for (label, phase) in [("fwd/bwd failure", Phase::FwdBwd), ("optimizer failure", Phase::OptStep)] {
        let mut cfg = ControllerConfig::flash(2, 8);
        cfg.heartbeat_interval = Duration::from_millis(50);
        cfg.failures = vec![FailurePlan {
            rank: 1,
            step: 4,
            phase,
            kind: FailureKind::Network,
        }];
        let rep = engine.run(cfg).unwrap();
        let r = &rep.recoveries[0];
        report.row(
            label,
            vec![r.detection_s, r.restart_s, r.restore_s, r.total_s],
        );
    }
    report.note("heartbeat 50 ms; replica restore over in-process broadcast");
    report.print();
}

fn main() {
    let args = Args::parse_env();
    let sizes = args.str_or("sizes", "tiny,small");
    let dp_sweep: Vec<usize> = args
        .str_or("dp-sweep", "1,2,4")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();

    for size in sizes.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let engine = TrainingEngine::load(size).expect("run `make artifacts`");
        phase_bench(&engine, size);
        let steps = if size == "tiny" { 10 } else { 4 };
        engine_bench(&engine, size, &dp_sweep, steps);
        if size == "tiny" {
            recovery_bench(&engine, size);
        }
    }
    println!("e2e_hotpath OK");
}
