//! Scale sweep for active failure detection: leased heartbeats over a
//! live TCP store at 64 -> 4096 simulated ranks (DESIGN.md §10).
//!
//! The lease table runs at full fleet scale (the monitor's O(alive)
//! scan is part of what is measured); live TCP agents (a fixed worker
//! sample including the victim) push real `Heartbeat` frames, so the
//! measured quantity is the wall clock from the victim's last good
//! heartbeat to the `LeaseMonitor` detection — which the paper claims,
//! and this bench asserts, is within seconds and independent of
//! cluster size (heartbeats are O(1) per worker).
//!
//! Emits `BENCH_detection_latency.json` (via `BenchReport::write_json`),
//! the artifact CI's bench gate compares against the committed
//! baseline in `ci/BENCH_detection_latency.baseline.json`.
//!
//!     cargo bench --bench detection_latency

use flashrecovery::coordinator::{detection_sweep, DetectionSweepConfig};

fn main() {
    let cfg = DetectionSweepConfig::default();
    let report = detection_sweep(&cfg).expect("detection sweep");
    report.print();
    report
        .write_json("BENCH_detection_latency.json")
        .expect("write BENCH_detection_latency.json");
    println!("wrote BENCH_detection_latency.json");

    // ---- asserted properties (the paper's §III-C claim) ---------------
    let min_scale = *cfg.scales.iter().min().unwrap();
    let max_scale = *cfg.scales.iter().max().unwrap();
    let p50 = |n: usize| report.row_values(&format!("n={n}")).expect("row")[0];
    let (lo, hi) = (p50(min_scale), p50(max_scale));
    // near-flat: a 64x larger fleet may not cost more than 2x the
    // detection latency (small absolute p50s get a 5ms noise floor)
    assert!(
        hi <= 2.0 * lo + 5.0,
        "detection p50 not scale-independent: {hi:.2}ms @ {max_scale} vs \
         {lo:.2}ms @ {min_scale}"
    );
    // "within seconds": every scale's p50 far under the 1800s
    // collective-timeout baseline — and under one second outright
    for &n in &cfg.scales {
        let v = p50(n);
        assert!(v < 1000.0, "detection p50 {v:.1}ms at n={n} not within seconds");
    }
    println!(
        "detection_latency OK: p50 {lo:.2}ms @ {min_scale} -> {hi:.2}ms @ \
         {max_scale} (<= 2x), O(1) heartbeats/worker"
    );
}
