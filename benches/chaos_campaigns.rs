//! Chaos campaign sweep: every built-in scenario × cluster scale,
//! Monte-Carlo averaged over seeds. The table extends Tab. III's
//! single-failure scale sweep to compound failure patterns: recovery
//! time should stay nearly scale-independent even for cascades, merged
//! mid-recovery failures, and flapping hosts.
//!
//!     cargo bench --bench chaos_campaigns

use flashrecovery::chaos::{evaluate, library, passed, run_campaign};
use flashrecovery::metrics::bench::BenchReport;

fn main() {
    let scales = [256usize, 1024, 4096];
    let seeds: Vec<u64> = (1..=8).collect();

    let mut report = BenchReport::new(
        "chaos campaigns: mean worst-recovery / downtime seconds by scale",
        &[
            "worst rec @256",
            "downtime @256",
            "worst rec @1024",
            "downtime @1024",
            "worst rec @4096",
            "downtime @4096",
        ],
    );

    let mut failures = 0usize;
    for name in library::NAMES {
        let mut row = Vec::new();
        for &devices in &scales {
            let spec = library::by_name(name, devices).unwrap();
            let mut worst = 0.0f64;
            let mut downtime = 0.0f64;
            for &seed in &seeds {
                let (r, _) = run_campaign(&spec, seed).expect("campaign");
                let outcomes = evaluate(&spec.assertions, &r);
                if !passed(&outcomes) {
                    failures += 1;
                    for o in outcomes.iter().filter(|o| !o.pass) {
                        eprintln!("[{name} @ {devices} seed {seed}] {}: {}", o.name, o.detail);
                    }
                }
                worst += r
                    .recoveries
                    .iter()
                    .map(|x| x.total_s())
                    .fold(0.0f64, f64::max);
                downtime += r.total_downtime_s;
            }
            let n = seeds.len() as f64;
            row.push(worst / n);
            row.push(downtime / n);
        }
        report.row(name, row);
    }

    report.note(format!("{} seeds per cell; assertions checked on every run", seeds.len()));
    report.note(
        "compound campaigns (cascade, merged, flap) keep worst-recovery within a \
         small constant of the single-fault baseline at every scale",
    );
    report.print();

    // Scale-independence check: worst recovery at 4096 devices within
    // 2x of 256 devices for the single-fault baseline.
    let rec = |devices: usize| {
        let spec = library::by_name("single_fault", devices).unwrap();
        let mut worst = 0.0;
        for &seed in &seeds {
            let (r, _) = run_campaign(&spec, seed).unwrap();
            worst += r
                .recoveries
                .iter()
                .map(|x| x.total_s())
                .fold(0.0f64, f64::max);
        }
        worst / seeds.len() as f64
    };
    let (small, large) = (rec(256), rec(4096));
    assert!(
        large / small < 2.0,
        "recovery grew {}x from 256 to 4096 devices",
        large / small
    );
    assert_eq!(failures, 0, "{failures} campaign runs failed assertions");
    println!("chaos_campaigns OK");
}
