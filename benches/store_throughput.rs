//! Store data-plane throughput sweep: the event-loop reactor core
//! (DESIGN.md §14) vs the worker pool (§11) under a mixed-opcode
//! workload at 64 -> 65,536 simulated clients multiplexed over a
//! bounded socket set.
//!
//! Asserted properties:
//!
//! * **batched beats serial**: pipelined `Batch` clients deliver at
//!   least 2x the ops/s of one-op-per-round-trip clients at 4096
//!   simulated clients — the data-plane redesign's headline number;
//! * **flat at 65k**: batched per-op p50 at the largest client count
//!   stays within 1.5x of the 4096-client p50 (plus a small noise
//!   floor) — readiness-driven serving adds no per-client thread or
//!   queueing cliff at scale;
//! * **O(1) serving threads**: the reactor cell's peak serving-thread
//!   count stays <= 8 regardless of client count (one event loop,
//!   not thread-per-connection), with bounded RSS at the top scale;
//! * **replication is cheap**: the `repl p50` column re-runs the
//!   batched cell against a quorum-replicated store (primary + 1
//!   log-shipping replica, DESIGN.md §13) and must stay within 1.5x
//!   of the un-replicated batched p50 — group-commit quorum acks off
//!   the hot path (capped at 8192 clients; see the report notes);
//! * **telemetry is cheap**: with the flight recorder on and every
//!   frame carrying a trace context (DESIGN.md §12), batched per-op
//!   p50 stays within 5% of the recorder-off run (plus a small noise
//!   floor).
//!
//! Emits `BENCH_store_throughput.json` (via `BenchReport::write_json`),
//! the artifact CI's bench gate compares against the committed
//! baseline in `ci/BENCH_store_throughput.baseline.json`.
//!
//!     cargo bench --bench store_throughput

use flashrecovery::comms::store_bench::{
    check_report, store_sweep, telemetry_overhead, StoreSweepConfig,
};

fn main() {
    let cfg = StoreSweepConfig::default();
    let report = store_sweep(&cfg).expect("store sweep");
    report.print();
    report
        .write_json("BENCH_store_throughput.json")
        .expect("write BENCH_store_throughput.json");
    println!("wrote BENCH_store_throughput.json");

    // ---- asserted properties (ISSUE 5/7 + §14 acceptance) -------------
    // the same checks `bench store --assert` runs in bench-gate:
    // batched >= 2x serial ops/s at 4096 clients, per-op p50 flat at
    // the top scale (<= 1.5x the 4096-client anchor), reactor serving
    // threads O(1) with bounded RSS, and quorum-replicated p50 <=
    // 1.5x un-replicated batched p50
    check_report(&cfg, &report).expect("acceptance properties");
    let col = |n: usize, c: usize| {
        report.row_values(&format!("n={n}")).expect("row")[c]
    };
    let (min_scale, max_scale) = (
        *cfg.clients.iter().min().unwrap(),
        *cfg.clients.iter().max().unwrap(),
    );
    println!(
        "store_throughput OK: p50 {:.2}us/op @ {min_scale} -> {:.2}us/op @ \
         {max_scale} (<= 1.5x the 4096 anchor), peak serving threads {:.0}, \
         batched >= 2x serial, replicated p50 {:.2}us/op @ 4096 \
         (<= 1.5x un-replicated)",
        col(min_scale, 0),
        col(max_scale, 0),
        col(max_scale, 8),
        col(4096.min(max_scale), 7),
    );

    // ---- telemetry overhead guard (flight recorder, DESIGN.md §12) ----
    // recorder on + trace context on every frame vs recorder off, same
    // batched workload: per-op p50 must stay within 5% (plus a 5us
    // noise floor for loaded runners)
    let (off_p50, on_p50) = telemetry_overhead(&cfg, 1024).expect("telemetry overhead cell");
    assert!(
        on_p50 <= off_p50 * 1.05 + 5e-6,
        "flight recorder too expensive on the batched hot path: p50 {:.2}us \
         on vs {:.2}us off (> 5% + 5us floor)",
        on_p50 * 1e6,
        off_p50 * 1e6
    );
    println!(
        "telemetry overhead OK: batched p50 {:.2}us recorder-on vs {:.2}us \
         recorder-off @ 1024 clients (<= 5% + floor)",
        on_p50 * 1e6,
        off_p50 * 1e6
    );
}
