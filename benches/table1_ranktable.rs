//! Table I reproduction: ranktable update time — original collect +
//! distribute (O(n)) vs FlashRecovery's shared-file load (O(1)).
//!
//! * REAL — the actual protocols at single-host scale: `original_update`
//!   over the in-process collective vs `SharedRanktable::load` of a
//!   published file.
//! * SIMULATED — the calibrated model at the paper's device counts
//!   (1k / 4k / 8k / 16k / 18k), printed next to the paper's numbers.
//!
//!     cargo bench --bench table1_ranktable

use flashrecovery::cluster::LatencyModel;
use flashrecovery::comms::Collective;
use flashrecovery::coordinator::{original_update, RankEntry, Ranktable, SharedRanktable};
use flashrecovery::metrics::bench::BenchReport;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn entry(rank: usize) -> RankEntry {
    RankEntry {
        rank,
        node: rank / 8,
        device: rank % 8,
        addr: format!("10.0.{}.{}:2900", rank / 8, rank % 8),
    }
}

fn time_original(n: usize) -> f64 {
    let group = Collective::new(n, Duration::from_secs(30));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for rank in 0..n {
        let group: Arc<Collective> = group.clone();
        handles.push(std::thread::spawn(move || {
            original_update(&group, &entry(rank)).unwrap()
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

fn time_shared_load(n: usize, iters: u32) -> f64 {
    let dir = flashrecovery::util::temp_dir("t1-rt").unwrap();
    let shared = SharedRanktable::new(dir.join("ranktable.json"));
    shared
        .publish(&Ranktable::new((0..n).map(entry).collect()))
        .unwrap();
    let t0 = Instant::now();
    for _ in 0..iters {
        let t = shared.load().unwrap();
        assert_eq!(t.entries.len(), n);
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    std::fs::remove_dir_all(dir).ok();
    per
}

fn main() {
    // ---- real protocols, single host --------------------------------
    let mut real = BenchReport::new(
        "Tab. I (real, in-process): ranktable update time (ms)",
        &["original O(n)", "shared-file O(1)"],
    );
    for n in [4usize, 8, 16, 32, 64] {
        real.row(
            format!("n={n}"),
            vec![time_original(n) * 1e3, time_shared_load(n, 20) * 1e3],
        );
    }
    real.note("original = all-gather collect+distribute across n threads");
    real.print();

    // ---- simulated paper scale ----------------------------------------
    let lat = LatencyModel::default();
    let paper_orig = [8.0, 31.0, 60.0, 176.0, 249.0];
    let paper_shared = [0.1, 0.1, 0.5, 0.5, 0.5];
    let mut sim = BenchReport::new(
        "Tab. I (simulated, paper scale): ranktable update time (s)",
        &["original", "paper orig", "shared-file", "paper shared"],
    );
    for (i, n) in [1000usize, 4000, 8000, 16000, 18000].iter().enumerate() {
        sim.row(
            format!("{n} devices"),
            vec![
                lat.ranktable_original(*n),
                paper_orig[i],
                lat.ranktable_shared(*n),
                paper_shared[i],
            ],
        );
    }
    sim.note("paper columns are Tab. I's published values");
    sim.print();

    // shape: original superlinear-ish, shared flat sub-second
    assert!(lat.ranktable_original(18000) / lat.ranktable_original(1000) > 15.0);
    assert!(lat.ranktable_shared(18000) < 0.5);
    println!("table1 OK");
}
