//! Table II reproduction: the vanilla recovery baseline at the paper's
//! 175B task scales — timeout detection (1800 s) plus a task-restart
//! time that grows linearly with scale.
//!
//!     cargo bench --bench table2_vanilla

use flashrecovery::cluster::{scenario::average, simulate_vanilla, ScenarioConfig};
use flashrecovery::metrics::bench::BenchReport;

fn main() {
    let runs = 32;
    // (devices, paper restart seconds)
    let grid = [(1824usize, 231.0), (3936, 801.0), (5472, 1115.0)];

    let mut report = BenchReport::new(
        "Tab. II: vanilla recovery, 175B model (seconds)",
        &["detection", "restart (sim)", "restart (paper)"],
    );
    let mut restarts = Vec::new();
    for (devices, paper) in grid {
        let b = average(runs, 3, |s| {
            simulate_vanilla(&ScenarioConfig::paper(devices, 175e9, s))
        });
        restarts.push(b.restart_s);
        report.row(
            format!("{devices} devices"),
            vec![b.detection_s, b.restart_s, paper],
        );
    }
    report.note("detection = PyTorch collective hang timeout (paper default)");
    report.note(format!("{runs} Monte-Carlo runs per row"));
    report.print();

    // fine-grained stage breakdown at the largest scale
    let b = simulate_vanilla(&ScenarioConfig::paper(5472, 175e9, 1));
    let mut stages = BenchReport::new(
        "Tab. II detail: vanilla restart stages at 5472 devices (s)",
        &["seconds"],
    );
    for (name, v) in &b.stages {
        stages.row(name.clone(), vec![*v]);
    }
    stages.print();

    // shape: detection fixed at 1800, restart grows ~linearly, right
    // order of magnitude vs the paper.
    assert!((restarts[1] / restarts[0]) > 1.5, "restart must grow with scale");
    assert!((restarts[2] / restarts[1]) > 1.15);
    for (r, (_, paper)) in restarts.iter().zip(grid.iter()) {
        let ratio = r / paper;
        assert!(
            (0.3..3.0).contains(&ratio),
            "sim {r} vs paper {paper}: off by {ratio}"
        );
    }
    println!("table2 OK");
}
