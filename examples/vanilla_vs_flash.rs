//! Head-to-head on real training: FlashRecovery vs the vanilla
//! periodic-checkpoint baseline, same model, same injected failure.
//!
//! Reports, per system: detection latency, restart latency, lost
//! steps, checkpoint stall time, and total wall time — the RPO/RTO
//! comparison of the paper's §II on this testbed's real execution
//! plane (the paper-scale version is benches/table2/3).
//!
//!     cargo run --release --example vanilla_vs_flash -- \
//!         [--size tiny] [--dp 2] [--steps 30] [--ckpt-interval 5] [--timeout-s 3]

use flashrecovery::cluster::failure::FailureKind;
use flashrecovery::coordinator::ControllerConfig;
use flashrecovery::training::worker::{FailurePlan, Phase};
use flashrecovery::training::TrainingEngine;
use flashrecovery::util::Args;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let size = args.str_or("size", "tiny");
    let dp = args.usize_or("dp", 2);
    let steps = args.u64_or("steps", 30);
    let ckpt_interval = args.u64_or("ckpt-interval", 5);
    // The paper's baseline waits 1800 s for the collective timeout; we
    // scale it down so the example finishes, and report the paper-scale
    // equivalent separately (benches/table2_vanilla).
    let timeout_s = args.f64_or("timeout-s", 3.0);
    let fail_step = args.u64_or("fail-step", steps / 2);

    println!("[cmp] loading '{size}'…");
    let engine = TrainingEngine::load(&size)?;
    let failure = FailurePlan {
        rank: 1 % dp,
        step: fail_step,
        phase: Phase::FwdBwd,
        kind: FailureKind::Segfault,
    };

    // ---- FlashRecovery ------------------------------------------------
    let mut flash_cfg = ControllerConfig::flash(dp, steps);
    flash_cfg.failures = vec![failure];
    let t0 = std::time::Instant::now();
    let flash = engine.run(flash_cfg)?;
    let flash_wall = t0.elapsed().as_secs_f64();

    // ---- Vanilla baseline ---------------------------------------------
    let ckpt_dir = std::env::temp_dir().join(format!(
        "flashrec-cmp-{}-{}",
        std::process::id(),
        fail_step
    ));
    let mut vanilla_cfg =
        ControllerConfig::vanilla(dp, steps, ckpt_interval, Duration::from_secs_f64(timeout_s));
    vanilla_cfg.ckpt_dir = ckpt_dir.clone();
    vanilla_cfg.failures = vec![failure];
    let t1 = std::time::Instant::now();
    let vanilla = engine.run(vanilla_cfg)?;
    let vanilla_wall = t1.elapsed().as_secs_f64();
    std::fs::remove_dir_all(&ckpt_dir).ok();

    // ---- report --------------------------------------------------------
    let fr = &flash.recoveries[0];
    let vr = &vanilla.recoveries[0];
    println!("\n                        FlashRecovery      Vanilla");
    println!("detection latency       {:>10.3} s    {:>10.3} s", fr.detection_s, vr.detection_s);
    println!("restart latency         {:>10.3} s    {:>10.3} s", fr.restart_s, vr.restart_s);
    println!("resume step             {:>12}    {:>12}", fr.resume_step, vr.resume_step);
    println!("lost completed steps    {:>12}    {:>12}", fr.lost_steps, vr.lost_steps);
    println!(
        "checkpoint stalls       {:>12}    {:>12}",
        flash.checkpoints_taken, vanilla.checkpoints_taken
    );
    println!(
        "checkpoint stall time   {:>10.3} s    {:>10.3} s",
        flash.checkpoint_stall_s, vanilla.checkpoint_stall_s
    );
    println!("total wall time         {:>10.2} s    {:>10.2} s", flash_wall, vanilla_wall);

    assert_eq!(fr.lost_steps, 0, "FlashRecovery must lose no completed steps");
    assert!(vr.lost_steps > 0 || vr.resume_step < fail_step,
            "vanilla should have rolled back");
    assert!(fr.detection_s < vr.detection_s, "flash must detect faster");
    println!("\n[cmp] OK: FlashRecovery detected {:.1}x faster and lost {} steps vs {}",
        vr.detection_s / fr.detection_s.max(1e-3), fr.lost_steps, vr.lost_steps);
    Ok(())
}
