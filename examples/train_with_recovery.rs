//! END-TO-END VALIDATION DRIVER (recorded in EXPERIMENTS.md).
//!
//! Trains a real transformer with data parallelism through the full
//! three-layer stack (Pallas kernel -> JAX fwd/bwd/Adam -> AOT HLO ->
//! PJRT executed by the Rust coordinator), injects failures mid-run in
//! *both* phases the paper distinguishes (fwd/bwd -> resume at step i;
//! optimizer -> resume at step i+1), recovers checkpoint-free from DP
//! replicas, and proves the loss curve is bitwise-identical to a
//! failure-free run.
//!
//!     cargo run --release --example train_with_recovery -- \
//!         [--size small] [--dp 2] [--steps 60] [--base]
//!
//! `--size base --steps 300` is the ~100M-parameter run reported in
//! EXPERIMENTS.md (several hours of CPU time on this 1-core testbed).

use flashrecovery::cluster::failure::FailureKind;
use flashrecovery::coordinator::ControllerConfig;
use flashrecovery::training::worker::{FailurePlan, Phase};
use flashrecovery::training::TrainingEngine;
use flashrecovery::util::{Args, Json};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let size = args.str_or("size", "small");
    let dp = args.usize_or("dp", 2);
    let steps = args.u64_or("steps", 60);
    let seed = args.u64_or("seed", 0);
    let compare_clean = args.bool_or("compare-clean", true);

    println!("[e2e] loading '{size}' (AOT artifact compile)…");
    let t0 = std::time::Instant::now();
    let engine = TrainingEngine::load(&size)?;
    println!(
        "[e2e] compiled in {:.1}s — {:.1}M params",
        t0.elapsed().as_secs_f64(),
        engine.bundle.manifest.dims.param_count as f64 / 1e6
    );

    // Two failures: one in each phase of the §III-E case analysis.
    let f1_step = steps / 3;
    let f2_step = 2 * steps / 3;
    let failures = vec![
        FailurePlan {
            rank: 1 % dp,
            step: f1_step,
            phase: Phase::FwdBwd,
            kind: FailureKind::Segfault,
        },
        FailurePlan {
            rank: 0,
            step: f2_step,
            phase: Phase::OptStep,
            kind: FailureKind::Network,
        },
    ];

    let mut cfg = ControllerConfig::flash(dp, steps);
    cfg.seed = seed;
    cfg.failures = failures.clone();
    cfg.ranktable_path = Some(std::env::temp_dir().join("flashrec-e2e-ranktable.json"));
    cfg.max_wall = std::time::Duration::from_secs(4 * 3600);

    println!(
        "[e2e] training {steps} steps, dp={dp}; injecting {} failures \
         (fwd/bwd @ step {f1_step}, optimizer @ step {f2_step})",
        failures.len()
    );
    let t1 = std::time::Instant::now();
    let report = engine.run(cfg)?;
    let train_wall = t1.elapsed().as_secs_f64();

    println!("\n===== loss curve (with two recoveries) =====");
    for (step, loss) in &report.losses {
        let marker = if *step == f1_step + 1 || *step == f2_step + 1 { "  <- recovered" } else { "" };
        if step % args.u64_or("log-every", 5) == 0 || *step == 1 || marker != "" {
            println!("step {step:>5}  loss {loss:.4}{marker}");
        }
    }

    println!("\n===== recovery episodes =====");
    for (i, r) in report.recoveries.iter().enumerate() {
        println!(
            "#{i}: rank {:?} {} ({}), failed at step {}, resumed at step {} \
             (lost {} completed steps) — detect {:.3}s, restart {:.3}s \
             (restore {:.3}s), total {:.3}s",
            r.failed_ranks,
            r.kind.name(),
            if r.via_device_plugin { "device plugin" } else { "monitor process" },
            r.failed_at_step,
            r.resume_step,
            r.lost_steps,
            r.detection_s,
            r.restart_s,
            r.restore_s,
            r.total_s
        );
    }
    assert_eq!(report.recoveries.len(), 2, "expected both injected failures");
    assert_eq!(report.recoveries[0].resume_step, f1_step, "fwd/bwd -> step i");
    assert_eq!(report.recoveries[1].resume_step, f2_step + 1, "optimizer -> step i+1");
    assert!(report.recoveries.iter().all(|r| r.lost_steps == 0));
    assert_eq!(report.final_param_divergence, 0.0, "DP replicas diverged!");

    if compare_clean {
        println!("\n[e2e] re-running failure-free for loss-curve comparison…");
        let mut clean_cfg = ControllerConfig::flash(dp, steps);
        clean_cfg.seed = seed;
        clean_cfg.max_wall = std::time::Duration::from_secs(4 * 3600);
        let clean = engine.run(clean_cfg)?;
        // Join on step: the rank-0 loss event for the exact step where
        // rank 0 itself died is legitimately absent from the recovered
        // run (the process was gone before reporting), so compare all
        // common steps and require near-full coverage + identical tail.
        let mut max_diff = 0f32;
        let mut common = 0usize;
        for (s, l_clean) in &clean.losses {
            if let Some((_, l_rec)) = report.losses.iter().find(|(rs, _)| rs == s) {
                max_diff = max_diff.max((l_clean - l_rec).abs());
                common += 1;
            }
        }
        println!(
            "[e2e] {common}/{} steps present in both runs; \
             max |loss_clean - loss_recovered| = {max_diff:.2e}",
            clean.losses.len()
        );
        assert!(common + 2 >= clean.losses.len() as usize, "too many gaps");
        assert!(max_diff < 1e-5, "recovered trajectory diverged from clean run");
        let last_clean = clean.losses.last().unwrap();
        let last_rec = report.losses.last().unwrap();
        assert_eq!(last_clean.0, last_rec.0);
        assert!((last_clean.1 - last_rec.1).abs() < 1e-6, "final losses differ");
    }

    // Machine-readable record for EXPERIMENTS.md.
    let mut out = Json::object();
    out.set("size", size.as_str())
        .set("dp", dp)
        .set("steps", steps)
        .set("train_wall_s", train_wall)
        .set("report", report.to_json());
    let path = "e2e_report.json";
    std::fs::write(path, out.render_pretty())?;
    println!("\n[e2e] OK — report written to {path}");
    Ok(())
}
