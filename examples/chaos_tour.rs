//! Tour of the built-in chaos scenario library at 256 devices: run
//! every campaign, print a per-scenario recovery-time table, and check
//! each spec's declared assertions.
//!
//!     cargo run --release --example chaos_tour -- [--devices 256] [--seed 1]

use flashrecovery::chaos::{evaluate, library, passed, run_campaign};
use flashrecovery::metrics::bench::BenchReport;
use flashrecovery::util::Args;

fn main() {
    let args = Args::parse_env();
    let devices = args.usize_or("devices", 256);
    let seed = args.u64_or("seed", 1);

    let mut report = BenchReport::new(
        &format!("chaos tour @ {devices} devices (seed {seed}) — seconds unless noted"),
        &["recoveries", "worst detect", "worst restart", "downtime", "steps", "pass"],
    );

    let mut all_pass = true;
    for spec in library::all(devices) {
        let (r, journal) = run_campaign(&spec, seed).expect("campaign runs");
        let outcomes = evaluate(&spec.assertions, &r);
        let ok = passed(&outcomes);
        all_pass &= ok;
        let worst_detect = r
            .recoveries
            .iter()
            .map(|x| x.detection_s)
            .fold(0.0f64, f64::max);
        let worst_restart = r
            .recoveries
            .iter()
            .map(|x| x.restart_s)
            .fold(0.0f64, f64::max);
        report.row(
            spec.name.clone(),
            vec![
                r.recoveries.len() as f64,
                worst_detect,
                worst_restart,
                r.total_downtime_s,
                r.steps_completed as f64,
                if ok { 1.0 } else { 0.0 },
            ],
        );
        if !ok {
            for o in outcomes.iter().filter(|o| !o.pass) {
                println!("  [{}] FAIL {}: {}", spec.name, o.name, o.detail);
            }
        }
        // journals replay byte-identically for (spec, seed)
        let (_, j2) = run_campaign(&spec, seed).unwrap();
        assert_eq!(journal.render(), j2.render(), "{} journal nondeterministic", spec.name);
    }

    report.note("pass = all spec assertions held; every journal verified replay-identical");
    report.note(
        "worst restart stays near-constant across scenario complexity — \
         the paper's scale-independence claim under compound failures",
    );
    report.print();
    assert!(all_pass, "some scenario failed its assertions");
    println!("chaos_tour OK");
}
