//! Quickstart: load the AOT-compiled transformer, run a short DP
//! training job under FlashRecovery, print the loss curve.
//!
//!     cargo run --release --example quickstart -- [--size tiny] [--dp 2] [--steps 20]

use flashrecovery::coordinator::ControllerConfig;
use flashrecovery::training::TrainingEngine;
use flashrecovery::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let size = args.str_or("size", "tiny");
    let dp = args.usize_or("dp", 2);
    let steps = args.u64_or("steps", 20);

    println!("[quickstart] loading model '{size}' (compiling AOT artifacts)…");
    let engine = TrainingEngine::load(&size)?;
    let m = &engine.bundle.manifest;
    println!(
        "[quickstart] {} params, vocab={}, seq={}, batch/rank={}, dp={dp}",
        m.dims.param_count, m.dims.vocab, m.dims.seq, m.dims.batch
    );

    let mut cfg = ControllerConfig::flash(dp, steps);
    cfg.seed = args.u64_or("seed", 0);
    let report = engine.run(cfg)?;

    println!("\nstep   loss");
    for (step, loss) in &report.losses {
        println!("{step:>4}   {loss:.4}");
    }
    println!(
        "\n[quickstart] {} steps in {:.1}s ({:.2} s/step), DP-consistent: {}",
        report.final_step,
        report.wall_s,
        report.wall_s / report.final_step.max(1) as f64,
        report.final_param_divergence == 0.0
    );
    Ok(())
}
