//! The paper's headline claim (§IV-C): recovery time is nearly
//! scale-independent. Sweeps the simulated control plane from 32 to
//! 18,000 devices for both systems and prints the Tab. II/III-style
//! rows plus the growth factor.
//!
//!     cargo run --release --example scale_sweep -- [--runs 32]

use flashrecovery::cluster::{simulate_flash, simulate_vanilla, scenario::average, ScenarioConfig};
use flashrecovery::metrics::bench::BenchReport;
use flashrecovery::util::Args;

fn main() {
    let args = Args::parse_env();
    let runs = args.u64_or("runs", 32);

    // (devices, model params) — Tab. III's sweep plus two larger points.
    let sweep: &[(usize, f64, &str)] = &[
        (32, 7e9, "7B"),
        (960, 7e9, "7B"),
        (80, 70e9, "70B"),
        (800, 70e9, "70B"),
        (2880, 70e9, "70B"),
        (2880, 175e9, "175B"),
        (4800, 175e9, "175B"),
        (10000, 175e9, "175B"),
        (18000, 175e9, "175B"),
    ];

    let mut report = BenchReport::new(
        "scale sweep: FlashRecovery vs vanilla recovery time (simulated, seconds)",
        &["devices", "flash detect", "flash restart", "flash total", "vanilla total"],
    );
    let mut flash_totals = Vec::new();
    for &(devices, params, name) in sweep {
        let flash = average(runs, 7, |s| {
            simulate_flash(&ScenarioConfig::paper(devices, params, s))
        });
        let vanilla = average(runs, 7, |s| {
            simulate_vanilla(&ScenarioConfig::paper(devices, params, s))
        });
        flash_totals.push(flash.total_s);
        report.row(
            format!("{name} @ {devices}"),
            vec![
                devices as f64,
                flash.detection_s,
                flash.restart_s,
                flash.total_s,
                vanilla.total_s,
            ],
        );
    }
    let growth = flash_totals.iter().cloned().fold(0.0f64, f64::max)
        / flash_totals.iter().cloned().fold(f64::INFINITY, f64::min);
    report.note(format!(
        "FlashRecovery total grows only {growth:.2}x from 32 to 18,000 devices \
         (paper: ~1.52x from 32 to 4,800); vanilla grows with scale."
    ));
    report.note(format!("each row averages {runs} seeded Monte-Carlo runs"));
    report.print();

    assert!(growth < 2.0, "flash recovery should be nearly scale-independent");
}
