//! Vendored host-side stand-in for the `xla` crate (xla-rs bindings).
//!
//! The build environment has neither crates.io access nor the native
//! `xla_extension` C++ libraries, so this crate keeps the repo
//! compiling and every non-PJRT code path fully functional:
//!
//! * [`Literal`] — complete host implementation (shaped f32/i32
//!   buffers): `scalar`, `vec1`, `reshape`, `to_vec`,
//!   `get_first_element`, `element_count`, `ty`, `array_shape`;
//! * PJRT surface ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`HloModuleProto`], [`XlaComputation`]) — present so callers
//!   compile, but `PjRtClient::cpu()` returns an error: there is no
//!   accelerator runtime to execute HLO here. Code must treat a failed
//!   client construction as "live training plane unavailable" and fall
//!   back to the discrete-event simulator (see DESIGN.md §7).
//!
//! Swap this path dependency for the real `xla` crate to light up the
//! live training plane — the API subset matches call-for-call.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`'s role (implements
/// `std::error::Error`, so `?` converts into `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }

    fn pjrt_unavailable() -> Self {
        Error::new(
            "vendored xla stub: PJRT runtime unavailable in this build — \
             swap rust/vendor/xla for the real xla crate to execute HLO \
             artifacts (DESIGN.md §7)",
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes (subset; the repo only moves f32 and s32 buffers).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::S32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            Data::F32(_) => ElementType::F32,
            Data::S32(_) => ElementType::S32,
        }
    }
}

/// Host element types storable in a [`Literal`].
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn wrap(xs: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(xs: Vec<Self>) -> Data {
        Data::F32(xs)
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(xs: Vec<Self>) -> Data {
        Data::S32(xs)
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::S32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Array shape of a literal (`dims` in row-major order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host tensor: shaped buffer of one element type.
#[derive(Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal { data: T::wrap(vec![value]), dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal {
            data: T::wrap(values.to_vec()),
            dims: vec![values.len() as i64],
        }
    }

    /// Reinterpret the buffer under new dimensions (element count must
    /// be preserved — same contract as the real crate).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape {:?} -> {dims:?}: element count mismatch ({})",
                self.dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.data.ty())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| {
            Error::new(format!(
                "to_vec: literal holds {:?}, requested {:?}",
                self.data.ty(),
                T::TY
            ))
        })
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::new("get_first_element on empty literal"))
    }

    /// Decompose a tuple literal. The stub never produces tuples (they
    /// only come out of PJRT execution), so this is unreachable in
    /// practice and errors defensively.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new("stub literal is not a tuple"))
    }
}

/// Parsed HLO module (opaque placeholder).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::pjrt_unavailable())
    }
}

/// Computation wrapper (opaque placeholder).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT device buffer (opaque placeholder).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::pjrt_unavailable())
    }
}

/// PJRT client. Construction fails in the stub: callers use this as the
/// "is the live training plane available?" probe.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::pjrt_unavailable())
    }

    pub fn platform_name(&self) -> String {
        "vendored-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::pjrt_unavailable())
    }
}

/// Compiled executable (opaque placeholder).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::pjrt_unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.ty().unwrap(), ElementType::F32);
    }

    #[test]
    fn literal_scalar_i32() {
        let l = Literal::scalar(7i32);
        assert_eq!(l.get_first_element::<i32>().unwrap(), 7);
        assert_eq!(l.element_count(), 1);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn reshape_rejects_mismatch() {
        assert!(Literal::vec1(&[0f32; 6]).reshape(&[4, 2]).is_err());
    }

    #[test]
    fn pjrt_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
