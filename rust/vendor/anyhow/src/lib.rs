//! Vendored, offline subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this shim provides
//! the exact API surface the repo uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Swap this path dependency for the real `anyhow = "1"` in
//! `Cargo.toml` when a registry is available — no source changes needed.
//!
//! Semantics mirrored from upstream:
//! * `Error` deliberately does **not** implement `std::error::Error`,
//!   which is what lets the blanket `From<E: std::error::Error>` impl
//!   coexist with `From<T> for T`;
//! * `Display` shows the outermost context only; `{:#}` shows the whole
//!   chain separated by `: `; `Debug` shows the chain as a `Caused by`
//!   list (what `fn main() -> Result<()>` prints on error);
//! * `downcast_ref` reaches through contexts to the root cause.

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>` — `Result` with a boxed, context-carrying error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: a stack of human-readable context strings
/// (outermost first) over a root cause.
pub struct Error {
    context: Vec<String>,
    root: Box<dyn StdError + Send + Sync + 'static>,
}

/// Root cause used by `anyhow!`-style message errors.
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Create an error from a display-able message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            context: Vec::new(),
            root: Box::new(MessageError(message.to_string())),
        }
    }

    /// Wrap an existing error as the root cause.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { context: Vec::new(), root: Box::new(error) }
    }

    /// Push a new outermost context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.insert(0, context.to_string());
        self
    }

    /// Downcast the root cause by type (context layers are skipped,
    /// matching upstream's chain-walking behaviour for wrapped roots).
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.root.downcast_ref::<E>()
    }

    /// The root cause of this error.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        &*self.root
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>, sep: &str) -> fmt::Result {
        for (i, c) in self.context.iter().enumerate() {
            if i > 0 {
                f.write_str(sep)?;
            }
            f.write_str(c)?;
        }
        if !self.context.is_empty() {
            f.write_str(sep)?;
        }
        write!(f, "{}", self.root)
    }
}

impl fmt::Display for Error {
    // Outermost message only; `{:#}` renders the full chain.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            return self.write_chain(f, ": ");
        }
        match self.context.first() {
            Some(c) => f.write_str(c),
            None => write!(f, "{}", self.root),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.first() {
            Some(c) => f.write_str(c)?,
            None => write!(f, "{}", self.root)?,
        }
        let mut causes: Vec<String> =
            self.context.iter().skip(1).cloned().collect();
        if !self.context.is_empty() {
            causes.push(self.root.to_string());
        }
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

/// Private conversion trait so `Context` has one impl covering both
/// `Result<T, E: std::error::Error>` and `Result<T, anyhow::Error>`.
pub trait IntoError {
    fn into_anyhow(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_anyhow(self) -> Error {
        Error::new(self)
    }
}

impl IntoError for Error {
    fn into_anyhow(self) -> Error {
        self
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or display-able value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::TimedOut, "timed out")
    }

    #[test]
    fn display_shows_outermost_and_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading frame")
            .unwrap_err()
            .context("serving connection");
        assert_eq!(format!("{e}"), "serving connection");
        assert_eq!(
            format!("{e:#}"),
            "serving connection: reading frame: timed out"
        );
    }

    #[test]
    fn downcast_reaches_root_through_context() {
        let e: Error = Err::<(), _>(io_err()).context("ctx").unwrap_err();
        let ioe = e.downcast_ref::<std::io::Error>().unwrap();
        assert_eq!(ioe.kind(), std::io::ErrorKind::TimedOut);
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(n: i32) -> Result<i32> {
            ensure!(n >= 0, "negative: {n}");
            if n == 1 {
                bail!("one is not allowed");
            }
            Ok(n)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(1).unwrap_err().to_string(), "one is not allowed");
        assert_eq!(f(-3).unwrap_err().to_string(), "negative: -3");
        let x = 7;
        assert_eq!(anyhow!("x={x}").to_string(), "x=7");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }
}
