//! Bench harness (criterion substitute) for the `benches/*.rs` targets.
//!
//! Each paper table/figure bench builds a [`BenchReport`], adds named
//! rows or series, and prints both a human table and a JSON line
//! (machine-parsable, prefixed `BENCH_JSON:`) so results can be scraped
//! into EXPERIMENTS.md.

use super::Histogram;
use crate::util::Json;
use std::path::Path;
use std::time::Instant;

/// Time `f` with `warmup` unmeasured runs then `samples` measured runs.
pub fn time_fn<F: FnMut()>(warmup: u32, samples: u32, mut f: F) -> Histogram {
    for _ in 0..warmup {
        f();
    }
    let mut h = Histogram::new();
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        h.record(t0.elapsed().as_secs_f64());
    }
    h
}

/// Prevent the optimizer from discarding a value (std black_box shim).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A labelled table of results: rows x columns of f64 values.
pub struct BenchReport {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
    pub notes: Vec<String>,
}

impl BenchReport {
    pub fn new(name: &str, columns: &[&str]) -> Self {
        BenchReport {
            name: name.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) -> &mut Self {
        let label = label.into();
        assert_eq!(
            values.len(),
            self.columns.len(),
            "row '{label}' arity mismatch"
        );
        self.rows.push((label, values));
        self
    }

    pub fn note(&mut self, text: impl Into<String>) -> &mut Self {
        self.notes.push(text.into());
        self
    }

    /// Render and print the table + machine-readable JSON line.
    pub fn print(&self) {
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([8])
            .max()
            .unwrap();
        println!("\n=== {} ===", self.name);
        print!("{:label_w$}", "");
        for c in &self.columns {
            print!("  {c:>14}");
        }
        println!();
        for (label, values) in &self.rows {
            print!("{label:label_w$}");
            for v in values {
                if v.abs() >= 1000.0 || (*v != 0.0 && v.abs() < 0.01) {
                    print!("  {v:>14.3e}");
                } else {
                    print!("  {v:>14.3}");
                }
            }
            println!();
        }
        for n in &self.notes {
            println!("  note: {n}");
        }
        println!("BENCH_JSON: {}", self.to_json().render());
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::object();
        obj.set("name", self.name.as_str());
        obj.set(
            "columns",
            Json::Array(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
        );
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(l, vs)| {
                let mut r = Json::object();
                r.set("label", l.as_str());
                r.set(
                    "values",
                    Json::Array(vs.iter().map(|v| Json::Num(*v)).collect()),
                );
                r
            })
            .collect();
        obj.set("rows", Json::Array(rows));
        obj.set(
            "notes",
            Json::Array(self.notes.iter().map(|n| Json::Str(n.clone())).collect()),
        );
        obj
    }

    /// Write the report as a structured JSON file (`BENCH_*.json`) —
    /// the machine-readable sink CI uploads and perf-gates.
    pub fn write_json(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().render_pretty() + "\n")
    }

    /// Find a row's values by label.
    pub fn row_values(&self, label: &str) -> Option<&[f64]> {
        self.rows
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, vs)| vs.as_slice())
    }

    /// Perf-gate this report against a committed baseline (the same
    /// JSON schema): every row label present in both must keep
    /// `values[col] <= max_ratio x baseline`. Returns the violations;
    /// empty means the gate passes. Rows absent from the baseline are
    /// skipped so adding scales doesn't require a baseline refresh.
    pub fn gate(&self, baseline: &Json, col: usize, max_ratio: f64) -> Vec<String> {
        let empty: Vec<Json> = Vec::new();
        let base_rows = baseline.get("rows").as_array().unwrap_or(&empty);
        let mut violations = Vec::new();
        for (label, values) in &self.rows {
            let Some(base) = base_rows
                .iter()
                .find(|r| r.get("label").as_str() == Some(label.as_str()))
            else {
                continue;
            };
            let Some(bv) = base.get("values").idx(col).as_f64() else {
                continue;
            };
            let Some(cv) = values.get(col).copied() else { continue };
            if bv > 0.0 && cv > bv * max_ratio {
                violations.push(format!(
                    "{label}: {cv:.3} exceeds {max_ratio} x baseline {bv:.3}"
                ));
            }
        }
        violations
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_collects_samples() {
        let h = time_fn(1, 5, || {
            black_box((0..1000).sum::<u64>());
        });
        assert_eq!(h.len(), 5);
        assert!(h.mean() >= 0.0);
    }

    #[test]
    fn report_json_roundtrip() {
        let mut r = BenchReport::new("demo", &["a", "b"]);
        r.row("x", vec![1.0, 2.0]).note("hello");
        let j = r.to_json();
        assert_eq!(j.get("name").as_str(), Some("demo"));
        assert_eq!(j.get("rows").idx(0).get("values").idx(1).as_f64(), Some(2.0));
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn report_rejects_bad_arity() {
        let mut r = BenchReport::new("demo", &["a", "b"]);
        r.row("x", vec![1.0]);
    }

    #[test]
    fn write_json_is_parseable() {
        let dir = crate::util::temp_dir("bench").unwrap();
        let path = dir.join("BENCH_demo.json");
        let mut r = BenchReport::new("demo", &["p50 ms"]);
        r.row("n=256", vec![12.5]).note("sink test");
        r.write_json(&path).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("name").as_str(), Some("demo"));
        assert_eq!(back.get("rows").idx(0).get("values").idx(0).as_f64(), Some(12.5));
        assert_eq!(back.get("notes").idx(0).as_str(), Some("sink test"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn gate_flags_only_regressions() {
        let mut baseline = BenchReport::new("demo", &["p50 ms"]);
        baseline
            .row("n=256", vec![10.0])
            .row("n=8192", vec![20.0]);
        let base_json = baseline.to_json();

        let mut ok = BenchReport::new("demo", &["p50 ms"]);
        // within 1.5x, plus a row the baseline doesn't know (skipped)
        ok.row("n=256", vec![14.9])
            .row("n=8192", vec![29.0])
            .row("n=16384", vec![500.0]);
        assert!(ok.gate(&base_json, 0, 1.5).is_empty());

        let mut bad = BenchReport::new("demo", &["p50 ms"]);
        bad.row("n=256", vec![9.0]).row("n=8192", vec![31.0]);
        let v = bad.gate(&base_json, 0, 1.5);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("n=8192"), "{v:?}");
    }
}
