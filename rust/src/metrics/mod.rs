//! Metrics: histograms, stopwatches, counters, and a small bench harness
//! (criterion substitute) used by `benches/*.rs`.

pub mod bench;

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Streaming histogram over f64 samples. Keeps all samples (experiment
/// scales here are small) so quantiles are exact.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum() / self.samples.len() as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Exact quantile, q in [0,1], linear interpolation.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            xs[lo]
        } else {
            let frac = pos - lo as f64;
            xs[lo] * (1.0 - frac) + xs[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// Wall-clock stopwatch measuring into a named registry.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

/// Thread-safe named-metric registry: counters and timing histograms.
/// One per engine/controller; rendered into experiment reports.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    timings: Mutex<BTreeMap<String, Histogram>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += delta;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn time(&self, name: &str, secs: f64) {
        self.timings
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .record(secs);
    }

    pub fn timing(&self, name: &str) -> Histogram {
        self.timings
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    /// Render all metrics as one human-readable report block.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, h) in self.timings.lock().unwrap().iter() {
            out.push_str(&format!(
                "{k}: n={} mean={:.4}s p50={:.4}s p95={:.4}s max={:.4}s\n",
                h.len(),
                h.mean(),
                h.p50(),
                h.p95(),
                h.max()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_exact() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.p50() - 50.5).abs() < 1e-9);
        assert!((h.quantile(0.0) - 1.0).abs() < 1e-9);
        assert!((h.quantile(1.0) - 100.0).abs() < 1e-9);
        assert!(h.p95() > 90.0 && h.p95() < 100.0);
    }

    #[test]
    fn histogram_empty_is_safe() {
        let h = Histogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_std() {
        let mut h = Histogram::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            h.record(v);
        }
        assert!((h.std() - 2.138).abs() < 0.01);
    }

    #[test]
    fn registry_counts_and_times() {
        let r = Registry::new();
        r.inc("failures");
        r.add("failures", 2);
        r.time("restart", 1.0);
        r.time("restart", 3.0);
        assert_eq!(r.counter("failures"), 3);
        assert_eq!(r.counter("unknown"), 0);
        let t = r.timing("restart");
        assert_eq!(t.len(), 2);
        assert!((t.mean() - 2.0).abs() < 1e-9);
        assert!(r.report().contains("failures: 3"));
    }

    #[test]
    fn stopwatch_measures_time() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(sw.elapsed_ms() >= 9.0);
    }
}
