//! Metrics: histograms, stopwatches, counters, and a small bench harness
//! (criterion substitute) used by `benches/*.rs`.

pub mod bench;

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Streaming histogram over f64 samples. The default mode keeps every
/// sample (bench scales are small, quantiles exact); bounded mode
/// ([`Histogram::with_capacity`]) keeps an Algorithm-R reservoir so
/// million-record chaos soaks stay flat in memory, while
/// count/sum/min/max stay exact (running) and std stays exact
/// (Welford) — only quantiles become reservoir estimates.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Reservoir bound; 0 = unbounded exact mode.
    cap: usize,
    samples: Vec<f64>,
    count: u64,
    total: f64,
    lo: f64,
    hi: f64,
    /// Welford accumulators — exact mean/variance at any count.
    w_mean: f64,
    w_m2: f64,
    /// SplitMix64 state (inline `util::rng` step) for reservoir draws;
    /// fixed seed keeps soak quantiles reproducible.
    rng: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::sized(0)
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::sized(0)
    }

    /// Bounded-reservoir mode: exact until `cap` samples, uniform
    /// reservoir sampling past it (memory stays O(cap) forever).
    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "reservoir capacity must be positive");
        Self::sized(cap)
    }

    fn sized(cap: usize) -> Self {
        Histogram {
            cap,
            samples: Vec::new(),
            count: 0,
            total: 0.0,
            lo: f64::INFINITY,
            hi: f64::NEG_INFINITY,
            w_mean: 0.0,
            w_m2: 0.0,
            rng: 0x5DEE_CE66_D1CE_4E5B,
        }
    }

    fn next_rand(&mut self) -> u64 {
        self.rng = self.rng.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.rng;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn record(&mut self, v: f64) {
        self.count += 1;
        self.total += v;
        self.lo = self.lo.min(v);
        self.hi = self.hi.max(v);
        let d = v - self.w_mean;
        self.w_mean += d / self.count as f64;
        self.w_m2 += d * (v - self.w_mean);
        if self.cap == 0 || self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // Algorithm R: admit with probability cap/count by drawing
            // a slot over [0, count); in-range draws replace a
            // uniformly chosen resident.
            let j = self.next_rand() % self.count;
            if (j as usize) < self.cap {
                self.samples[j as usize] = v;
            }
        }
    }

    /// Total samples recorded (not the resident reservoir size).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Samples resident in memory (`== len()` in unbounded mode).
    pub fn resident(&self) -> usize {
        self.samples.len()
    }

    pub fn sum(&self) -> f64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.lo
    }

    pub fn max(&self) -> f64 {
        self.hi
    }

    pub fn std(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        (self.w_m2 / (self.count - 1) as f64).sqrt()
    }

    /// Quantile over resident samples — exact unless the reservoir
    /// spilled — q in [0,1], linear interpolation.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            xs[lo]
        } else {
            let frac = pos - lo as f64;
            xs[lo] * (1.0 - frac) + xs[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// Wall-clock stopwatch measuring into a named registry.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

/// Thread-safe named-metric registry: counters and timing histograms.
/// One per engine/controller; rendered into experiment reports.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    timings: Mutex<BTreeMap<String, Histogram>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, delta: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += delta;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn time(&self, name: &str, secs: f64) {
        self.timings
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .record(secs);
    }

    pub fn timing(&self, name: &str) -> Histogram {
        self.timings
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    /// Render all metrics as one human-readable report block.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, h) in self.timings.lock().unwrap().iter() {
            out.push_str(&format!(
                "{k}: n={} mean={:.4}s p50={:.4}s p95={:.4}s max={:.4}s\n",
                h.len(),
                h.mean(),
                h.p50(),
                h.p95(),
                h.max()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_exact() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert!((h.p50() - 50.5).abs() < 1e-9);
        assert!((h.quantile(0.0) - 1.0).abs() < 1e-9);
        assert!((h.quantile(1.0) - 100.0).abs() < 1e-9);
        assert!(h.p95() > 90.0 && h.p95() < 100.0);
    }

    #[test]
    fn histogram_empty_is_safe() {
        let h = Histogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_std() {
        let mut h = Histogram::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            h.record(v);
        }
        assert!((h.std() - 2.138).abs() < 0.01);
    }

    #[test]
    fn bounded_mode_is_exact_below_capacity() {
        let mut h = Histogram::with_capacity(100);
        for i in 1..=50 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 50);
        assert_eq!(h.resident(), 50);
        assert!((h.p50() - 25.5).abs() < 1e-9);
        assert!((h.quantile(1.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn bounded_reservoir_memory_stays_flat_over_1m_records() {
        // Regression: the unbounded histogram grew one f64 per record
        // forever — a long chaos soak leaked without bound. Bounded
        // mode must hold residency at `cap` across 1M records while
        // count/sum/min/max/mean/std stay exact.
        let cap = 1024;
        let mut h = Histogram::with_capacity(cap);
        let n = 1_000_000u64;
        for i in 0..n {
            h.record(i as f64);
        }
        assert_eq!(h.len(), n as usize);
        assert_eq!(h.resident(), cap, "reservoir must not grow past cap");
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), (n - 1) as f64);
        assert!((h.mean() - 499_999.5).abs() < 1e-6);
        // uniform 0..n-1: sample std = sqrt(n*(n+1)/12) ~= 288675.28
        assert!((h.std() - 288_675.28).abs() < 1.0, "std={}", h.std());
        // quantiles are estimates over a 1024-sample uniform reservoir
        let p50 = h.p50();
        assert!(
            (350_000.0..650_000.0).contains(&p50),
            "reservoir p50 estimate off: {p50}"
        );
    }

    #[test]
    fn registry_counts_and_times() {
        let r = Registry::new();
        r.inc("failures");
        r.add("failures", 2);
        r.time("restart", 1.0);
        r.time("restart", 3.0);
        assert_eq!(r.counter("failures"), 3);
        assert_eq!(r.counter("unknown"), 0);
        let t = r.timing("restart");
        assert_eq!(t.len(), 2);
        assert!((t.mean() - 2.0).abs() < 1e-9);
        assert!(r.report().contains("failures: 3"));
    }

    #[test]
    fn stopwatch_measures_time() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(sw.elapsed_ms() >= 9.0);
    }
}
