//! `flashrecovery` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   train          run a real DP training job (optionally with an
//!                  injected failure) under FlashRecovery or vanilla
//!   simulate       one paper-scale recovery scenario on the simulator
//!   scenario       declarative chaos campaigns: list / run / export
//!   bench          unified bench runner: `bench <suite>` with suite
//!                  one of rebuild (group-reconstruction scale sweep),
//!                  restore (shard-aware streaming restore), detect
//!                  (detection latency over leased heartbeats), store
//!                  (store data-plane throughput, plain + replicated);
//!                  emits a BENCH_*.json report, optionally perf-gated
//!                  against a committed baseline via
//!                  `--baseline <path> [--gate [RATIO]] [--json <out>]`
//!   trace          run a live chaos scenario with the flight recorder
//!                  on and write a Perfetto-viewable Chrome trace
//!                  (plus an optional JSONL journal); --check
//!                  self-validates the trace against the episode
//!   netem          run an impaired-plane chaos scenario (a spec with a
//!                  `netem:` section) over real degraded sockets
//!                  (DESIGN.md §15); --check asserts the outcome,
//!                  --calibrate prints the §6 latency model refreshed
//!                  from the measured wire numbers
//!   info           print artifact/manifest information
//!
//! Examples:
//!   flashrecovery train --size tiny --dp 2 --steps 20
//!   flashrecovery train --size tiny --dp 2 --steps 20 \
//!       --fail-rank 1 --fail-step 8 --fail-phase optstep
//!   flashrecovery train --mode vanilla --ckpt-interval 5 --timeout-s 3 \
//!       --fail-rank 1 --fail-step 8
//!   flashrecovery simulate --devices 4800 --params-b 175 --mode flash
//!   flashrecovery scenario list
//!   flashrecovery scenario run --spec rolling_cascade --seed 7
//!   flashrecovery scenario run --spec my_campaign.json --journal out.jsonl
//!   flashrecovery scenario export --spec flaky_node > flaky.json
//!   flashrecovery bench rebuild --json BENCH_group_rebuild.json \
//!       --baseline ci/BENCH_group_rebuild.baseline.json --gate
//!   flashrecovery trace silent_hang --out trace.json --check
//!   flashrecovery netem detection_under_loss --check
//!   flashrecovery netem all --check --calibrate
//!   flashrecovery info --size small

use flashrecovery::cluster::failure::FailureKind;
use flashrecovery::cluster::{simulate_flash, simulate_vanilla, ScenarioConfig};
use flashrecovery::coordinator::ControllerConfig;
use flashrecovery::runtime::load_manifest;
use flashrecovery::training::worker::{FailurePlan, Phase};
use flashrecovery::training::TrainingEngine;
use flashrecovery::util::{artifacts_dir, Args, BenchFlags};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    match args.positional.first().map(String::as_str) {
        Some("train") => train(&args),
        Some("simulate") => simulate(&args),
        Some("scenario") => scenario(&args),
        Some("bench") => bench(&args),
        Some("trace") => trace_cmd(&args),
        Some("netem") => netem(&args),
        Some("info") => info(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}");
            usage();
            std::process::exit(2);
        }
        None => {
            usage();
            Ok(())
        }
    }
}

/// `bench <suite>` — the unified bench runner.
fn bench(args: &Args) -> anyhow::Result<()> {
    let suite = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| {
            anyhow::anyhow!("bench needs a suite: rebuild|restore|detect|store|redundancy")
        })?;
    run_bench_suite(suite, args)
}

fn run_bench_suite(suite: &str, args: &Args) -> anyhow::Result<()> {
    match suite {
        "rebuild" => rebuild_bench(args),
        "restore" => restore_bench(args),
        "detect" => detect_bench(args),
        "store" => store_bench(args),
        "redundancy" => redundancy_bench(args),
        other => anyhow::bail!(
            "unknown bench suite {other:?} (rebuild|restore|detect|store|redundancy)"
        ),
    }
}

fn usage() {
    println!(
        "flashrecovery — fast and low-cost failure recovery for LLM training\n\
         \n\
         USAGE: flashrecovery <train|simulate|scenario|bench|trace|netem|info> [--flags]\n\
         \n\
         train:    --size tiny|small|base  --dp N  --steps N  --seed N\n\
         \u{20}         --mode flash|vanilla  --ckpt-interval N  --timeout-s S\n\
         \u{20}         --fail-rank N --fail-step N --fail-phase fwdbwd|optstep\n\
         simulate: --devices N  --params-b N  --mode flash|vanilla  --runs N\n\
         scenario: list | run --spec <name|file.json> [--seed N]\n\
         \u{20}         [--devices N] [--journal out.jsonl] [--live]\n\
         \u{20}         | export --spec <name> [--devices N]\n\
         bench:    <rebuild|restore|detect|store|redundancy>\n\
         \u{20}         [--baseline FILE] [--gate [RATIO]] [--json FILE]\n\
         \u{20}         rebuild: [--scales 256,1024,4096,8192] [--samples N]\n\
         \u{20}                  [--failures N] [--live-survivors N]\n\
         \u{20}         restore: [--sizes 262144,1048576] [--shards 2,4]\n\
         \u{20}                  [--samples N] [--chunk-kib N]\n\
         \u{20}         detect:  [--scales 64,256,1024,4096] [--samples N]\n\
         \u{20}                  [--live-agents N] [--interval-ms N]\n\
         \u{20}                  [--lease-misses N] [--node-agent]\n\
         \u{20}         store:   [--clients 64,1024,4096,8192,65536]\n\
         \u{20}                  [--connections N] [--repeats N] [--rounds N]\n\
         \u{20}                  [--replicas N] [--assert]\n\
         \u{20}         redundancy: [--sizes 262144,1048576] [--samples N]\n\
         \u{20}                  [--k N] [--m N] [--chunk-kib N] [--assert]\n\
         trace:    <name|file.json> [--devices N] [--out trace.json]\n\
         \u{20}         [--journal FILE] [--check]\n\
         netem:    <name|file.json|all> [--devices N] [--check]\n\
         \u{20}         [--calibrate] [--driver detection|restore|heal]\n\
         info:     --size tiny|small|base"
    );
}

fn parse_phase(s: &str) -> Phase {
    match s {
        "optstep" | "opt" | "optimizer" => Phase::OptStep,
        _ => Phase::FwdBwd,
    }
}

fn train(args: &Args) -> anyhow::Result<()> {
    // Declarative path: a JSON job file drives the whole run.
    if let Some(path) = args.get("config") {
        let job = flashrecovery::config::JobConfig::load(path)?;
        let cfg = ControllerConfig::from_job(&job)?;
        println!("[train] job config {path}: model={} dp={}", job.model, job.parallelism.dp);
        let engine = TrainingEngine::load(&job.model)?;
        let report = engine.run(cfg)?;
        println!("{}", report.to_json().render_pretty());
        return Ok(());
    }

    let size = args.str_or("size", "tiny");
    let dp = args.usize_or("dp", 2);
    let steps = args.u64_or("steps", 20);
    let mode = args.str_or("mode", "flash");

    let mut cfg = if mode == "vanilla" {
        ControllerConfig::vanilla(
            dp,
            steps,
            args.u64_or("ckpt-interval", 5),
            Duration::from_secs_f64(args.f64_or("timeout-s", 5.0)),
        )
    } else {
        ControllerConfig::flash(dp, steps)
    };
    cfg.seed = args.u64_or("seed", 0);
    if let Some(rank) = args.get("fail-rank") {
        cfg.failures.push(FailurePlan {
            rank: rank.parse()?,
            step: args.u64_or("fail-step", steps / 2),
            phase: parse_phase(&args.str_or("fail-phase", "fwdbwd")),
            kind: FailureKind::Segfault,
        });
    }

    println!("[train] loading '{size}'…");
    let engine = TrainingEngine::load(&size)?;
    let report = engine.run(cfg)?;

    for (step, loss) in &report.losses {
        if step % args.u64_or("log-every", 5) == 0 || *step == 1 {
            println!("step {step:>6}  loss {loss:.4}");
        }
    }
    for r in &report.recoveries {
        println!(
            "[recovery] {} ranks {:?} at step {} -> resumed step {} \
             (lost {}), detect {:.3}s restart {:.3}s",
            r.mode.name(),
            r.failed_ranks,
            r.failed_at_step,
            r.resume_step,
            r.lost_steps,
            r.detection_s,
            r.restart_s
        );
    }
    println!(
        "[train] done: {} steps, wall {:.1}s, dp-consistent={}",
        report.final_step,
        report.wall_s,
        report.final_param_divergence == 0.0
    );
    Ok(())
}

fn simulate(args: &Args) -> anyhow::Result<()> {
    let devices = args.usize_or("devices", 4800);
    let params = args.f64_or("params-b", 175.0) * 1e9;
    let runs = args.u64_or("runs", 32);
    let mode = args.str_or("mode", "flash");

    let avg = flashrecovery::cluster::scenario::average(runs, args.u64_or("seed", 1), |s| {
        let cfg = ScenarioConfig::paper(devices, params, s);
        if mode == "vanilla" {
            simulate_vanilla(&cfg)
        } else {
            simulate_flash(&cfg)
        }
    });
    println!(
        "[simulate] {mode} @ {devices} devices, {:.0}B params ({runs} runs):",
        params / 1e9
    );
    println!("  detection   {:>9.2} s", avg.detection_s);
    println!("  restart     {:>9.2} s", avg.restart_s);
    println!("  redone      {:>9.2} s (step = {:.2} s)", avg.redone_s, avg.step_time_s);
    println!("  total       {:>9.2} s", avg.total_s);
    for (name, v) in &avg.stages {
        println!("    stage {name:<28} {v:>9.3} s");
    }
    Ok(())
}

/// `scenario list | run | export` — the chaos campaign CLI.
fn scenario(args: &Args) -> anyhow::Result<()> {
    use flashrecovery::chaos::{self, library};

    let devices = args.usize_or("devices", 256);
    match args.positional.get(1).map(String::as_str) {
        Some("list") | None => {
            println!("built-in chaos scenarios (--devices {devices}):");
            for spec in library::all(devices) {
                println!(
                    "  {:<24} {} fault(s), mode={}  — {}",
                    spec.name,
                    spec.faults.len(),
                    spec.mode.name(),
                    spec.description
                );
            }
            println!("\nrun one:  flashrecovery scenario run --spec <name> --seed N");
            Ok(())
        }
        Some("export") => {
            let name = args
                .get("spec")
                .ok_or_else(|| anyhow::anyhow!("export needs --spec <name>"))?;
            let spec = library::by_name(name, devices)
                .ok_or_else(|| anyhow::anyhow!("unknown built-in scenario {name:?}"))?;
            println!("{}", spec.to_json().render_pretty());
            Ok(())
        }
        Some("run") => {
            let sel = args
                .get("spec")
                .ok_or_else(|| anyhow::anyhow!("run needs --spec <name|file.json>"))?;
            let spec = match library::by_name(sel, devices) {
                Some(s) => s,
                None => chaos::ScenarioSpec::load(sel)?,
            };
            let seed = args.u64_or("seed", 1);

            if args.bool_or("live", false) {
                let out = chaos::run_live(&spec, seed)?;
                println!(
                    "[scenario:{}] live run: {} steps, {} recoveries, wall {:.1}s",
                    spec.name,
                    out.report.final_step,
                    out.report.recoveries.len(),
                    out.report.wall_s
                );
                for r in &out.report.recoveries {
                    println!(
                        "  recovery ranks {:?} at step {} -> resume {} \
                         (lost {}), detect {:.3}s restart {:.3}s",
                        r.failed_ranks, r.failed_at_step, r.resume_step,
                        r.lost_steps, r.detection_s, r.restart_s
                    );
                }
                return finish(&spec.name, &out.assertions);
            }

            let (report, journal) = chaos::run_campaign(&spec, seed)?;
            if let Some(path) = args.get("journal") {
                std::fs::write(path, journal.render())?;
                println!("[scenario:{}] journal ({} events) -> {path}", spec.name, journal.len());
            }
            println!(
                "[scenario:{}] seed {seed}, mode {}, {} nodes + {} spares @ {} devices",
                spec.name,
                report.mode.name(),
                spec.cluster.active_nodes(),
                spec.cluster.spare_nodes,
                spec.cluster.devices
            );
            for (i, r) in report.recoveries.iter().enumerate() {
                println!(
                    "  recovery {i}: nodes {:?} at t={:.1}s  detect {:.1}s  \
                     restart {:.1}s  total {:.1}s  merged {}  lost {}",
                    r.nodes, r.started_s, r.detection_s, r.restart_s,
                    r.total_s(), r.merged_faults, r.lost_steps
                );
            }
            println!(
                "  campaign: {} steps done, {} lost, downtime {:.1}s, \
                 {} running / {} spare / {} unrecovered, journal digest {:016x}",
                report.steps_completed,
                report.lost_steps,
                report.total_downtime_s,
                report.final_running_nodes,
                report.spares_left,
                report.unrecovered_nodes,
                journal.digest()
            );
            let outcomes = chaos::evaluate(&spec.assertions, &report);
            finish(&spec.name, &outcomes)
        }
        Some(other) => {
            anyhow::bail!("unknown scenario subcommand {other:?} (list|run|export)")
        }
    }
}

fn finish(name: &str, outcomes: &[flashrecovery::chaos::AssertionOutcome]) -> anyhow::Result<()> {
    for o in outcomes {
        println!(
            "  assert {:<28} {}  ({})",
            o.name,
            if o.pass { "PASS" } else { "FAIL" },
            o.detail
        );
    }
    if flashrecovery::chaos::passed(outcomes) {
        println!("[scenario:{name}] PASS");
        Ok(())
    } else {
        println!("[scenario:{name}] FAIL");
        std::process::exit(1);
    }
}

/// Shared `--baseline FILE [--gate RATIO]` handling for the bench
/// suites: compares column 0 (p50) of `report` against the committed
/// baseline and exits non-zero on any regression beyond the gate
/// ratio. No-op when `--baseline` is absent.
fn gate_against_baseline(
    prefix: &str,
    report: &flashrecovery::metrics::bench::BenchReport,
    flags: &BenchFlags,
) -> anyhow::Result<()> {
    use flashrecovery::util::Json;

    let Some(baseline_path) = flags.baseline.as_deref() else {
        return Ok(());
    };
    let max_ratio = flags.gate;
    let text = std::fs::read_to_string(baseline_path)?;
    let baseline =
        Json::parse(&text).map_err(|e| anyhow::anyhow!("{baseline_path}: {e}"))?;
    let violations = report.gate(&baseline, 0, max_ratio);
    if violations.is_empty() {
        println!("[{prefix}] gate PASS (p50 within {max_ratio}x of {baseline_path})");
    } else {
        for v in &violations {
            eprintln!("[{prefix}] gate FAIL: {v}");
        }
        eprintln!(
            "[{prefix}] if this is an accepted change, refresh the \
             baseline: cp {} {baseline_path} (see README)",
            flags.out
        );
        std::process::exit(1);
    }
    Ok(())
}

/// `bench rebuild` — the group-reconstruction scale sweep, with an
/// optional perf gate against a committed baseline JSON (CI's
/// bench-gate job fails the build on p50 regressions > --gate).
fn rebuild_bench(args: &Args) -> anyhow::Result<()> {
    use flashrecovery::coordinator::rendezvous::{rebuild_sweep, SweepConfig};

    let mut cfg = SweepConfig::default();
    if let Some(scales) = args.usize_list("scales")? {
        cfg.scales = scales;
    }
    cfg.samples = args.u64_or("samples", cfg.samples as u64) as u32;
    cfg.failures = args.usize_or("failures", cfg.failures);
    cfg.live_survivors = args.usize_or("live-survivors", cfg.live_survivors);

    let flags = args.bench_flags("BENCH_group_rebuild.json");
    let report = rebuild_sweep(&cfg)?;
    report.print();
    report.write_json(&flags.out)?;
    println!("[bench rebuild] wrote {}", flags.out);
    gate_against_baseline("bench rebuild", &report, &flags)
}

/// `bench restore` — the shard-aware streaming-restore sweep, with an
/// optional perf gate against a committed baseline JSON (CI's
/// bench-gate job fails the build on p50 regressions > --gate).
fn restore_bench(args: &Args) -> anyhow::Result<()> {
    use flashrecovery::coordinator::restore::{restore_sweep, RestoreSweepConfig};

    let mut cfg = RestoreSweepConfig::default();
    if let Some(sizes) = args.usize_list("sizes")? {
        cfg.sizes = sizes;
    }
    if let Some(shards) = args.usize_list("shards")? {
        cfg.shards = shards;
    }
    cfg.samples = args.u64_or("samples", cfg.samples as u64) as u32;
    cfg.chunk_bytes =
        args.usize_or("chunk-kib", cfg.chunk_bytes / 1024).max(4) * 1024;

    let flags = args.bench_flags("BENCH_state_restore.json");
    let report = restore_sweep(&cfg)?;
    report.print();
    report.write_json(&flags.out)?;
    println!("[bench restore] wrote {}", flags.out);
    gate_against_baseline("bench restore", &report, &flags)
}

/// `bench detect` — the detection-latency scale sweep over leased
/// heartbeats (DESIGN.md §10), with an optional perf gate against a
/// committed baseline JSON (CI's bench-gate job fails the build on
/// p50 regressions > --gate).
fn detect_bench(args: &Args) -> anyhow::Result<()> {
    use flashrecovery::coordinator::{detection_sweep, DetectionSweepConfig};
    use std::time::Duration;

    let mut cfg = DetectionSweepConfig::default();
    if let Some(scales) = args.usize_list("scales")? {
        cfg.scales = scales;
    }
    cfg.samples = args.u64_or("samples", cfg.samples as u64) as u32;
    cfg.live_agents = args.usize_or("live-agents", cfg.live_agents);
    cfg.interval = Duration::from_millis(
        args.u64_or("interval-ms", cfg.interval.as_millis() as u64).max(1),
    );
    cfg.lease_misses =
        args.u64_or("lease-misses", cfg.lease_misses as u64).max(1) as u32;
    cfg.node_agent = args.bool_or("node-agent", cfg.node_agent);

    let flags = args.bench_flags("BENCH_detection_latency.json");
    let report = detection_sweep(&cfg)?;
    report.print();
    report.write_json(&flags.out)?;
    println!("[bench detect] wrote {}", flags.out);
    gate_against_baseline("bench detect", &report, &flags)
}

/// `bench store` — the store data-plane throughput sweep (DESIGN.md
/// §14): mixed-opcode workload on the event-loop reactor core vs the
/// worker pool, batched vs serial client modes plus a
/// quorum-replicated column (DESIGN.md §13) and peak-serving-thread /
/// RSS columns, with an optional perf gate against a committed
/// baseline JSON (CI's bench-gate job fails the build on batched
/// per-op p50 regressions > --gate).
fn store_bench(args: &Args) -> anyhow::Result<()> {
    use flashrecovery::comms::store_bench::{check_report, store_sweep, StoreSweepConfig};

    let mut cfg = StoreSweepConfig::default();
    if let Some(clients) = args.usize_list("clients")? {
        cfg.clients = clients;
    }
    cfg.connections = args.usize_or("connections", cfg.connections).max(1);
    cfg.repeats = args.usize_or("repeats", cfg.repeats).max(1);
    cfg.rounds = args.u64_or("rounds", cfg.rounds as u64).max(1) as u32;
    cfg.replicas = args.usize_or("replicas", cfg.replicas);

    let flags = args.bench_flags("BENCH_store_throughput.json");
    let report = store_sweep(&cfg)?;
    report.print();
    report.write_json(&flags.out)?;
    println!("[bench store] wrote {}", flags.out);
    if args.bool_or("assert", false) {
        // the acceptance properties (batched >= 2x serial at 4096
        // clients, per-op p50 at the top scale <= 1.5x the 4096
        // anchor, reactor peak serving threads <= 8 with bounded RSS,
        // replicated acks within 1.5x of the un-replicated batched
        // path) — what bench-gate enforces on top of the baseline
        // ratio
        check_report(&cfg, &report)?;
        println!("[bench store] acceptance assertions PASS");
    }
    gate_against_baseline("bench store", &report, &flags)
}

/// `bench redundancy` — the redundancy tier's cost/benefit sweep
/// (DESIGN.md §16): steady-state stripe shipping (worst-case dirty and
/// delta fast path) against stripe reconstruction, a replica-sourced
/// stream, and the file-checkpoint fallback, with an optional perf
/// gate against a committed baseline JSON (CI's bench-gate job fails
/// the build on ship-p50 regressions > --gate).
fn redundancy_bench(args: &Args) -> anyhow::Result<()> {
    use flashrecovery::redundancy::bench::{
        check_report, redundancy_sweep, RedundancySweepConfig,
    };

    let mut cfg = RedundancySweepConfig::default();
    if let Some(sizes) = args.usize_list("sizes")? {
        cfg.sizes = sizes;
    }
    cfg.samples = args.u64_or("samples", u64::from(cfg.samples)) as u32;
    cfg.k = args.usize_or("k", cfg.k).max(1);
    cfg.m = args.usize_or("m", cfg.m).max(1);
    cfg.chunk_bytes =
        args.usize_or("chunk-kib", cfg.chunk_bytes / 1024).max(4) * 1024;

    let flags = args.bench_flags("BENCH_redundancy.json");
    let report = redundancy_sweep(&cfg)?;
    report.print();
    report.write_json(&flags.out)?;
    println!("[bench redundancy] wrote {}", flags.out);
    if args.bool_or("assert", false) {
        // the acceptance properties (delta reship undercuts a full
        // ship; stripe rebuild stays within 20x of a replica-sourced
        // stream) — what bench-gate enforces on top of the baseline
        // ratio
        check_report(&cfg, &report)?;
        println!("[bench redundancy] acceptance assertions PASS");
    }
    gate_against_baseline("bench redundancy", &report, &flags)
}

/// `trace <scenario>` — run a live chaos scenario with the flight
/// recorder on and export the episode(s) as a Chrome trace-event JSON
/// (open in Perfetto / chrome://tracing). `--journal FILE` also dumps
/// the compact JSONL journal; `--check` self-validates the document
/// schema and reconciles the rebuild/restore span durations against
/// the episode outcome (±1ms), exiting non-zero on any violation —
/// CI's telemetry smoke step runs exactly this.
fn trace_cmd(args: &Args) -> anyhow::Result<()> {
    use flashrecovery::chaos::{self, library};
    use flashrecovery::telemetry::{global, trace};
    use flashrecovery::util::Json;

    let devices = args.usize_or("devices", 256);
    let sel = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("trace needs a scenario: <name|file.json>"))?;
    let spec = match library::by_name(sel, devices) {
        Some(s) => s,
        None => chaos::ScenarioSpec::load(sel)?,
    };

    trace::set_recording(true);
    let outcomes = chaos::drive_live_detection(&spec)?;
    trace::set_recording(false);

    let mut events: Vec<Json> = Vec::new();
    for out in &outcomes {
        println!(
            "[trace:{}] episode step {}: epoch {}, detect {:.3}s, rebuild {:.3}s, \
             restore {:.3}s, total {:.3}s, trace_id {:016x}",
            spec.name, out.step, out.epoch, out.detection_s, out.rebuild_s,
            out.restore_s, out.total_s, out.trace_id
        );
        let doc = trace::chrome_trace(out.trace_id);
        if let Some(evs) = doc.get("traceEvents").as_array() {
            events.extend(evs.iter().cloned());
        }
    }
    // Episodes run sequentially on one monotonic clock, so their
    // concatenated events keep the ts order validate_chrome_trace
    // demands.
    let mut doc = Json::object();
    doc.set("displayTimeUnit", "ms").set("traceEvents", Json::Array(events));

    let out_path = args.str_or("out", "trace.json");
    std::fs::write(&out_path, doc.render())?;
    println!("[trace:{}] chrome trace -> {out_path} (open in ui.perfetto.dev)", spec.name);
    if let Some(path) = args.get("journal") {
        std::fs::write(path, trace::journal(0))?;
        println!("[trace:{}] jsonl journal -> {path}", spec.name);
    }

    let snap = global().snapshot();
    println!(
        "[trace:{}] registry: {} episodes recovered",
        spec.name,
        snap.counter("episode.recovered")
    );

    if args.bool_or("check", false) {
        trace::validate_chrome_trace(&doc)
            .map_err(|e| anyhow::anyhow!("trace schema violation: {e}"))?;
        for out in &outcomes {
            check_episode_trace(out)?;
        }
        println!("[trace:{}] check PASS ({} episode(s))", spec.name, outcomes.len());
    }
    Ok(())
}

/// One episode's span tree must carry detection/rebuild/restore under
/// the episode root, with rebuild/restore wall intervals reconciling
/// ±1ms against the outcome's measured phase durations.
fn check_episode_trace(out: &flashrecovery::chaos::LiveDetectionOutcome) -> anyhow::Result<()> {
    use flashrecovery::telemetry::trace;

    let spans = trace::spans_for(out.trace_id);
    let root = spans
        .iter()
        .find(|s| s.name == "episode" && s.parent == 0)
        .ok_or_else(|| anyhow::anyhow!("episode {}: no root span", out.step))?;
    for name in ["detection", "rebuild", "restore"] {
        if !spans.iter().any(|s| s.name == name && s.parent == root.span_id) {
            anyhow::bail!("episode {}: no {name} span under the root", out.step);
        }
    }
    // detection_s is a measured heartbeat->detection latency, not the
    // phase's wall interval, so only rebuild/restore reconcile.
    for (name, wall) in [("rebuild", out.rebuild_s), ("restore", out.restore_s)] {
        let s = spans
            .iter()
            .find(|s| s.name == name && s.parent == root.span_id)
            .expect("presence checked above");
        let dur = s.duration_s();
        if (dur - wall).abs() > 1e-3 {
            anyhow::bail!(
                "episode {}: {name} span {dur:.4}s vs outcome {wall:.4}s (> 1ms apart)",
                out.step
            );
        }
    }
    Ok(())
}

/// `netem <scenario|all>` — run impaired-plane chaos scenarios
/// (DESIGN.md §15): specs with a `netem:` section driven over real
/// degraded sockets. `--check` fails the process on any outcome
/// violation (CI's impaired smoke step runs exactly this);
/// `--calibrate` re-derives the §6 simulator latency model from the
/// measured wire numbers and prints both (the measured constants
/// replace `tcp_store_per_link_s` and re-center the detection notice
/// band via `LatencyModel::with_wire`).
fn netem(args: &Args) -> anyhow::Result<()> {
    use flashrecovery::chaos::{self, library};
    use flashrecovery::cluster::{LatencyModel, WireMeasurements};

    let devices = args.usize_or("devices", 256);
    let sel = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("netem needs a scenario: <name|file.json|all>"))?;
    let check = args.bool_or("check", false);

    let names: Vec<&str> = if sel == "all" {
        vec!["detection_under_loss", "restore_over_wan", "partition_heal_rendezvous"]
    } else {
        vec![sel.as_str()]
    };

    // Wire numbers this run measures; NaN = not measured, so
    // `with_wire` keeps the corresponding default.
    let mut wire = WireMeasurements {
        tcp_store_per_link_s: f64::NAN,
        detect_notice_s: f64::NAN,
    };
    for name in names {
        let spec = match library::by_name(name, devices) {
            Some(s) => s,
            None => chaos::ScenarioSpec::load(name)?,
        };
        run_netem_scenario(&spec, args, check, &mut wire)?;
    }

    if args.bool_or("calibrate", false) {
        let default = LatencyModel::default();
        let model = LatencyModel::with_wire(wire);
        println!("[netem:calibrate] §6 latency model from measured wire numbers:");
        println!(
            "  tcp_store_per_link_s {:.6}s (simulator default {:.6}s)",
            model.tcp_store_per_link_s, default.tcp_store_per_link_s
        );
        println!(
            "  detect_notice {:.3}..{:.3}s (simulator default {:.3}..{:.3}s)",
            model.detect_notice_min_s,
            model.detect_notice_max_s,
            default.detect_notice_min_s,
            default.detect_notice_max_s
        );
    }
    Ok(())
}

/// Run one impaired scenario with the driver its shape (or `--driver`)
/// selects, printing the outcome and folding measured wire numbers
/// into `wire`. With `check`, exits non-zero on outcome violations.
fn run_netem_scenario(
    spec: &flashrecovery::chaos::ScenarioSpec,
    args: &Args,
    check: bool,
    wire: &mut flashrecovery::cluster::WireMeasurements,
) -> anyhow::Result<()> {
    use flashrecovery::chaos;

    let driver = match args.get("driver") {
        Some(d) => d.to_string(),
        None => match spec.name.as_str() {
            "detection_under_loss" => "detection".into(),
            "restore_over_wan" => "restore".into(),
            "partition_heal_rendezvous" => "heal".into(),
            _ => anyhow::bail!(
                "no default driver for scenario {:?}: pass --driver \
                 detection|restore|heal",
                spec.name
            ),
        },
    };
    match driver.as_str() {
        "detection" => {
            let episodes = chaos::drive_netem_detection(spec)?;
            for out in &episodes {
                println!(
                    "[netem:{}] step {}: detect {:.3}s over the impaired \
                     plane (lease budget {:.3}s), rebuild {:.3}s -> epoch {}, \
                     {} detection(s), {} false eviction(s)",
                    spec.name, out.step, out.detection_s, out.lease_budget_s,
                    out.rebuild_s, out.epoch, out.detections.len(),
                    out.false_evictions.len()
                );
            }
            let last = episodes
                .last()
                .ok_or_else(|| anyhow::anyhow!("no impaired episode ran"))?;
            wire.detect_notice_s = last.detection_s;
            if check {
                anyhow::ensure!(
                    last.false_evictions.is_empty(),
                    "impaired beats evicted live ranks {:?}",
                    last.false_evictions
                );
                for out in &episodes {
                    anyhow::ensure!(
                        !out.detections.is_empty(),
                        "victim never detected at step {}",
                        out.step
                    );
                }
            }
        }
        "restore" => {
            let out = chaos::drive_netem_restore(spec)?;
            println!(
                "[netem:{}] store op {:.4}s over a {:.3}s-RTT link, rebuild \
                 {:.3}s -> epoch {}, fetched {} bytes in {:.3}s, bit_exact={}",
                spec.name, out.store_op_s, out.rtt_s, out.rebuild_s, out.epoch,
                out.bytes, out.fetch_wall_s, out.bit_exact
            );
            wire.tcp_store_per_link_s = out.store_op_s;
            if check {
                anyhow::ensure!(out.bit_exact, "restored snapshot diverged");
                anyhow::ensure!(out.bytes > 0, "nothing was streamed");
            }
        }
        "heal" => {
            let out = chaos::drive_netem_partition_heal(spec)?;
            println!(
                "[netem:{}] ranks {:?} partitioned for {:.2}s; all {} rank(s) \
                 joined {:.3}s after the partition began",
                spec.name, out.healed_ranks, out.heal_after_s,
                out.wakes.len(), out.join_wall_s
            );
            if check {
                anyhow::ensure!(!out.wakes.is_empty(), "no rank woke from the barrier");
                anyhow::ensure!(
                    out.join_wall_s >= out.heal_after_s * 0.95,
                    "ranks joined before the partition healed"
                );
            }
        }
        other => anyhow::bail!("unknown netem driver {other:?} (detection|restore|heal)"),
    }
    if check {
        println!("[netem:{}] check PASS", spec.name);
    }
    Ok(())
}

fn info(args: &Args) -> anyhow::Result<()> {
    let dir = artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("artifacts/ not found — run `make artifacts`"))?;
    let size = args.str_or("size", "tiny");
    let m = load_manifest(&dir, &size)?;
    println!("model '{size}' from {dir:?}:");
    println!(
        "  layers={} d_model={} heads={} d_ff={} vocab={} seq={} batch={}",
        m.dims.n_layers, m.dims.d_model, m.dims.n_heads, m.dims.d_ff,
        m.dims.vocab, m.dims.seq, m.dims.batch
    );
    println!(
        "  params: {} tensors, {:.2}M elements, state {:.1} MB",
        m.params.len(),
        m.total_elements() as f64 / 1e6,
        m.state_bytes() as f64 / 1e6
    );
    println!(
        "  optimizer: adam lr={} b1={} b2={} clip={}",
        m.optimizer.lr, m.optimizer.beta1, m.optimizer.beta2, m.optimizer.grad_clip
    );
    for (name, path) in &m.artifacts {
        println!("  artifact {name:<11} {path:?}");
    }
    Ok(())
}
