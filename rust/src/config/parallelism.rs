//! Parallelism topology: DP x TP x PP (+ ZeRO/FSDP sharding) and the
//! replica-location math behind checkpoint-free recovery (paper Fig. 3
//! and Fig. 6).
//!
//! Devices with the *same model-state shard* are replicas of each other;
//! a failed device is recoverable iff at least one replica survives.
//! ZeRO is modelled with a sharding degree `zero_shards` inside each DP
//! group (hybrid/HSDP generalisation): `zero_shards = 1` is vanilla DP
//! (full state replicated dp ways), `zero_shards = dp` is pure FSDP
//! (no replica — recovery must fall back to a checkpoint, the paper's
//! §III-G limitation 1).

use crate::util::Json;
use anyhow::{bail, Result};

/// Logical coordinates of a device in the topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeviceCoord {
    pub dp: usize,
    pub pp: usize,
    pub tp: usize,
}

/// The unit of model state a device holds. Devices sharing a `ShardId`
/// hold byte-identical model states (the same-coloured frames in the
/// paper's Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShardId {
    pub pp: usize,
    pub tp: usize,
    /// Position inside the ZeRO partition group (0 when zero_shards=1).
    pub zero: usize,
}

/// ZeRO/FSDP sharding mode, expressed as the partition-group size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ZeroMode {
    /// Vanilla data parallelism: model states fully replicated.
    None,
    /// States sharded `shards` ways within each DP group (1 < shards <=
    /// dp); replicas exist iff dp / shards > 1.
    Sharded { shards: usize },
}

impl ZeroMode {
    pub fn shards(&self) -> usize {
        match self {
            ZeroMode::None => 1,
            ZeroMode::Sharded { shards } => *shards,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ParallelismConfig {
    pub dp: usize,
    pub pp: usize,
    pub tp: usize,
    pub zero: ZeroMode,
}

impl ParallelismConfig {
    /// Pure data parallelism of degree `dp`.
    pub fn dp(dp: usize) -> Self {
        ParallelismConfig { dp, pp: 1, tp: 1, zero: ZeroMode::None }
    }

    pub fn new(dp: usize, pp: usize, tp: usize) -> Self {
        ParallelismConfig { dp, pp, tp, zero: ZeroMode::None }
    }

    pub fn with_zero(mut self, shards: usize) -> Self {
        self.zero = if shards <= 1 {
            ZeroMode::None
        } else {
            ZeroMode::Sharded { shards }
        };
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.dp == 0 || self.pp == 0 || self.tp == 0 {
            bail!("parallelism degrees must be >= 1");
        }
        let shards = self.zero.shards();
        if shards == 0 || self.dp % shards != 0 {
            bail!(
                "zero_shards={} must divide dp={}",
                shards,
                self.dp
            );
        }
        Ok(())
    }

    pub fn world_size(&self) -> usize {
        self.dp * self.pp * self.tp
    }

    /// Number of distinct replicas each model-state shard has.
    pub fn replication_factor(&self) -> usize {
        self.dp / self.zero.shards()
    }

    /// global rank -> coordinates. Layout: dp-major, then pp, then tp
    /// (tp neighbours are adjacent ranks, the usual Megatron layout).
    pub fn coord(&self, global: usize) -> DeviceCoord {
        debug_assert!(global < self.world_size());
        let tp = global % self.tp;
        let pp = (global / self.tp) % self.pp;
        let dp = global / (self.tp * self.pp);
        DeviceCoord { dp, pp, tp }
    }

    pub fn global(&self, c: DeviceCoord) -> usize {
        debug_assert!(c.dp < self.dp && c.pp < self.pp && c.tp < self.tp);
        c.dp * self.pp * self.tp + c.pp * self.tp + c.tp
    }

    /// The model-state shard a device holds (Fig. 3's frame id).
    pub fn shard_id(&self, global: usize) -> ShardId {
        let c = self.coord(global);
        ShardId { pp: c.pp, tp: c.tp, zero: c.dp % self.zero.shards() }
    }

    /// All devices holding a replica of `global`'s model state,
    /// *excluding* `global` itself.
    pub fn replicas_of(&self, global: usize) -> Vec<usize> {
        let c = self.coord(global);
        let shards = self.zero.shards();
        (0..self.dp)
            .filter(|&d| d != c.dp && d % shards == c.dp % shards)
            .map(|d| self.global(DeviceCoord { dp: d, ..c }))
            .collect()
    }

    /// Members of the DP process group containing `global` (all dp
    /// indices at the same (pp, tp)) — the gradient-allreduce group.
    pub fn dp_group(&self, global: usize) -> Vec<usize> {
        let c = self.coord(global);
        (0..self.dp)
            .map(|d| self.global(DeviceCoord { dp: d, ..c }))
            .collect()
    }

    /// For each failed device, a surviving replica to restore from
    /// (`None` if every replica also failed — checkpoint fallback).
    pub fn recovery_sources(&self, failed: &[usize]) -> Vec<(usize, Option<usize>)> {
        failed
            .iter()
            .map(|&f| {
                let src = self
                    .replicas_of(f)
                    .into_iter()
                    .find(|r| !failed.contains(r));
                (f, src)
            })
            .collect()
    }

    /// True iff the whole failure set is recoverable from replicas.
    pub fn can_recover(&self, failed: &[usize]) -> bool {
        self.recovery_sources(failed).iter().all(|(_, s)| s.is_some())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("dp", self.dp)
            .set("pp", self.pp)
            .set("tp", self.tp)
            .set("zero_shards", self.zero.shards());
        o
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let dp = v.get("dp").as_usize().unwrap_or(1);
        let pp = v.get("pp").as_usize().unwrap_or(1);
        let tp = v.get("tp").as_usize().unwrap_or(1);
        let shards = v.get("zero_shards").as_usize().unwrap_or(1);
        let cfg = ParallelismConfig::new(dp, pp, tp).with_zero(shards);
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn coord_roundtrip() {
        let p = ParallelismConfig::new(4, 3, 2);
        for g in 0..p.world_size() {
            assert_eq!(p.global(p.coord(g)), g);
        }
    }

    #[test]
    fn tp_neighbours_are_adjacent() {
        let p = ParallelismConfig::new(2, 2, 4);
        let c0 = p.coord(0);
        let c1 = p.coord(1);
        assert_eq!((c0.dp, c0.pp), (c1.dp, c1.pp));
        assert_eq!(c1.tp, c0.tp + 1);
    }

    #[test]
    fn vanilla_dp_replicas() {
        let p = ParallelismConfig::new(4, 2, 2);
        let reps = p.replicas_of(0);
        assert_eq!(reps.len(), 3); // dp=4 -> 3 replicas
        for r in &reps {
            assert_eq!(p.shard_id(*r), p.shard_id(0));
        }
        assert_eq!(p.replication_factor(), 4);
    }

    #[test]
    fn zero_sharding_reduces_replicas() {
        let p = ParallelismConfig::dp(8).with_zero(4);
        // dp=8 sharded 4 ways -> each shard has 2 copies -> 1 replica.
        assert_eq!(p.replication_factor(), 2);
        assert_eq!(p.replicas_of(0).len(), 1);
        // replica of dp-rank 0 is dp-rank 4 (same zero offset).
        assert_eq!(p.replicas_of(0), vec![4]);
    }

    #[test]
    fn pure_fsdp_has_no_replicas() {
        let p = ParallelismConfig::dp(4).with_zero(4);
        assert_eq!(p.replication_factor(), 1);
        assert!(p.replicas_of(2).is_empty());
        assert!(!p.can_recover(&[2]));
    }

    #[test]
    fn single_failure_recoverable_with_dp() {
        let p = ParallelismConfig::new(2, 2, 1);
        for g in 0..p.world_size() {
            assert!(p.can_recover(&[g]), "device {g}");
        }
    }

    #[test]
    fn whole_dp_group_loss_unrecoverable() {
        let p = ParallelismConfig::new(2, 1, 1);
        assert!(p.can_recover(&[0]));
        assert!(!p.can_recover(&[0, 1]));
    }

    #[test]
    fn recovery_source_prefers_survivor() {
        let p = ParallelismConfig::dp(4);
        let src = p.recovery_sources(&[1, 2]);
        assert_eq!(src.len(), 2);
        for (f, s) in src {
            let s = s.unwrap();
            assert!(![1usize, 2].contains(&s), "failed {f} got failed src {s}");
            assert_eq!(p.shard_id(s), p.shard_id(f));
        }
    }

    #[test]
    fn dp_group_spans_dp_axis() {
        let p = ParallelismConfig::new(3, 2, 2);
        let g = p.dp_group(5);
        assert_eq!(g.len(), 3);
        let c = p.coord(5);
        for m in g {
            let mc = p.coord(m);
            assert_eq!((mc.pp, mc.tp), (c.pp, c.tp));
        }
    }

    #[test]
    fn validate_rejects_bad_shards() {
        assert!(ParallelismConfig::dp(4).with_zero(3).validate().is_err());
        assert!(ParallelismConfig::dp(4).with_zero(2).validate().is_ok());
    }

    // ---------------------------------------------------- property tests

    #[test]
    fn prop_replicas_share_shard_id_and_are_symmetric() {
        prop::check("replica symmetry", 200, |rng| {
            let dp = 1 + rng.below(6) as usize;
            let pp = 1 + rng.below(3) as usize;
            let tp = 1 + rng.below(3) as usize;
            let divisors: Vec<usize> = (1..=dp).filter(|s| dp % s == 0).collect();
            let shards = *rng.choose(&divisors);
            let p = ParallelismConfig::new(dp, pp, tp).with_zero(shards);
            p.validate().map_err(|e| e.to_string())?;
            let g = rng.below(p.world_size() as u64) as usize;
            for r in p.replicas_of(g) {
                prop::assert_eq_prop(&p.shard_id(r), &p.shard_id(g))?;
                prop::assert_prop(
                    p.replicas_of(r).contains(&g),
                    format!("replica relation not symmetric: {g} vs {r}"),
                )?;
            }
            // replica count == replication_factor - 1 everywhere
            prop::assert_eq_prop(
                &p.replicas_of(g).len(),
                &(p.replication_factor() - 1),
            )
        });
    }

    #[test]
    fn prop_recoverable_iff_not_all_replicas_failed() {
        prop::check("recoverability criterion", 200, |rng| {
            let dp = 1 + rng.below(5) as usize;
            let p = ParallelismConfig::new(dp, 1 + rng.below(2) as usize, 1);
            let world = p.world_size();
            let mut failed: Vec<usize> = (0..world)
                .filter(|_| rng.bool(0.3))
                .collect();
            if failed.is_empty() {
                failed.push(rng.below(world as u64) as usize);
            }
            let expected = failed.iter().all(|&f| {
                let mut group = p.dp_group(f);
                group.retain(|m| !failed.contains(m));
                !group.is_empty()
            });
            prop::assert_eq_prop(&p.can_recover(&failed), &expected)
        });
    }

    #[test]
    fn prop_shard_count_matches_world_partition() {
        prop::check("shard partition", 100, |rng| {
            let dp = 1 + rng.below(6) as usize;
            let divisors: Vec<usize> = (1..=dp).filter(|s| dp % s == 0).collect();
            let shards = *rng.choose(&divisors);
            let p = ParallelismConfig::new(dp, 1 + rng.below(3) as usize, 1 + rng.below(3) as usize)
                .with_zero(shards);
            let mut ids: Vec<ShardId> =
                (0..p.world_size()).map(|g| p.shard_id(g)).collect();
            ids.sort();
            ids.dedup();
            prop::assert_eq_prop(&ids.len(), &(p.pp * p.tp * shards))
        });
    }
}
