//! Typed configuration system (JSON-backed, no serde offline).
//!
//! Every experiment is described by a [`JobConfig`]: the model size, the
//! parallelism topology, the cluster layout, the failure model, and the
//! recovery policy (vanilla periodic-checkpointing vs FlashRecovery).
//! Configs load from JSON files and render back losslessly, so example
//! binaries and benches can snapshot the exact configuration they ran.

pub mod parallelism;
pub mod timeouts;

pub use parallelism::{DeviceCoord, ParallelismConfig, ShardId, ZeroMode};
pub use timeouts::Timeouts;

use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Which recovery system a run uses — the paper's core comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryMode {
    /// Timeout detection + full restart + checkpoint reload (§II, Tab. II).
    Vanilla,
    /// FlashRecovery: heartbeat detection + selective restart +
    /// DP-replica restoration (§III, Tab. III).
    Flash,
}

impl RecoveryMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "vanilla" => Ok(RecoveryMode::Vanilla),
            "flash" => Ok(RecoveryMode::Flash),
            other => bail!("unknown recovery mode {other:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RecoveryMode::Vanilla => "vanilla",
            RecoveryMode::Flash => "flash",
        }
    }
}

/// How the ranktable is refreshed after a restart (§III-D, Tab. I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RanktableMode {
    /// Master collects from every node then redistributes — O(n).
    Original,
    /// Controller maintains a shared file every node loads — O(1).
    SharedFile,
}

/// Cluster layout + detection constants.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub num_nodes: usize,
    pub devices_per_node: usize,
    /// Healthy standby nodes available for substitution.
    pub spare_nodes: usize,
    /// Heartbeat period (seconds of sim-time or wall-time).
    pub heartbeat_interval_s: f64,
    /// Consecutive missed heartbeats before a node is declared failed.
    pub miss_threshold: u32,
    /// Vanilla baseline: collective-communication hang timeout
    /// (PyTorch default 1800 s in the paper).
    pub collective_timeout_s: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            num_nodes: 4,
            devices_per_node: 1,
            spare_nodes: 1,
            heartbeat_interval_s: 2.0,
            miss_threshold: 3,
            collective_timeout_s: 1800.0,
        }
    }
}

impl ClusterConfig {
    pub fn total_devices(&self) -> usize {
        self.num_nodes * self.devices_per_node
    }
}

/// Periodic-checkpointing policy (the baseline FlashRecovery removes).
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// Steps between checkpoints (`t` in §II); 0 disables.
    pub interval_steps: u64,
    /// Directory for persisted checkpoints.
    pub dir: String,
    /// Persist snapshots to disk asynchronously (k1 overlaps training).
    pub async_persist: bool,
    /// Keep at most this many persisted checkpoints.
    pub keep: usize,
}

impl Default for CheckpointPolicy {
    fn default() -> Self {
        CheckpointPolicy {
            interval_steps: 0,
            dir: "checkpoints".to_string(),
            async_persist: true,
            keep: 2,
        }
    }
}

/// Recovery system knobs.
#[derive(Debug, Clone)]
pub struct RecoveryPolicy {
    pub mode: RecoveryMode,
    pub ranktable: RanktableMode,
    /// Degree of parallelisation for TCP-Store establishment (`p` in
    /// §III-D; 1 = the serialized baseline).
    pub tcp_store_parallelism: usize,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            mode: RecoveryMode::Flash,
            ranktable: RanktableMode::SharedFile,
            tcp_store_parallelism: 64,
        }
    }
}

/// Failure model: arrival rate + the Fig. 9 taxonomy mix.
#[derive(Debug, Clone)]
pub struct FailureModel {
    /// Mean time between failures for the *whole cluster*, seconds.
    /// Paper-scale clusters see failures every few hours; tests inject
    /// deterministically instead.
    pub cluster_mtbf_s: f64,
    pub seed: u64,
}

impl Default for FailureModel {
    fn default() -> Self {
        FailureModel { cluster_mtbf_s: 3600.0 * 4.0, seed: 0 }
    }
}

/// Top-level job description.
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Model size key in artifacts/manifest.json ("tiny"/"small"/"base").
    pub model: String,
    pub parallelism: ParallelismConfig,
    pub cluster: ClusterConfig,
    pub checkpoint: CheckpointPolicy,
    pub recovery: RecoveryPolicy,
    pub failure: FailureModel,
    pub steps: u64,
    pub seed: u64,
    pub log_every: u64,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            model: "tiny".to_string(),
            parallelism: ParallelismConfig::dp(2),
            cluster: ClusterConfig::default(),
            checkpoint: CheckpointPolicy::default(),
            recovery: RecoveryPolicy::default(),
            failure: FailureModel::default(),
            steps: 50,
            seed: 0,
            log_every: 10,
        }
    }
}

impl JobConfig {
    pub fn validate(&self) -> Result<()> {
        if self.parallelism.world_size() > self.cluster.total_devices() {
            bail!(
                "parallelism world size {} exceeds cluster devices {}",
                self.parallelism.world_size(),
                self.cluster.total_devices()
            );
        }
        self.parallelism.validate()?;
        if self.recovery.tcp_store_parallelism == 0 {
            bail!("tcp_store_parallelism must be >= 1");
        }
        if self.cluster.heartbeat_interval_s <= 0.0 {
            bail!("heartbeat_interval_s must be positive");
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut cl = Json::object();
        cl.set("num_nodes", self.cluster.num_nodes)
            .set("devices_per_node", self.cluster.devices_per_node)
            .set("spare_nodes", self.cluster.spare_nodes)
            .set("heartbeat_interval_s", self.cluster.heartbeat_interval_s)
            .set("miss_threshold", self.cluster.miss_threshold as u64)
            .set("collective_timeout_s", self.cluster.collective_timeout_s);
        let mut ck = Json::object();
        ck.set("interval_steps", self.checkpoint.interval_steps)
            .set("dir", self.checkpoint.dir.as_str())
            .set("async_persist", self.checkpoint.async_persist)
            .set("keep", self.checkpoint.keep);
        let mut rc = Json::object();
        rc.set("mode", self.recovery.mode.name())
            .set(
                "ranktable",
                match self.recovery.ranktable {
                    RanktableMode::Original => "original",
                    RanktableMode::SharedFile => "shared_file",
                },
            )
            .set("tcp_store_parallelism", self.recovery.tcp_store_parallelism);
        let mut fm = Json::object();
        fm.set("cluster_mtbf_s", self.failure.cluster_mtbf_s)
            .set("seed", self.failure.seed);
        let mut o = Json::object();
        o.set("model", self.model.as_str())
            .set("parallelism", self.parallelism.to_json())
            .set("cluster", cl)
            .set("checkpoint", ck)
            .set("recovery", rc)
            .set("failure", fm)
            .set("steps", self.steps)
            .set("seed", self.seed)
            .set("log_every", self.log_every);
        o
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let d = JobConfig::default();
        let cl = v.get("cluster");
        let ck = v.get("checkpoint");
        let rc = v.get("recovery");
        let fm = v.get("failure");
        let cfg = JobConfig {
            model: v
                .get("model")
                .as_str()
                .unwrap_or(&d.model)
                .to_string(),
            parallelism: if v.get("parallelism").is_null() {
                d.parallelism.clone()
            } else {
                ParallelismConfig::from_json(v.get("parallelism"))?
            },
            cluster: ClusterConfig {
                num_nodes: cl.get("num_nodes").as_usize().unwrap_or(d.cluster.num_nodes),
                devices_per_node: cl
                    .get("devices_per_node")
                    .as_usize()
                    .unwrap_or(d.cluster.devices_per_node),
                spare_nodes: cl.get("spare_nodes").as_usize().unwrap_or(d.cluster.spare_nodes),
                heartbeat_interval_s: cl
                    .get("heartbeat_interval_s")
                    .as_f64()
                    .unwrap_or(d.cluster.heartbeat_interval_s),
                miss_threshold: cl
                    .get("miss_threshold")
                    .as_usize()
                    .unwrap_or(d.cluster.miss_threshold as usize)
                    as u32,
                collective_timeout_s: cl
                    .get("collective_timeout_s")
                    .as_f64()
                    .unwrap_or(d.cluster.collective_timeout_s),
            },
            checkpoint: CheckpointPolicy {
                interval_steps: ck
                    .get("interval_steps")
                    .as_i64()
                    .unwrap_or(d.checkpoint.interval_steps as i64) as u64,
                dir: ck
                    .get("dir")
                    .as_str()
                    .unwrap_or(&d.checkpoint.dir)
                    .to_string(),
                async_persist: ck
                    .get("async_persist")
                    .as_bool()
                    .unwrap_or(d.checkpoint.async_persist),
                keep: ck.get("keep").as_usize().unwrap_or(d.checkpoint.keep),
            },
            recovery: RecoveryPolicy {
                mode: RecoveryMode::parse(
                    rc.get("mode").as_str().unwrap_or("flash"),
                )?,
                ranktable: match rc.get("ranktable").as_str().unwrap_or("shared_file") {
                    "original" => RanktableMode::Original,
                    "shared_file" => RanktableMode::SharedFile,
                    other => bail!("unknown ranktable mode {other:?}"),
                },
                tcp_store_parallelism: rc
                    .get("tcp_store_parallelism")
                    .as_usize()
                    .unwrap_or(d.recovery.tcp_store_parallelism),
            },
            failure: FailureModel {
                cluster_mtbf_s: fm
                    .get("cluster_mtbf_s")
                    .as_f64()
                    .unwrap_or(d.failure.cluster_mtbf_s),
                seed: fm.get("seed").as_i64().unwrap_or(0) as u64,
            },
            steps: v.get("steps").as_i64().unwrap_or(d.steps as i64) as u64,
            seed: v.get("seed").as_i64().unwrap_or(0) as u64,
            log_every: v.get("log_every").as_i64().unwrap_or(d.log_every as i64) as u64,
        };
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        let v = Json::parse(&text).context("parsing job config")?;
        let cfg = Self::from_json(&v)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().render_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        JobConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = JobConfig::default();
        cfg.model = "small".into();
        cfg.steps = 123;
        cfg.recovery.mode = RecoveryMode::Vanilla;
        cfg.recovery.ranktable = RanktableMode::Original;
        cfg.checkpoint.interval_steps = 10;
        let back = JobConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.model, "small");
        assert_eq!(back.steps, 123);
        assert_eq!(back.recovery.mode, RecoveryMode::Vanilla);
        assert_eq!(back.recovery.ranktable, RanktableMode::Original);
        assert_eq!(back.checkpoint.interval_steps, 10);
    }

    #[test]
    fn world_size_must_fit_cluster() {
        let mut cfg = JobConfig::default();
        cfg.parallelism = ParallelismConfig::dp(64);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::temp_dir("cfg").unwrap();
        let path = dir.join("job.json");
        let cfg = JobConfig::default();
        cfg.save(&path).unwrap();
        let back = JobConfig::load(&path).unwrap();
        assert_eq!(back.model, cfg.model);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_unknown_mode() {
        let v = Json::parse(r#"{"recovery":{"mode":"bogus"}}"#).unwrap();
        assert!(JobConfig::from_json(&v).is_err());
    }
}
