//! One source of truth for the live plane's IO deadlines.
//!
//! Before the link layer (DESIGN.md §15) every protocol hard-coded
//! deadlines tuned for a perfect loopback — the rendezvous join
//! window, the state-stream IO-stall bound, heartbeat periods, probe
//! budgets. Over an impaired link (50 ms cross-region RTT, loss,
//! partitions) those constants either spuriously trip watchdogs or
//! mask real failures. [`Timeouts`] gathers them in one struct that
//! campaigns derive per-link with [`Timeouts::scaled_for_rtt`], and
//! the protocol configs (`EpisodeConfig`, `StreamConfig`, session
//! wait windows) are built *from* it instead of from literals.

use std::time::Duration;

/// The live plane's deadline set. Defaults reproduce the historical
/// loopback-tuned constants exactly, so a default-constructed config
/// behaves bit-for-bit like the pre-refactor plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Timeouts {
    /// Rendezvous: how long an episode waits for every participant to
    /// join before declaring the rebuild failed.
    pub join_deadline: Duration,
    /// State streams: IO inactivity bound on data-plane sockets — a
    /// frozen peer surfaces as a bounded failure within this window.
    pub io_stall: Duration,
    /// State streams: how long a source waits for its receivers to
    /// connect.
    pub accept_deadline: Duration,
    /// Worker heartbeat emission period.
    pub heartbeat_interval: Duration,
    /// Connect budget for endpoint discovery / replication probes.
    pub probe_connect: Duration,
    /// Read window for blocking fenced waits (`Wait`, `ClaimRestore`).
    pub wait_window: Duration,
    /// Plain store-client connect budget.
    pub connect: Duration,
}

impl Default for Timeouts {
    fn default() -> Self {
        Timeouts {
            join_deadline: Duration::from_secs(120),
            io_stall: Duration::from_secs(60),
            accept_deadline: Duration::from_secs(60),
            heartbeat_interval: Duration::from_millis(500),
            probe_connect: Duration::from_millis(250),
            wait_window: Duration::from_secs(300),
            connect: Duration::from_secs(10),
        }
    }
}

impl Timeouts {
    /// Widen every deadline for a link with the given round-trip time,
    /// so a slow-but-healthy path never spuriously trips a watchdog.
    /// Each deadline absorbs the worst-case number of round trips its
    /// protocol phase performs; the heartbeat period additionally
    /// never drops below one RTT (a beat must be able to land before
    /// the next is due).
    pub fn scaled_for_rtt(self, rtt: Duration) -> Timeouts {
        Timeouts {
            // a join is a handshake plus fenced waits: many ranks'
            // worth of round trips in the worst case
            join_deadline: self.join_deadline + rtt * 64,
            io_stall: self.io_stall + rtt * 16,
            accept_deadline: self.accept_deadline + rtt * 16,
            heartbeat_interval: self.heartbeat_interval.max(rtt),
            // a probe is SYN + hello: a couple of round trips
            probe_connect: self.probe_connect + rtt * 4,
            wait_window: self.wait_window + rtt * 16,
            connect: self.connect + rtt * 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_historical_loopback_constants() {
        let t = Timeouts::default();
        assert_eq!(t.join_deadline, Duration::from_secs(120));
        assert_eq!(t.io_stall, Duration::from_secs(60));
        assert_eq!(t.accept_deadline, Duration::from_secs(60));
        assert_eq!(t.probe_connect, Duration::from_millis(250));
        assert_eq!(t.wait_window, Duration::from_secs(300));
        assert_eq!(t.connect, Duration::from_secs(10));
    }

    #[test]
    fn rtt_scaling_widens_every_deadline_monotonically() {
        let base = Timeouts::default();
        let wan = base.scaled_for_rtt(Duration::from_millis(100));
        assert!(wan.join_deadline > base.join_deadline);
        assert!(wan.io_stall > base.io_stall);
        assert!(wan.accept_deadline > base.accept_deadline);
        assert!(wan.probe_connect > base.probe_connect);
        assert!(wan.wait_window > base.wait_window);
        assert!(wan.connect > base.connect);
        // a wider link than that widens further
        let worse = base.scaled_for_rtt(Duration::from_millis(500));
        assert!(worse.join_deadline > wan.join_deadline);
    }

    #[test]
    fn heartbeat_interval_never_undershoots_the_link() {
        let tight = Timeouts {
            heartbeat_interval: Duration::from_millis(15),
            ..Default::default()
        };
        let wan = tight.scaled_for_rtt(Duration::from_millis(100));
        assert_eq!(wan.heartbeat_interval, Duration::from_millis(100));
        let lan = tight.scaled_for_rtt(Duration::from_millis(1));
        assert_eq!(lan.heartbeat_interval, Duration::from_millis(15));
    }
}
