//! Leveled, env-filtered logger for coordinator/comms hot paths —
//! replaces raw `eprintln!` so nightly-soak artifacts capture messages
//! with timestamps and targets instead of interleaved stderr
//! (DESIGN.md §12).
//!
//! Filtering: `FLASH_LOG=off|error|warn|info|debug` (default `warn`).
//! Messages are lazy — the closure only runs when the level passes the
//! filter, so debug logging on the §11 data plane costs one relaxed
//! atomic load when disabled.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Severity, ordered `Error < Warn < Info < Debug`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        }
    }
}

/// Active threshold: 0 = off, otherwise a [`Level`] as u8.
/// 0xFF = not yet initialised from the environment.
static THRESHOLD: AtomicU8 = AtomicU8::new(0xFF);

fn parse_level(s: &str) -> u8 {
    match s.trim().to_ascii_lowercase().as_str() {
        "off" | "none" | "0" => 0,
        "error" => Level::Error as u8,
        "warn" | "warning" => Level::Warn as u8,
        "info" => Level::Info as u8,
        "debug" | "trace" | "all" => Level::Debug as u8,
        _ => Level::Warn as u8,
    }
}

fn threshold() -> u8 {
    let t = THRESHOLD.load(Ordering::Relaxed);
    if t != 0xFF {
        return t;
    }
    let t = std::env::var("FLASH_LOG").map_or(Level::Warn as u8, |v| parse_level(&v));
    THRESHOLD.store(t, Ordering::Relaxed);
    t
}

/// Override the env-derived filter (CLI flags, tests). `None` = off.
pub fn set_level(level: Option<Level>) {
    THRESHOLD.store(level.map_or(0, |l| l as u8), Ordering::Relaxed);
}

/// Would a message at `level` currently be emitted?
pub fn enabled(level: Level) -> bool {
    (level as u8) <= threshold()
}

fn clock() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

/// Emit one line — `[   12.345s WARN  store] message` — when `level`
/// passes the filter. The message closure is only invoked on emit.
pub fn log(level: Level, target: &str, msg: impl FnOnce() -> String) {
    if !enabled(level) {
        return;
    }
    let t = clock().elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {target}] {}", level.tag(), msg());
}

pub fn error(target: &str, msg: impl FnOnce() -> String) {
    log(Level::Error, target, msg);
}

pub fn warn(target: &str, msg: impl FnOnce() -> String) {
    log(Level::Warn, target, msg);
}

pub fn info(target: &str, msg: impl FnOnce() -> String) {
    log(Level::Info, target, msg);
}

pub fn debug(target: &str, msg: impl FnOnce() -> String) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_all_levels() {
        assert_eq!(parse_level("off"), 0);
        assert_eq!(parse_level("ERROR"), Level::Error as u8);
        assert_eq!(parse_level("warn"), Level::Warn as u8);
        assert_eq!(parse_level("info"), Level::Info as u8);
        assert_eq!(parse_level("debug"), Level::Debug as u8);
        // unknown values fall back to the default, not to silence
        assert_eq!(parse_level("verbose?"), Level::Warn as u8);
    }

    #[test]
    fn levels_are_ordered() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn lazy_message_skipped_when_filtered() {
        // The global threshold is shared across the parallel test
        // binary, so restore the default before returning.
        set_level(Some(Level::Error));
        let mut ran = false;
        debug("test", || {
            ran = true;
            String::new()
        });
        assert!(!ran, "filtered message closure must not run");
        assert!(enabled(Level::Error) && !enabled(Level::Warn));
        set_level(Some(Level::Warn));
    }
}
