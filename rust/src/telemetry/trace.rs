//! Flight-recorder tracer: structured spans and point events with
//! monotonic timestamps, parent/child nesting, and an episode-scoped
//! `trace_id` that crosses the wire as an optional trailing
//! [`TraceCtx`] on store frames (DESIGN.md §12). Hand-rolled like
//! `util/rng` — no external crates, safe to leave compiled into the
//! offline build.
//!
//! The recorder is a process-global, lock-striped store of *finished*
//! records: a [`Span`] carries its own identity while open and pushes
//! one [`SpanRecord`] when it ends (or drops), so the hot path takes a
//! stripe lock exactly once per span. While recording is off (the
//! default) spans are inert — zero ids, nothing stored, no clock read.
//!
//! Export: Chrome trace-event JSON ([`chrome_trace`], loadable in
//! Perfetto / `chrome://tracing`) and a compact JSONL journal
//! ([`journal`]).

use crate::util::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

/// Wire size of an encoded trace context (two u64-le).
pub const CTX_WIRE_LEN: usize = 16;

/// Trace identity propagated across the wire: the episode's `trace_id`
/// plus the sender's current `span_id` (the remote parent).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

impl TraceCtx {
    /// Append the 16-byte wire form: `trace_id` u64-le, `span_id`
    /// u64-le.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.trace_id.to_le_bytes());
        out.extend_from_slice(&self.span_id.to_le_bytes());
    }

    /// Decode from exactly [`CTX_WIRE_LEN`] bytes. `None` on length
    /// mismatch or a zero `trace_id` (the unrecorded sentinel).
    pub fn decode(bytes: &[u8]) -> Option<TraceCtx> {
        if bytes.len() != CTX_WIRE_LEN {
            return None;
        }
        let trace_id = u64::from_le_bytes(bytes[..8].try_into().unwrap());
        let span_id = u64::from_le_bytes(bytes[8..].try_into().unwrap());
        if trace_id == 0 {
            return None;
        }
        Some(TraceCtx { trace_id, span_id })
    }
}

/// A finished span: one contiguous `[start_us, end_us]` interval on a
/// named track, nested under `parent` (0 = trace root).
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent: u64,
    pub name: String,
    pub track: String,
    pub start_us: u64,
    pub end_us: u64,
    pub detail: String,
}

impl SpanRecord {
    pub fn duration_s(&self) -> f64 {
        self.end_us.saturating_sub(self.start_us) as f64 / 1e6
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("t", "span")
            .set("trace", hex_id(self.trace_id))
            .set("span", hex_id(self.span_id))
            .set("parent", hex_id(self.parent))
            .set("name", self.name.as_str())
            .set("track", self.track.as_str())
            .set("start_us", self.start_us)
            .set("end_us", self.end_us);
        if !self.detail.is_empty() {
            o.set("detail", self.detail.as_str());
        }
        o
    }
}

/// A point event, attached to a span (possibly a remote one).
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    pub trace_id: u64,
    pub span_id: u64,
    pub name: String,
    pub track: String,
    pub at_us: u64,
    pub detail: String,
}

impl EventRecord {
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("t", "event")
            .set("trace", hex_id(self.trace_id))
            .set("span", hex_id(self.span_id))
            .set("name", self.name.as_str())
            .set("track", self.track.as_str())
            .set("at_us", self.at_us);
        if !self.detail.is_empty() {
            o.set("detail", self.detail.as_str());
        }
        o
    }
}

const STRIPES: usize = 8;

struct Recorder {
    spans: Vec<Mutex<Vec<SpanRecord>>>,
    events: Vec<Mutex<Vec<EventRecord>>>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn recorder() -> &'static Recorder {
    static R: OnceLock<Recorder> = OnceLock::new();
    R.get_or_init(|| Recorder {
        spans: (0..STRIPES).map(|_| Mutex::new(Vec::new())).collect(),
        events: (0..STRIPES).map(|_| Mutex::new(Vec::new())).collect(),
    })
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

/// Microseconds since the process's (monotonic) trace epoch.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Turn the flight recorder on or off. Spans created while off are
/// inert; previously stored records are kept until [`clear`].
pub fn set_recording(on: bool) {
    epoch(); // pin the time origin no later than the first span
    ENABLED.store(on, Ordering::SeqCst);
}

pub fn recording() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

fn stripe(id: u64) -> usize {
    (id as usize) % STRIPES
}

fn push_span(rec: SpanRecord) {
    if recording() {
        lock(&recorder().spans[stripe(rec.span_id)]).push(rec);
    }
}

fn push_event(rec: EventRecord) {
    if recording() {
        lock(&recorder().events[stripe(rec.span_id)]).push(rec);
    }
}

/// A live span. Ends (and records itself) on [`Span::end`] or drop.
/// Inert — `trace_id == 0`, no storage, no clock reads — when the
/// recorder was off at creation.
#[derive(Debug)]
pub struct Span {
    trace_id: u64,
    span_id: u64,
    parent: u64,
    name: String,
    track: String,
    start_us: u64,
    detail: String,
}

impl Span {
    fn open(trace_id: u64, parent: u64, name: &str, track: &str) -> Span {
        if trace_id == 0 || !recording() {
            return Span {
                trace_id: 0,
                span_id: 0,
                parent: 0,
                name: String::new(),
                track: String::new(),
                start_us: 0,
                detail: String::new(),
            };
        }
        Span {
            trace_id,
            span_id: next_id(),
            parent,
            name: name.to_string(),
            track: track.to_string(),
            start_us: now_us(),
            detail: String::new(),
        }
    }

    pub fn active(&self) -> bool {
        self.trace_id != 0
    }

    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    pub fn span_id(&self) -> u64 {
        self.span_id
    }

    /// This span's wire context; `None` while the recorder is inert.
    pub fn ctx(&self) -> Option<TraceCtx> {
        self.active().then_some(TraceCtx { trace_id: self.trace_id, span_id: self.span_id })
    }

    /// Open a child span nested under this one.
    pub fn child(&self, name: &str, track: &str) -> Span {
        Span::open(self.trace_id, self.span_id, name, track)
    }

    /// Attach a free-form annotation carried into the export.
    pub fn set_detail(&mut self, detail: impl Into<String>) {
        if self.active() {
            self.detail = detail.into();
        }
    }

    /// Record a point event on this span.
    pub fn event(&self, name: &str) {
        if self.active() {
            push_event(EventRecord {
                trace_id: self.trace_id,
                span_id: self.span_id,
                name: name.to_string(),
                track: self.track.clone(),
                at_us: now_us(),
                detail: String::new(),
            });
        }
    }

    /// Close the span now (dropping it does the same).
    pub fn end(self) {}
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.trace_id == 0 {
            return;
        }
        let rec = SpanRecord {
            trace_id: self.trace_id,
            span_id: self.span_id,
            parent: self.parent,
            name: std::mem::take(&mut self.name),
            track: std::mem::take(&mut self.track),
            start_us: self.start_us,
            end_us: now_us(),
            detail: std::mem::take(&mut self.detail),
        };
        self.trace_id = 0;
        push_span(rec);
    }
}

/// Start a new trace: a root span under a fresh `trace_id`.
pub fn root(name: &str, track: &str) -> Span {
    if !recording() {
        return Span::open(0, 0, name, track);
    }
    Span::open(next_id(), 0, name, track)
}

/// Continue a trace received over the wire: a span nested under the
/// remote sender's span.
pub fn from_ctx(ctx: TraceCtx, name: &str, track: &str) -> Span {
    Span::open(ctx.trace_id, ctx.span_id, name, track)
}

/// [`from_ctx`] for an optional context: `None` yields an inert span,
/// so call sites carrying `Option<TraceCtx>` need no branching.
pub fn from_opt_ctx(ctx: Option<TraceCtx>, name: &str, track: &str) -> Span {
    match ctx {
        Some(ctx) => from_ctx(ctx, name, track),
        None => Span::open(0, 0, name, track),
    }
}

/// A point event attached to a remote context (e.g. one store frame).
pub fn event_in(ctx: TraceCtx, name: &str, track: &str, detail: String) {
    if recording() {
        push_event(EventRecord {
            trace_id: ctx.trace_id,
            span_id: ctx.span_id,
            name: name.to_string(),
            track: track.to_string(),
            at_us: now_us(),
            detail,
        });
    }
}

// ------------------------------------------------------------- export

fn hex_id(id: u64) -> String {
    // u64 ids would lose precision as JSON f64 — render as hex text
    format!("{id:016x}")
}

fn collect(trace_id: u64) -> (Vec<SpanRecord>, Vec<EventRecord>) {
    let keep = |t: u64| trace_id == 0 || t == trace_id;
    let mut spans = Vec::new();
    for m in &recorder().spans {
        spans.extend(lock(m).iter().filter(|s| keep(s.trace_id)).cloned());
    }
    spans.sort_by_key(|s| (s.start_us, s.span_id));
    let mut events = Vec::new();
    for m in &recorder().events {
        events.extend(lock(m).iter().filter(|e| keep(e.trace_id)).cloned());
    }
    events.sort_by_key(|e| (e.at_us, e.span_id));
    (spans, events)
}

/// Finished spans of one trace, sorted by start time.
pub fn spans_for(trace_id: u64) -> Vec<SpanRecord> {
    collect(trace_id).0
}

/// Point events of one trace, sorted by timestamp.
pub fn events_for(trace_id: u64) -> Vec<EventRecord> {
    collect(trace_id).1
}

/// Drop every stored record (between episodes / in tests). Does not
/// change the recording flag.
pub fn clear() {
    for m in &recorder().spans {
        lock(m).clear();
    }
    for m in &recorder().events {
        lock(m).clear();
    }
}

/// Export one trace (`trace_id == 0`: every trace) as Chrome
/// trace-event JSON — open in Perfetto (<https://ui.perfetto.dev>) or
/// `chrome://tracing`. Spans are `ph:"X"` complete events (`ts`/`dur`
/// in µs), point events `ph:"i"` instants, and each track gets a
/// `thread_name` metadata record. Non-metadata events are sorted by
/// `ts`, so timestamps are monotonic in file order.
pub fn chrome_trace(trace_id: u64) -> Json {
    let (spans, events) = collect(trace_id);
    let mut tracks: Vec<String> = spans
        .iter()
        .map(|s| s.track.clone())
        .chain(events.iter().map(|e| e.track.clone()))
        .collect();
    tracks.sort();
    tracks.dedup();
    let tid_of = |track: &str| tracks.iter().position(|t| t == track).unwrap() + 1;

    let mut evs: Vec<Json> = Vec::new();
    for (i, t) in tracks.iter().enumerate() {
        let mut m = Json::object();
        m.set("ph", "M").set("pid", 1usize).set("tid", i + 1).set("name", "thread_name");
        let mut args = Json::object();
        args.set("name", t.as_str());
        m.set("args", args);
        evs.push(m);
    }

    // merge spans and instants into one ts-ordered stream
    let mut timed: Vec<(u64, u64, Json)> = Vec::new();
    for s in &spans {
        let mut e = Json::object();
        e.set("ph", "X")
            .set("pid", 1usize)
            .set("tid", tid_of(&s.track))
            .set("name", s.name.as_str())
            .set("cat", "span")
            .set("ts", s.start_us)
            .set("dur", s.end_us.saturating_sub(s.start_us));
        let mut args = Json::object();
        args.set("trace_id", hex_id(s.trace_id))
            .set("span_id", hex_id(s.span_id))
            .set("parent", hex_id(s.parent));
        if !s.detail.is_empty() {
            args.set("detail", s.detail.as_str());
        }
        e.set("args", args);
        timed.push((s.start_us, s.span_id, e));
    }
    for ev in &events {
        let mut e = Json::object();
        e.set("ph", "i")
            .set("pid", 1usize)
            .set("tid", tid_of(&ev.track))
            .set("name", ev.name.as_str())
            .set("cat", "event")
            .set("ts", ev.at_us)
            .set("s", "t");
        let mut args = Json::object();
        args.set("trace_id", hex_id(ev.trace_id)).set("span_id", hex_id(ev.span_id));
        if !ev.detail.is_empty() {
            args.set("detail", ev.detail.as_str());
        }
        e.set("args", args);
        timed.push((ev.at_us, ev.span_id, e));
    }
    timed.sort_by_key(|(ts, id, _)| (*ts, *id));
    evs.extend(timed.into_iter().map(|(_, _, e)| e));

    let mut out = Json::object();
    out.set("displayTimeUnit", "ms").set("traceEvents", Json::Array(evs));
    out
}

/// Compact JSONL journal of one trace (`trace_id == 0`: every trace):
/// one record per line, time-ordered, spans and events merged.
pub fn journal(trace_id: u64) -> String {
    let (spans, events) = collect(trace_id);
    let mut lines: Vec<(u64, u64, String)> = Vec::new();
    for s in &spans {
        lines.push((s.start_us, s.span_id, s.to_json().render()));
    }
    for e in &events {
        lines.push((e.at_us, e.span_id, e.to_json().render()));
    }
    lines.sort_by_key(|(ts, id, _)| (*ts, *id));
    let mut out = String::new();
    for (_, _, l) in lines {
        out.push_str(&l);
        out.push('\n');
    }
    out
}

/// Validate a Chrome trace-event document: object shape, per-event
/// required fields, non-negative durations, and monotonic `ts` across
/// non-metadata events. Returns a description of the first violation.
pub fn validate_chrome_trace(doc: &Json) -> Result<(), String> {
    let evs = doc
        .get("traceEvents")
        .as_array()
        .ok_or_else(|| "traceEvents missing or not an array".to_string())?;
    let mut last_ts = f64::NEG_INFINITY;
    for (i, e) in evs.iter().enumerate() {
        let ph = e
            .get("ph")
            .as_str()
            .ok_or_else(|| format!("event {i}: ph missing"))?;
        if e.get("name").as_str().is_none() {
            return Err(format!("event {i}: name missing"));
        }
        if e.get("pid").as_f64().is_none() || e.get("tid").as_f64().is_none() {
            return Err(format!("event {i}: pid/tid missing"));
        }
        if ph == "M" {
            continue;
        }
        let ts = e
            .get("ts")
            .as_f64()
            .ok_or_else(|| format!("event {i}: ts missing"))?;
        if ts < last_ts {
            return Err(format!("event {i}: ts {ts} < previous {last_ts} (not monotonic)"));
        }
        last_ts = ts;
        if ph == "X" {
            let dur = e
                .get("dur")
                .as_f64()
                .ok_or_else(|| format!("event {i}: dur missing on X event"))?;
            if dur < 0.0 {
                return Err(format!("event {i}: negative dur {dur}"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the recorder is process-global and the test binary runs in
    // parallel, so tests only ever *enable* recording and assert on
    // their own trace_id — never on global counts, never disabling.

    #[test]
    fn ctx_wire_roundtrip() {
        let ctx = TraceCtx { trace_id: 0xDEAD_BEEF_0123_4567, span_id: 42 };
        let mut buf = Vec::new();
        ctx.encode_into(&mut buf);
        assert_eq!(buf.len(), CTX_WIRE_LEN);
        assert_eq!(TraceCtx::decode(&buf), Some(ctx));
        // wrong length or zero trace_id: no context
        assert_eq!(TraceCtx::decode(&buf[..15]), None);
        assert_eq!(TraceCtx::decode(&[0u8; 16]), None);
    }

    #[test]
    fn spans_nest_and_record() {
        set_recording(true);
        let mut root = root("episode", "controller");
        let trace = root.trace_id();
        assert!(trace != 0);
        let root_id = root.span_id();
        {
            let child = root.child("detection", "controller");
            assert_eq!(child.trace_id(), trace);
            child.event("first-beat-missed");
        }
        root.set_detail("step=4");
        root.end();

        let spans = spans_for(trace);
        assert_eq!(spans.len(), 2);
        let child = spans.iter().find(|s| s.name == "detection").unwrap();
        let rootr = spans.iter().find(|s| s.name == "episode").unwrap();
        assert_eq!(child.parent, root_id);
        assert_eq!(rootr.parent, 0);
        assert_eq!(rootr.detail, "step=4");
        assert!(child.start_us >= rootr.start_us);
        assert!(child.end_us <= rootr.end_us);
        let events = events_for(trace);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].span_id, child.span_id);
    }

    #[test]
    fn remote_ctx_stitches_into_same_trace() {
        set_recording(true);
        let root = root("episode", "controller");
        let ctx = root.ctx().unwrap();
        // "other process": continue from the wire context
        from_ctx(ctx, "serve", "store").end();
        event_in(ctx, "frame", "store", "op=Set".to_string());
        let trace = root.trace_id();
        root.end();

        let spans = spans_for(trace);
        assert_eq!(spans.len(), 2);
        let serve = spans.iter().find(|s| s.name == "serve").unwrap();
        assert_eq!(serve.parent, ctx.span_id);
        let events = events_for(trace);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].detail, "op=Set");
    }

    #[test]
    fn inert_ctx_spawns_inert_spans() {
        // zero trace_id (no recording upstream) stays inert even while
        // the recorder is on — no phantom records
        set_recording(true);
        let s = from_ctx(TraceCtx { trace_id: 0, span_id: 0 }, "x", "y");
        assert!(!s.active());
        assert_eq!(s.ctx(), None);
        s.end();
    }

    #[test]
    fn chrome_export_is_schema_valid_and_monotonic() {
        set_recording(true);
        let mut r = root("episode", "controller");
        r.set_detail("scenario=silent_hang");
        {
            let c1 = r.child("rebuild", "controller");
            std::thread::sleep(std::time::Duration::from_millis(2));
            c1.end();
        }
        {
            let c2 = r.child("restore", "worker/1");
            c2.event("shard-done");
            c2.end();
        }
        let trace = r.trace_id();
        r.end();

        let doc = chrome_trace(trace);
        validate_chrome_trace(&doc).unwrap();
        // parse back from rendered text, as the CLI check does
        let parsed = Json::parse(&doc.render()).unwrap();
        validate_chrome_trace(&parsed).unwrap();
        let evs = parsed.get("traceEvents").as_array().unwrap();
        // 2 tracks -> 2 metadata + 3 spans + 1 instant
        assert_eq!(evs.len(), 6);
        let names: Vec<&str> =
            evs.iter().filter_map(|e| e.get("name").as_str()).collect();
        for required in ["episode", "rebuild", "restore", "shard-done"] {
            assert!(names.contains(&required), "{required} missing from {names:?}");
        }
        // journal holds the same records, one JSON object per line
        let j = journal(trace);
        assert_eq!(j.lines().count(), 4);
        for line in j.lines() {
            Json::parse(line).unwrap();
        }
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        let no_events = Json::parse(r#"{"foo": 1}"#).unwrap();
        assert!(validate_chrome_trace(&no_events).is_err());
        let bad_ts = Json::parse(
            r#"{"traceEvents":[
                {"ph":"X","pid":1,"tid":1,"name":"a","ts":10,"dur":1},
                {"ph":"X","pid":1,"tid":1,"name":"b","ts":5,"dur":1}]}"#,
        )
        .unwrap();
        let err = validate_chrome_trace(&bad_ts).unwrap_err();
        assert!(err.contains("monotonic"), "{err}");
        let no_dur = Json::parse(
            r#"{"traceEvents":[{"ph":"X","pid":1,"tid":1,"name":"a","ts":1}]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace(&no_dur).is_err());
    }
}
