//! Recovery flight recorder (DESIGN.md §12): structured spans/events
//! with wire-propagated trace context ([`trace`]), a unified metrics
//! registry with snapshot/diff semantics ([`registry`]), and a
//! leveled env-filtered logger ([`log`], `FLASH_LOG=debug`).
//!
//! Hand-rolled like `util` — no external crates — and inert by
//! default: recording costs one atomic load until
//! [`trace::set_recording`] turns the recorder on.

pub mod log;
pub mod registry;
pub mod trace;

pub use registry::{global, Counter, Gauge, Registry, Series, SeriesStat, Snapshot};
pub use trace::{Span, SpanRecord, TraceCtx};
