//! Unified metrics registry: named counters / gauges / series behind
//! cheap cloneable handles, with snapshot + diff semantics and a JSON
//! schema shared by benches, chaos assertions, the store's `Stats`
//! wire op, and CI gates (DESIGN.md §12).
//!
//! Handles are lock-free on the update path (relaxed atomics); series
//! are bounded-reservoir [`Histogram`]s so long soaks stay flat in
//! memory. The store owns a per-server [`Registry`] instance (parallel
//! tests never collide); process-wide phase metrics use [`global`].

use crate::metrics::Histogram;
use crate::util::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

/// Resident samples kept per series (reservoir bound).
const SERIES_RESERVOIR: usize = 4096;

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Monotonic counter handle. Clone freely; clones share the cell.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level handle (goes up and down).
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn sub(&self, d: i64) {
        self.0.fetch_sub(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Distribution handle backed by a bounded-reservoir [`Histogram`].
#[derive(Debug, Clone)]
pub struct Series(Arc<Mutex<Histogram>>);

impl Series {
    fn new() -> Series {
        Series(Arc::new(Mutex::new(Histogram::with_capacity(SERIES_RESERVOIR))))
    }

    pub fn record(&self, v: f64) {
        lock(&self.0).record(v);
    }

    pub fn snapshot(&self) -> Histogram {
        lock(&self.0).clone()
    }
}

/// Named-metric registry. Lookup get-or-creates; cache the returned
/// handle on hot paths so updates never touch the name maps.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    series: Mutex<BTreeMap<String, Series>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create a named counter.
    pub fn counter(&self, name: &str) -> Counter {
        lock(&self.counters).entry(name.to_string()).or_default().clone()
    }

    /// Get-or-create a named gauge.
    pub fn gauge(&self, name: &str) -> Gauge {
        lock(&self.gauges).entry(name.to_string()).or_default().clone()
    }

    /// Get-or-create a named series.
    pub fn series(&self, name: &str) -> Series {
        lock(&self.series).entry(name.to_string()).or_insert_with(Series::new).clone()
    }

    /// One-shot conveniences for cold call sites without a cached
    /// handle.
    pub fn inc(&self, name: &str) {
        self.counter(name).inc();
    }

    pub fn add(&self, name: &str, n: u64) {
        self.counter(name).add(n);
    }

    pub fn observe(&self, name: &str, v: f64) {
        self.series(name).record(v);
    }

    /// Point-in-time view of every metric.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = Snapshot::default();
        for (k, c) in lock(&self.counters).iter() {
            snap.counters.insert(k.clone(), c.get());
        }
        for (k, g) in lock(&self.gauges).iter() {
            snap.gauges.insert(k.clone(), g.get());
        }
        for (k, s) in lock(&self.series).iter() {
            snap.series.insert(k.clone(), SeriesStat::of(&lock(&s.0)));
        }
        snap
    }
}

/// The process-wide registry (controller phase timings, CLI metrics).
pub fn global() -> &'static Registry {
    static G: OnceLock<Registry> = OnceLock::new();
    G.get_or_init(Registry::new)
}

/// Summary of one series inside a [`Snapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesStat {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl SeriesStat {
    fn of(h: &Histogram) -> SeriesStat {
        if h.is_empty() {
            return SeriesStat { count: 0, sum: 0.0, min: 0.0, max: 0.0, p50: 0.0, p95: 0.0 };
        }
        SeriesStat {
            count: h.len() as u64,
            sum: h.sum(),
            min: h.min(),
            max: h.max(),
            p50: h.p50(),
            p95: h.p95(),
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("count", self.count)
            .set("sum", self.sum)
            .set("min", self.min)
            .set("max", self.max)
            .set("p50", self.p50)
            .set("p95", self.p95);
        o
    }

    fn from_json(v: &Json) -> Result<SeriesStat> {
        Ok(SeriesStat {
            count: v.get("count").as_i64().context("count")? as u64,
            sum: v.get("sum").as_f64().context("sum")?,
            min: v.get("min").as_f64().context("min")?,
            max: v.get("max").as_f64().context("max")?,
            p50: v.get("p50").as_f64().context("p50")?,
            p95: v.get("p95").as_f64().context("p95")?,
        })
    }
}

/// Point-in-time view of a [`Registry`] — diffable, JSON-round-trip —
/// the payload behind the store's `Stats` wire op.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub series: BTreeMap<String, SeriesStat>,
}

impl Snapshot {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    /// Counters become deltas against `older`; gauges and series keep
    /// their current values (levels and distributions, not rates).
    pub fn diff(&self, older: &Snapshot) -> Snapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), v.saturating_sub(older.counter(k))))
            .collect();
        Snapshot { counters, gauges: self.gauges.clone(), series: self.series.clone() }
    }

    pub fn to_json(&self) -> Json {
        let mut counters = Json::object();
        for (k, v) in &self.counters {
            counters.set(k, *v);
        }
        let mut gauges = Json::object();
        for (k, v) in &self.gauges {
            gauges.set(k, *v);
        }
        let mut series = Json::object();
        for (k, v) in &self.series {
            series.set(k, v.to_json());
        }
        let mut o = Json::object();
        o.set("counters", counters).set("gauges", gauges).set("series", series);
        o
    }

    /// Parse a snapshot from wire bytes (the `Stats` response value).
    pub fn parse(bytes: &[u8]) -> Result<Snapshot> {
        let text = std::str::from_utf8(bytes).context("snapshot utf8")?;
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut snap = Snapshot::default();
        if let Some(o) = v.get("counters").as_object() {
            for (k, n) in o {
                snap.counters.insert(k.clone(), n.as_i64().context("counter")? as u64);
            }
        }
        if let Some(o) = v.get("gauges").as_object() {
            for (k, n) in o {
                snap.gauges.insert(k.clone(), n.as_i64().context("gauge")?);
            }
        }
        if let Some(o) = v.get("series").as_object() {
            for (k, s) in o {
                snap.series.insert(k.clone(), SeriesStat::from_json(s)?);
            }
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_cells_across_clones() {
        let r = Registry::new();
        let a = r.counter("frames");
        let b = r.counter("frames");
        a.add(2);
        b.inc();
        assert_eq!(r.counter("frames").get(), 3);

        let g = r.gauge("live");
        g.set(5);
        g.sub(2);
        assert_eq!(r.gauge("live").get(), 3);

        let s = r.series("lat");
        s.record(1.0);
        r.observe("lat", 3.0);
        assert_eq!(s.snapshot().len(), 2);
    }

    #[test]
    fn snapshot_diff_subtracts_counters_only() {
        let r = Registry::new();
        r.add("ops", 10);
        r.gauge("depth").set(4);
        let before = r.snapshot();
        r.add("ops", 7);
        r.gauge("depth").set(9);
        r.observe("wall", 0.25);
        let after = r.snapshot();
        let d = after.diff(&before);
        assert_eq!(d.counter("ops"), 7);
        assert_eq!(d.gauge("depth"), 9, "gauges stay levels");
        assert_eq!(d.series["wall"].count, 1);
        // missing-in-older counters diff from zero
        r.inc("new");
        assert_eq!(r.snapshot().diff(&before).counter("new"), 1);
    }

    #[test]
    fn snapshot_json_roundtrip() {
        let r = Registry::new();
        r.add("frames", 42);
        r.gauge("workers").set(-3);
        for v in [0.5, 1.5, 2.5, 9.5] {
            r.observe("lat_us", v);
        }
        let snap = r.snapshot();
        let back = Snapshot::parse(snap.to_json().render().as_bytes()).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.counter("frames"), 42);
        assert_eq!(back.gauge("workers"), -3);
        assert_eq!(back.series["lat_us"].count, 4);
        assert!((back.series["lat_us"].max - 9.5).abs() < 1e-9);
    }

    #[test]
    fn empty_series_serialises_finite() {
        // min/max of an empty histogram are +/-inf — the snapshot must
        // stay valid JSON
        let r = Registry::new();
        let _ = r.series("untouched");
        let snap = r.snapshot();
        let text = snap.to_json().render();
        assert!(!text.contains("inf"), "{text}");
        assert_eq!(Snapshot::parse(text.as_bytes()).unwrap(), snap);
    }

    #[test]
    fn global_registry_is_shared() {
        global().add("telemetry_test_global", 2);
        assert!(global().snapshot().counter("telemetry_test_global") >= 2);
    }
}
