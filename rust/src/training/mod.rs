//! The real training plane: data pipeline, per-rank model state, DP
//! worker threads executing AOT PJRT artifacts, and the engine facade.

pub mod data;
pub mod engine;
pub mod state;
pub mod worker;

pub use data::{DataConfig, DataIterator};
pub use engine::TrainingEngine;
pub use state::WorkerState;
pub use worker::{FailurePlan, MonitorBoard, Phase, WorkerCommand, WorkerEvent};
