//! Per-rank model state: parameters + Adam moments as device literals,
//! with host-side conversion for checkpointing and replica transfer.

use crate::checkpoint::Snapshot;
use crate::runtime::{literal_f32, to_f32_vec, ModelBundle};
use anyhow::{bail, Result};

/// One training rank's complete model state. `step` counts completed
/// optimizer updates: state at `step = i` is "the parameters of the
/// i-th step" in the paper's terms.
pub struct WorkerState {
    pub step: u64,
    pub params: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
}

// SAFETY: `xla::Literal` is an exclusively-owned host-memory object
// with no reference to the (non-thread-safe, Rc-based) PJRT client;
// moving a WorkerState into a worker thread transfers sole ownership.
unsafe impl Send for WorkerState {}

impl WorkerState {
    /// Fresh state from the on-device initializer.
    pub fn init(bundle: &ModelBundle, seed: i32) -> Result<Self> {
        Ok(WorkerState {
            step: 0,
            params: bundle.init_params(seed)?,
            m: bundle.zeros_like_params()?,
            v: bundle.zeros_like_params()?,
        })
    }

    /// Serialize to a host snapshot (k0 / replica-broadcast payload):
    /// params ++ m ++ v in manifest order.
    pub fn to_snapshot(&self) -> Result<Snapshot> {
        let mut tensors = Vec::with_capacity(3 * self.params.len());
        for group in [&self.params, &self.m, &self.v] {
            for lit in group.iter() {
                tensors.push(to_f32_vec(lit)?);
            }
        }
        Ok(Snapshot { step: self.step, tensors })
    }

    /// Rebuild device state from a snapshot.
    pub fn from_snapshot(bundle: &ModelBundle, snap: &Snapshot) -> Result<Self> {
        let n = bundle.manifest.params.len();
        if snap.tensors.len() != 3 * n {
            bail!(
                "snapshot has {} tensors, model wants {}",
                snap.tensors.len(),
                3 * n
            );
        }
        let build = |range: std::ops::Range<usize>| -> Result<Vec<xla::Literal>> {
            range
                .map(|i| {
                    let spec = &bundle.manifest.params[i % n];
                    if snap.tensors[i].len() != spec.elements() {
                        bail!(
                            "tensor {i} has {} elements, spec {} wants {}",
                            snap.tensors[i].len(),
                            spec.name,
                            spec.elements()
                        );
                    }
                    literal_f32(&spec.shape, &snap.tensors[i])
                })
                .collect()
        };
        Ok(WorkerState {
            step: snap.step,
            params: build(0..n)?,
            m: build(n..2 * n)?,
            v: build(2 * n..3 * n)?,
        })
    }

    /// Hash over the exact parameter bits + step (the shared word-wise
    /// `util::hash` flavour, fed f32s in place — ~8x faster than the
    /// byte-at-a-time FNV it replaces and with no intermediate byte
    /// copy, which matters when every recovery fingerprints tens of MB
    /// of state). Equal hashes across DP ranks == bitwise-consistent
    /// replicas (the invariant checkpoint-free recovery must preserve).
    pub fn param_hash(&self) -> Result<u64> {
        use crate::util::hash::{fnv1a, fnv1a_f32, FNV_OFFSET};
        let mut hash = fnv1a(&self.step.to_le_bytes(), FNV_OFFSET);
        for lit in &self.params {
            hash = fnv1a_f32(&to_f32_vec(lit)?, hash);
        }
        Ok(hash)
    }

    /// Max |a - b| over all parameters (DP-consistency checks).
    pub fn max_param_diff(&self, other: &WorkerState) -> Result<f32> {
        let mut max = 0.0f32;
        for (a, b) in self.params.iter().zip(other.params.iter()) {
            let av = to_f32_vec(a)?;
            let bv = to_f32_vec(b)?;
            for (x, y) in av.iter().zip(bv.iter()) {
                max = max.max((x - y).abs());
            }
        }
        Ok(max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::util::artifacts_dir;

    fn bundle() -> ModelBundle {
        let rt = Runtime::cpu().unwrap();
        ModelBundle::load(&rt, &artifacts_dir().unwrap(), "tiny").unwrap()
    }

    #[test]
    fn snapshot_roundtrip_is_exact() {
        crate::require_live_plane!();
        let b = bundle();
        let mut s = WorkerState::init(&b, 5).unwrap();
        s.step = 9;
        let snap = s.to_snapshot().unwrap();
        assert_eq!(snap.step, 9);
        assert_eq!(snap.tensors.len(), 3 * b.manifest.params.len());
        let back = WorkerState::from_snapshot(&b, &snap).unwrap();
        assert_eq!(back.step, 9);
        assert_eq!(s.max_param_diff(&back).unwrap(), 0.0);
    }

    #[test]
    fn from_snapshot_rejects_wrong_arity() {
        crate::require_live_plane!();
        let b = bundle();
        let s = WorkerState::init(&b, 0).unwrap();
        let mut snap = s.to_snapshot().unwrap();
        snap.tensors.pop();
        assert!(WorkerState::from_snapshot(&b, &snap).is_err());
    }

    #[test]
    fn from_snapshot_rejects_wrong_shape() {
        crate::require_live_plane!();
        let b = bundle();
        let s = WorkerState::init(&b, 0).unwrap();
        let mut snap = s.to_snapshot().unwrap();
        snap.tensors[0].pop();
        assert!(WorkerState::from_snapshot(&b, &snap).is_err());
    }

    #[test]
    fn max_param_diff_detects_divergence() {
        crate::require_live_plane!();
        let b = bundle();
        let a = WorkerState::init(&b, 0).unwrap();
        let c = WorkerState::init(&b, 1).unwrap();
        assert!(a.max_param_diff(&c).unwrap() > 0.0);
        assert_eq!(a.max_param_diff(&a).unwrap(), 0.0);
    }
}
