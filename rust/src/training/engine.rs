//! Public training-engine facade: load a model bundle once, then run
//! data-parallel training jobs under the FlashRecovery controller (or
//! the vanilla baseline) with scripted failure injection.

use crate::coordinator::{Controller, ControllerConfig, RunReport};
use crate::runtime::{ModelBundle, Runtime};
use crate::util::artifacts_dir;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// A loaded model + PJRT runtime, reusable across runs (compilation is
/// the expensive part; the bundle is shared by every worker thread).
pub struct TrainingEngine {
    pub runtime: Runtime,
    pub bundle: Arc<ModelBundle>,
}

impl TrainingEngine {
    /// Load `size` ("tiny" | "small" | "base") from the repo's
    /// artifacts directory.
    pub fn load(size: &str) -> Result<Self> {
        let dir = artifacts_dir()
            .context("artifacts/ not found — run `make artifacts`")?;
        Self::load_from(size, dir)
    }

    pub fn load_from(size: &str, dir: PathBuf) -> Result<Self> {
        let runtime = Runtime::cpu()?;
        let bundle = Arc::new(ModelBundle::load(&runtime, &dir, size)?);
        Ok(TrainingEngine { runtime, bundle })
    }

    /// Run one training job to completion (including any recoveries).
    pub fn run(&self, cfg: ControllerConfig) -> Result<RunReport> {
        Controller::new(self.bundle.clone(), cfg)?.run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::failure::FailureKind;
    use crate::config::RecoveryMode;
    use crate::training::worker::{FailurePlan, Phase};
    use crate::util::temp_dir;
    use std::time::Duration;

    fn engine() -> TrainingEngine {
        TrainingEngine::load("tiny").expect("run `make artifacts` first")
    }

    #[test]
    fn failure_free_run_converges_and_stays_consistent() {
        crate::require_live_plane!();
        let e = engine();
        let report = e.run(ControllerConfig::flash(2, 12)).unwrap();
        assert_eq!(report.final_step, 12);
        assert!(report.recoveries.is_empty());
        assert_eq!(report.final_param_divergence, 0.0, "DP ranks diverged");
        let first = report.losses.first().unwrap().1;
        let last = report.losses.last().unwrap().1;
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        assert_eq!(report.losses.len(), 12);
    }

    #[test]
    fn flash_recovers_from_fwd_bwd_failure_at_step_i() {
        crate::require_live_plane!();
        let e = engine();
        let mut cfg = ControllerConfig::flash(3, 10);
        cfg.failures = vec![FailurePlan {
            rank: 1,
            step: 4,
            phase: Phase::FwdBwd,
            kind: FailureKind::Segfault,
        }];
        let report = e.run(cfg).unwrap();
        assert_eq!(report.final_step, 10);
        assert_eq!(report.recoveries.len(), 1);
        let r = &report.recoveries[0];
        assert_eq!(r.mode, RecoveryMode::Flash);
        assert_eq!(r.failed_ranks, vec![1]);
        // fwd/bwd failure: resume from step i == 4
        assert_eq!(r.resume_step, 4);
        assert_eq!(r.lost_steps, 0);
        assert!(!r.via_device_plugin); // software death -> monitor path
        assert_eq!(report.final_param_divergence, 0.0);
        // all 10 steps present in the loss curve
        assert_eq!(report.losses.len(), 10);
    }

    #[test]
    fn flash_recovers_from_optimizer_failure_at_step_i_plus_1() {
        crate::require_live_plane!();
        let e = engine();
        let mut cfg = ControllerConfig::flash(2, 9);
        cfg.failures = vec![FailurePlan {
            rank: 0,
            step: 5,
            phase: Phase::OptStep,
            kind: FailureKind::Network,
        }];
        let report = e.run(cfg).unwrap();
        assert_eq!(report.final_step, 9);
        assert_eq!(report.recoveries.len(), 1);
        let r = &report.recoveries[0];
        // optimizer failure: survivors finished the update -> resume i+1
        assert_eq!(r.resume_step, 6);
        assert_eq!(r.lost_steps, 0);
        assert!(r.via_device_plugin); // hardware kind -> plugin path
        assert_eq!(report.final_param_divergence, 0.0);
    }

    #[test]
    fn flash_detection_is_fast() {
        crate::require_live_plane!();
        let e = engine();
        let mut cfg = ControllerConfig::flash(2, 8);
        cfg.heartbeat_interval = Duration::from_millis(50);
        cfg.failures = vec![FailurePlan {
            rank: 1,
            step: 3,
            phase: Phase::FwdBwd,
            kind: FailureKind::DeviceMemory,
        }];
        let report = e.run(cfg).unwrap();
        let r = &report.recoveries[0];
        // device-plugin path: noticed within a few heartbeat periods
        assert!(r.detection_s < 1.0, "detection took {}s", r.detection_s);
    }

    #[test]
    fn vanilla_recovers_from_checkpoint_with_lost_steps() {
        crate::require_live_plane!();
        let e = engine();
        let dir = temp_dir("vanilla-e2e").unwrap();
        let mut cfg = ControllerConfig::vanilla(
            2,
            10,
            3,                               // checkpoint every 3 steps
            Duration::from_millis(500),      // scaled-down 1800 s timeout
        );
        cfg.ckpt_dir = dir.clone();
        cfg.failures = vec![FailurePlan {
            rank: 1,
            step: 7,
            phase: Phase::FwdBwd,
            kind: FailureKind::Segfault,
        }];
        let report = e.run(cfg).unwrap();
        assert_eq!(report.final_step, 10);
        assert_eq!(report.recoveries.len(), 1);
        let r = &report.recoveries[0];
        assert_eq!(r.mode, RecoveryMode::Vanilla);
        // rolled back to the step-6 checkpoint, losing step 7's prefix
        assert_eq!(r.resume_step, 6);
        assert_eq!(r.failed_at_step, 7);
        assert_eq!(r.lost_steps, 1);
        // detection took at least the collective timeout
        assert!(r.detection_s >= 0.4, "detection {}s", r.detection_s);
        assert!(report.checkpoints_taken >= 2);
        assert_eq!(report.final_param_divergence, 0.0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn flash_loss_curve_is_continuous_across_recovery() {
        crate::require_live_plane!();
        // The recovered run must produce the same loss trajectory as a
        // failure-free run: checkpoint-free recovery loses nothing.
        let e = engine();
        let clean = e.run(ControllerConfig::flash(2, 8)).unwrap();
        let mut cfg = ControllerConfig::flash(2, 8);
        cfg.failures = vec![FailurePlan {
            rank: 1,
            step: 4,
            phase: Phase::FwdBwd,
            kind: FailureKind::Segfault,
        }];
        let recovered = e.run(cfg).unwrap();
        assert_eq!(clean.losses.len(), recovered.losses.len());
        for ((s1, l1), (s2, l2)) in clean.losses.iter().zip(recovered.losses.iter()) {
            assert_eq!(s1, s2);
            assert!(
                (l1 - l2).abs() < 1e-5,
                "step {s1}: clean {l1} vs recovered {l2}"
            );
        }
    }
}
