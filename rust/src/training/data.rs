//! Synthetic training corpus + resumable data iterator.
//!
//! Batches are a *pure function* of (seed, step, dp_rank): rolling the
//! iterator back after a failure (paper §III-E "Rollback") is just
//! re-requesting the same step index — no iterator state can be lost
//! with the faulty process. The corpus is an order-1 multiplicative
//! Markov chain over the vocabulary, so the LM loss visibly decreases
//! (structure is learnable) while generation stays allocation-cheap.

use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct DataConfig {
    pub vocab: usize,
    /// tokens per sequence *including* the shifted target (seq + 1).
    pub seq_plus_1: usize,
    pub batch: usize,
    pub seed: u64,
    /// Markov noise breadth: next = (prev * 7 + U[0,noise)) % vocab.
    pub noise: u64,
}

impl DataConfig {
    pub fn for_model(vocab: usize, seq: usize, batch: usize, seed: u64) -> Self {
        DataConfig { vocab, seq_plus_1: seq + 1, batch, seed, noise: 8 }
    }
}

/// Deterministic, resumable batch source.
#[derive(Debug, Clone)]
pub struct DataIterator {
    cfg: DataConfig,
}

impl DataIterator {
    pub fn new(cfg: DataConfig) -> Self {
        assert!(cfg.vocab > 1);
        assert!(cfg.noise > 0);
        DataIterator { cfg }
    }

    pub fn cfg(&self) -> &DataConfig {
        &self.cfg
    }

    /// The token batch for (step, dp_rank): i32[batch * (seq+1)],
    /// row-major. Distinct DP ranks get disjoint streams.
    pub fn batch_for(&self, step: u64, dp_rank: usize) -> Vec<i32> {
        let c = &self.cfg;
        let mut rng = Rng::new(
            c.seed
                ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (dp_rank as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let mut out = Vec::with_capacity(c.batch * c.seq_plus_1);
        for _ in 0..c.batch {
            let mut tok = rng.below(c.vocab as u64);
            out.push(tok as i32);
            for _ in 1..c.seq_plus_1 {
                tok = (tok.wrapping_mul(7) + rng.below(c.noise)) % c.vocab as u64;
                out.push(tok as i32);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn it() -> DataIterator {
        DataIterator::new(DataConfig::for_model(256, 32, 4, 0))
    }

    #[test]
    fn batch_shape_and_range() {
        let b = it().batch_for(0, 0);
        assert_eq!(b.len(), 4 * 33);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn rollback_reproduces_exactly() {
        let i = it();
        assert_eq!(i.batch_for(17, 2), i.batch_for(17, 2));
    }

    #[test]
    fn steps_and_ranks_are_distinct() {
        let i = it();
        assert_ne!(i.batch_for(1, 0), i.batch_for(2, 0));
        assert_ne!(i.batch_for(1, 0), i.batch_for(1, 1));
    }

    #[test]
    fn markov_structure_present() {
        // successive tokens satisfy next in {prev*7 .. prev*7+noise} mod V
        let i = it();
        let b = i.batch_for(3, 0);
        let row = &b[..33];
        for w in row.windows(2) {
            let prev = w[0] as u64;
            let next = w[1] as u64;
            let base = (prev * 7) % 256;
            let delta = (next + 256 - base) % 256;
            assert!(delta < 8, "prev={prev} next={next}");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = DataIterator::new(DataConfig::for_model(256, 32, 4, 0));
        let b = DataIterator::new(DataConfig::for_model(256, 32, 4, 1));
        assert_ne!(a.batch_for(0, 0), b.batch_for(0, 0));
    }
}
