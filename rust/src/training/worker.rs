//! DP worker threads: each simulated device runs real training steps
//! through the AOT-compiled PJRT executables.
//!
//! The step structure implements the paper's §III-E protocol exactly:
//!
//! ```text
//! tag = i          # beginning of forward (step-tag rule 1)
//! loss, grads = fwd_bwd(params_i, batch_i)          # PJRT execute
//! grads = allreduce_mean(grads)   # gradient sync == the barrier
//! tag = -1         # beginning of optimizer step (rule 4)
//! params_{i+1} = opt_step(params_i, grads)          # PJRT execute
//! tag = i + 1      # optimizer complete (rule 5)
//! ```
//!
//! Failure injection simulates *process death*: the thread simply stops
//! — no unwind, no poison — so peers block in the allreduce exactly as
//! a real NCCL/HCCL rank loss manifests. A monitoring board (atomic
//! flags shared with the controller) plays the role of the paper's
//! per-process monitor + per-node device plugin.

use super::data::DataIterator;
use super::state::WorkerState;
use crate::checkpoint::CheckpointManager;
use crate::cluster::failure::{FailureCategory, FailureKind};
use crate::comms::state_stream::{
    fetch_from_addr, serve_listener, EpochFence, Expect, RestoreError, StreamConfig,
};
use crate::comms::replication::{StoreEndpoints, StoreSession};
use crate::comms::{Collective, CollectiveError};
use crate::config::ShardId;
use crate::runtime::{literal_tokens, ModelBundle};
use crate::telemetry::{log, TraceCtx};
use anyhow::Result;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Step tag value while the optimizer is executing (paper rule 4).
pub const TAG_OPTIMIZER: i64 = -1;

/// Where in the step a planned failure strikes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// During forward/backward — before the gradient barrier.
    FwdBwd,
    /// During the optimizer step — after the barrier.
    OptStep,
}

/// A scripted failure for experiments: rank `rank` dies at step `step`
/// in phase `phase`, presenting as failure kind `kind`.
#[derive(Debug, Clone, Copy)]
pub struct FailurePlan {
    pub rank: usize,
    pub step: u64,
    pub phase: Phase,
    pub kind: FailureKind,
}

/// Controller -> worker commands.
pub enum WorkerCommand {
    /// Resume training from `resume_step` (state must already match).
    Continue { resume_step: u64 },
    /// Act as a replica source: stream this rank's state shard to
    /// `receivers` targets over the pre-bound listener, fenced at
    /// `epoch` (DESIGN.md §9).
    ServeState {
        listener: TcpListener,
        shard: ShardId,
        epoch: u64,
        receivers: usize,
        fence: EpochFence,
        /// Flight-recorder context of the controller's restore span;
        /// the serve spans (and the in-band stream trace frame) nest
        /// under it. `None` when the recorder is off.
        trace: Option<TraceCtx>,
    },
    /// Fetch this rank's state shard from the replica source at
    /// `source_addr`, verifying shard / epoch / resume step.
    RestoreState {
        source_rank: usize,
        source_addr: SocketAddr,
        shard: ShardId,
        epoch: u64,
        expect_step: u64,
        fence: EpochFence,
    },
    /// Exit cleanly.
    Stop,
}

/// Worker -> controller events.
#[derive(Debug)]
pub enum WorkerEvent {
    /// One optimizer step completed.
    Loss { rank: usize, step: u64, loss: f32 },
    /// The worker hit a collective error and is awaiting instructions.
    Parked { rank: usize, state_step: u64, err: CollectiveError },
    /// Clean exit (Stop or max_steps reached). `param_hash` fingerprints
    /// the exact final parameter bits for DP-consistency checks.
    Stopped { rank: usize, state_step: u64, param_hash: u64 },
    /// A periodic checkpoint was taken (vanilla baseline).
    CheckpointTaken { rank: usize, step: u64, k0_s: f64 },
    /// This rank finished serving its state shard to `targets` peers.
    StateServed { rank: usize, targets: usize, bytes: u64, wall_s: f64 },
    /// This rank's state was restored from `source` over the stream
    /// plane; the controller folds the stats into the episode record.
    StateRestored {
        rank: usize,
        shard: ShardId,
        source: usize,
        bytes: u64,
        wall_s: f64,
    },
    /// A state transfer did not complete. `retryable` is true when the
    /// transfer was superseded by an epoch bump (replan + retry), false
    /// for IO/corruption failures.
    RestoreFailed { rank: usize, retryable: bool, detail: String },
}

/// Shared monitoring state — the paper's monitoring process (liveness +
/// step tag) and device plugin (hardware error code) in one board the
/// controller polls every heartbeat interval.
pub struct MonitorBoard {
    pub alive: AtomicBool,
    /// Milliseconds since the global epoch at which an injected failure
    /// struck (ground truth for detection-latency measurement); 0 = n/a.
    pub death_at_ms: std::sync::atomic::AtomicU64,
    /// Paper step tag: i (fwd/bwd of step i), -1 (optimizer), i+1 (done).
    pub step_tag: AtomicI64,
    /// Device-plugin hardware error report: -1 = none, else a
    /// [`FailureKind`] discriminant (hardware kinds only).
    pub device_error: AtomicI64,
}

impl MonitorBoard {
    pub fn new() -> Arc<Self> {
        Arc::new(MonitorBoard {
            alive: AtomicBool::new(true),
            death_at_ms: std::sync::atomic::AtomicU64::new(0),
            step_tag: AtomicI64::new(0),
            device_error: AtomicI64::new(-1),
        })
    }
}

pub fn kind_code(kind: FailureKind) -> i64 {
    FailureKind::all().iter().position(|k| *k == kind).unwrap() as i64
}

pub fn kind_from_code(code: i64) -> Option<FailureKind> {
    FailureKind::all().get(code as usize).copied()
}

/// Where and how a worker's heartbeat emitter pushes beats.
#[derive(Debug, Clone)]
pub struct HeartbeatCfg {
    /// The coordination plane's endpoint set (one address for an
    /// un-replicated store; the emitter fails over across the set).
    pub store: StoreEndpoints,
    /// Push interval; the monitor's lease is a multiple of it.
    pub interval: Duration,
    /// Worker incarnation stamped on every beat — a replacement's
    /// lease can never be refreshed by its dead predecessor.
    pub incarnation: u64,
}

/// True when `board`'s worker is dead with no hardware report pending
/// — nothing a late store connection could still deliver for it.
fn board_done(board: &MonitorBoard) -> bool {
    !board.alive.load(Ordering::SeqCst)
        && board.device_error.load(Ordering::SeqCst) < 0
}

/// Connect to the store with bounded exponential backoff: an emitter
/// that starts before the store is up (controller still binding, or a
/// replacement racing the recovery episode) must still lease in
/// instead of silently forfeiting the wire plane. Every attempt is a
/// full discovery pass over the *whole* endpoint set — the old loop
/// retried one address only, so a worker started during a primary
/// crash never leased in even though a replica was one endpoint away.
/// Gives up — and lets the board-scan fallback cover the ranks — once
/// the attempts are exhausted or `abandoned()` reports there is
/// nobody left to beat for (per-process: its one board; node agent:
/// *every* member, so one rank dying early cannot strand its healthy
/// peers).
fn connect_with_backoff(
    store: &StoreEndpoints,
    interval: Duration,
    abandoned: impl Fn() -> bool,
) -> Option<StoreSession> {
    let mut delay = interval.max(Duration::from_millis(5));
    // Jitter each sleep so a fleet of workers racing a recovering
    // store spreads its reconnects instead of stampeding in lockstep
    // (DESIGN.md §15); salted per-endpoint-set so the spread is
    // deterministic per process yet distinct across peers.
    let salt = store
        .addrs()
        .first()
        .map(|a| u64::from(a.port()))
        .unwrap_or(0)
        ^ (std::process::id() as u64) << 16;
    for attempt in 0..12 {
        match StoreSession::try_connect(store) {
            Ok(s) => return Some(s),
            Err(_) => {
                if abandoned() || attempt == 11 {
                    return None;
                }
                std::thread::sleep(crate::comms::jittered(delay, salt, attempt));
                delay = (delay * 2).min(Duration::from_secs(1));
            }
        }
    }
    None
}

/// Spawn the heartbeat emitter for one worker: the paper's per-process
/// monitoring process + per-node device plugin pushing over the live
/// wire (DESIGN.md §10). Reads the board's atomics and pushes one
/// `Heartbeat` frame per interval — O(1) per worker per beat.
///
/// The device plugin outlives the training process: when the worker
/// dies (`alive == false`) with a pending hardware report, one final
/// beat carrying the `device_code` still reaches the wire before the
/// emitter exits, so the monitor classifies the failure by its
/// hardware kind even when the death and the report race into the
/// same interval. A silent hang, by contrast, keeps the emitter alive
/// and pushing a frozen `step_tag` — exactly what the monitor's stall
/// detection consumes.
pub fn spawn_heartbeat(
    rank: usize,
    board: Arc<MonitorBoard>,
    cfg: HeartbeatCfg,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("hb-{rank}"))
        .spawn(move || {
            // Bounded-backoff connect: a worker starting before the
            // store is up still leases in (the old emitter exited
            // silently on the first refused connect).
            let Some(mut client) =
                connect_with_backoff(&cfg.store, cfg.interval, || board_done(&board))
            else {
                return; // no plane: the board-scan fallback covers us
            };
            loop {
                let tag = board.step_tag.load(Ordering::SeqCst);
                if !board.alive.load(Ordering::SeqCst) {
                    // Dying gasp: the hardware report must reach the
                    // wire even though the process is gone. Load the
                    // code *after* observing death — failure paths
                    // store `device_error` before dropping `alive`,
                    // so this load cannot miss a report the way a
                    // pre-check load raced against both stores could.
                    let code = board.device_error.load(Ordering::SeqCst);
                    if code >= 0 {
                        let _ = client.heartbeat(rank as u64, cfg.incarnation, tag, code);
                    }
                    return;
                }
                let code = board.device_error.load(Ordering::SeqCst);
                if client.heartbeat(rank as u64, cfg.incarnation, tag, code).is_err() {
                    return; // store gone (controller teardown)
                }
                std::thread::sleep(cfg.interval);
            }
        })
        .expect("spawn heartbeat emitter")
}

/// One local rank a node agent pushes beats for.
pub struct NodeRank {
    pub rank: usize,
    pub incarnation: u64,
    pub board: Arc<MonitorBoard>,
}

/// Where and how a node agent pushes its coalesced beats.
#[derive(Debug, Clone)]
pub struct NodeAgentCfg {
    /// The coordination plane's endpoint set (the agent's batched
    /// beats fail over across it like any session op).
    pub store: StoreEndpoints,
    /// Push interval; the monitor's lease is a multiple of it.
    pub interval: Duration,
}

/// Node-agent heartbeat mode: one emitter per *node* pushing every
/// local rank's beat as a single `Batch` frame per interval — the
/// wire cost per node drops from `ranks x RTT` to one RTT while every
/// rank still gets its own O(1) beat record (and its own lease,
/// incarnation, and stall clock on the monitor).
///
/// Per-rank semantics match [`spawn_heartbeat`] exactly: a dying
/// rank's pending hardware report still reaches the wire in the
/// agent's next batch (the dying gasp), after which the rank is
/// dropped from the batch — its lease then expires like any silent
/// peer's. A silently *hanging* rank keeps beating with a frozen step
/// tag, feeding the monitor's stall detection. The agent exits once
/// every member is done or the store is gone.
pub fn spawn_node_heartbeat(
    members: Vec<NodeRank>,
    cfg: NodeAgentCfg,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("hb-node".to_string())
        .spawn(move || {
            if members.is_empty() {
                return;
            }
            let Some(mut client) = connect_with_backoff(&cfg.store, cfg.interval, || {
                members.iter().all(|m| board_done(&m.board))
            }) else {
                return; // no plane: the board-scan fallback covers us
            };
            let mut done = vec![false; members.len()];
            // Beats whose flush failed mid-failover (the session's
            // whole-set discovery came up empty for one interval) —
            // carried into the next tick's batch instead of dropped,
            // so a beat interval that fires while the plane is
            // electing still refreshes every lease on the survivor.
            // Only consecutive failures abandon the agent: teardown
            // stays bounded, but one bad interval is not a death.
            let mut carry: Vec<crate::comms::wire::Request> = Vec::new();
            let mut flush_failures = 0u32;
            const MAX_FLUSH_FAILURES: u32 = 10;
            loop {
                let mut beats = std::mem::take(&mut carry);
                for (i, m) in members.iter().enumerate() {
                    if done[i] {
                        continue;
                    }
                    let tag = m.board.step_tag.load(Ordering::SeqCst);
                    let rank = m.rank as u64;
                    // A fresh beat supersedes this rank's carried one
                    // (newest step tag / device code wins); carried
                    // dying gasps survive — a done rank emits nothing
                    // fresh, so its gasp stays until it flushes.
                    beats.retain(|b| {
                        !matches!(
                            b,
                            crate::comms::wire::Request::Heartbeat { rank: r, .. }
                                if *r == rank
                        )
                    });
                    if !m.board.alive.load(Ordering::SeqCst) {
                        // Dying gasp: load the code *after* observing
                        // death (failure paths store `device_error`
                        // before dropping `alive`), same ordering
                        // argument as the per-process emitter.
                        let code = m.board.device_error.load(Ordering::SeqCst);
                        if code >= 0 {
                            beats.push(crate::comms::wire::Request::Heartbeat {
                                rank,
                                incarnation: m.incarnation,
                                step_tag: tag,
                                device_code: code,
                            });
                        }
                        done[i] = true;
                        continue;
                    }
                    let code = m.board.device_error.load(Ordering::SeqCst);
                    beats.push(crate::comms::wire::Request::Heartbeat {
                        rank,
                        incarnation: m.incarnation,
                        step_tag: tag,
                        device_code: code,
                    });
                }
                if !beats.is_empty() {
                    if client.batch(beats.clone()).is_err() {
                        flush_failures += 1;
                        if flush_failures >= MAX_FLUSH_FAILURES {
                            return; // store gone (controller teardown)
                        }
                        carry = beats;
                    } else {
                        flush_failures = 0;
                    }
                }
                if done.iter().all(|d| *d) && carry.is_empty() {
                    return; // every member dead and flushed
                }
                std::thread::sleep(cfg.interval);
            }
        })
        .expect("spawn node heartbeat agent")
}

/// Worker-side handle on the redundancy tier (DESIGN.md §16): ships
/// erasure-coded stripes of the post-step state during idle step time,
/// so the shard stays restorable even if its whole replica group dies.
pub struct RedundancyHook {
    pub shipper: crate::redundancy::StripeShipper,
    /// Ship every `interval` steps (values <= 1 mean every step).
    pub interval: u64,
    /// Coordination epoch the stripes are fenced under.
    pub epoch: u64,
}

/// Everything a worker thread needs.
pub struct WorkerCtx {
    pub rank: usize,
    pub bundle: Arc<ModelBundle>,
    pub data: DataIterator,
    pub collective: Arc<Collective>,
    pub cmd_rx: Receiver<WorkerCommand>,
    pub event_tx: Sender<WorkerEvent>,
    pub board: Arc<MonitorBoard>,
    pub failure: Option<FailurePlan>,
    /// Periodic checkpointing (vanilla baseline); rank 0 writes.
    pub ckpt: Option<CheckpointManager>,
    pub ckpt_interval: u64,
    pub state: WorkerState,
    pub max_steps: u64,
    /// Replacement workers start parked, awaiting RestoreState.
    pub start_parked: bool,
    /// Redundancy tier: stripe shipping after the optimizer step.
    pub redundancy: Option<RedundancyHook>,
}

enum Disposition {
    KeepRunning,
    Exit,
}

/// Worker thread entry point.
pub fn worker_main(mut ctx: WorkerCtx) {
    struct AliveGuard(Arc<MonitorBoard>);
    impl Drop for AliveGuard {
        fn drop(&mut self) {
            self.0.alive.store(false, Ordering::SeqCst);
        }
    }
    let _guard = AliveGuard(ctx.board.clone());

    if ctx.start_parked {
        let _ = ctx.event_tx.send(WorkerEvent::Parked {
            rank: ctx.rank,
            state_step: ctx.state.step,
            err: CollectiveError::Poisoned,
        });
        if matches!(park(&mut ctx), Disposition::Exit) {
            return;
        }
    }

    loop {
        // Non-blocking command drain between steps.
        while let Ok(cmd) = ctx.cmd_rx.try_recv() {
            match cmd {
                WorkerCommand::Stop => {
                    send_stopped(&ctx);
                    return;
                }
                WorkerCommand::Continue { .. } => {} // already running
                _ => unreachable!("state transfer commands only while parked"),
            }
        }
        if ctx.state.step >= ctx.max_steps {
            send_stopped(&ctx);
            return;
        }

        match run_one_step(&mut ctx) {
            StepOutcome::Completed => {}
            StepOutcome::Died => return, // silent: simulated process death
            StepOutcome::CollectiveBroken(err) => {
                let _ = ctx.event_tx.send(WorkerEvent::Parked {
                    rank: ctx.rank,
                    state_step: ctx.state.step,
                    err,
                });
                if matches!(park(&mut ctx), Disposition::Exit) {
                    return;
                }
            }
            StepOutcome::Fatal(e) => {
                log::error("worker", || format!("rank {}: fatal: {e:#}", ctx.rank));
                return;
            }
        }
    }
}

fn send_stopped(ctx: &WorkerCtx) {
    let _ = ctx.event_tx.send(WorkerEvent::Stopped {
        rank: ctx.rank,
        state_step: ctx.state.step,
        param_hash: ctx.state.param_hash().unwrap_or(0),
    });
}

enum StepOutcome {
    Completed,
    Died,
    CollectiveBroken(CollectiveError),
    Fatal(anyhow::Error),
}

fn should_die(ctx: &WorkerCtx, phase: Phase) -> Option<FailureKind> {
    ctx.failure
        .filter(|f| f.rank == ctx.rank && f.step == ctx.state.step && f.phase == phase)
        .map(|f| f.kind)
}

/// Global epoch for death/detection latency bookkeeping.
pub fn epoch() -> Instant {
    static EPOCH: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Milliseconds since the global epoch.
pub fn now_ms() -> u64 {
    epoch().elapsed().as_millis() as u64
}

fn die(ctx: &WorkerCtx, kind: FailureKind) {
    ctx.board.death_at_ms.store(now_ms().max(1), Ordering::SeqCst);
    // Hardware failures are visible to the device plugin immediately;
    // software deaths are only detectable as lost liveness.
    if kind.category() == FailureCategory::Hardware {
        ctx.board.device_error.store(kind_code(kind), Ordering::SeqCst);
    }
    // alive -> false via the guard when the thread unwinds.
}

fn run_one_step(ctx: &mut WorkerCtx) -> StepOutcome {
    let step = ctx.state.step;
    // Rule 1: tag = i at the beginning of forward.
    ctx.board.step_tag.store(step as i64, Ordering::SeqCst);

    // ---- forward/backward (PJRT) -------------------------------------
    if let Some(kind) = should_die(ctx, Phase::FwdBwd) {
        die(ctx, kind);
        return StepOutcome::Died;
    }
    let m = &ctx.bundle.manifest;
    let tokens_host = ctx.data.batch_for(step, ctx.rank);
    let tokens = match literal_tokens(m.dims.batch, m.dims.seq + 1, &tokens_host) {
        Ok(t) => t,
        Err(e) => return StepOutcome::Fatal(e),
    };
    let (loss, grads) = match ctx.bundle.run_fwd_bwd(&ctx.state.params, &tokens) {
        Ok(r) => r,
        Err(e) => return StepOutcome::Fatal(e),
    };

    // ---- gradient allreduce == the pre-optimizer barrier --------------
    let mut flat = match flatten_grads(&grads) {
        Ok(f) => f,
        Err(e) => return StepOutcome::Fatal(e),
    };
    if let Err(err) = ctx.collective.allreduce_mean(&mut flat) {
        return StepOutcome::CollectiveBroken(err);
    }
    let grads = match unflatten_grads(ctx, &flat) {
        Ok(g) => g,
        Err(e) => return StepOutcome::Fatal(e),
    };

    // Rule 4: tag = -1 at the beginning of the optimizer step.
    ctx.board.step_tag.store(TAG_OPTIMIZER, Ordering::SeqCst);
    if let Some(kind) = should_die(ctx, Phase::OptStep) {
        die(ctx, kind);
        return StepOutcome::Died;
    }

    // ---- optimizer step (PJRT) ----------------------------------------
    let (p, mm, vv) = match ctx.bundle.run_opt_step(
        &ctx.state.params,
        &ctx.state.m,
        &ctx.state.v,
        (step + 1) as f32,
        &grads,
    ) {
        Ok(r) => r,
        Err(e) => return StepOutcome::Fatal(e),
    };
    ctx.state.params = p;
    ctx.state.m = mm;
    ctx.state.v = vv;
    ctx.state.step = step + 1;
    // Rule 5: tag = i + 1 once the optimizer step completes.
    ctx.board.step_tag.store((step + 1) as i64, Ordering::SeqCst);

    let _ = ctx.event_tx.send(WorkerEvent::Loss { rank: ctx.rank, step: step + 1, loss });

    // ---- redundancy tier: stripe shipping in idle step time ------------
    if let Some(hook) = ctx.redundancy.as_mut() {
        let lag = match hook.shipper.last_shipped_step() {
            Some(last) => ctx.state.step.saturating_sub(last),
            None => ctx.state.step,
        };
        crate::telemetry::global().gauge("redund.stripe_lag").set(lag as i64);
        if hook.interval <= 1 || ctx.state.step % hook.interval == 0 {
            let snap = match ctx.state.to_snapshot() {
                Ok(s) => s,
                Err(e) => return StepOutcome::Fatal(e),
            };
            match hook.shipper.ship(&snap, hook.epoch) {
                Ok(_) => {}
                Err(e) if e.retryable() => {
                    // superseded by a recovery epoch: drop this round;
                    // the controller re-fences the tier once the
                    // episode completes
                    log::debug("worker", || {
                        format!("rank {}: stripe ship superseded: {e}", ctx.rank)
                    });
                }
                Err(e) => {
                    return StepOutcome::Fatal(anyhow::anyhow!(
                        "rank {} stripe ship: {e}",
                        ctx.rank
                    ))
                }
            }
        }
    }

    // ---- periodic checkpoint (vanilla baseline) ------------------------
    if ctx.ckpt_interval > 0 && ctx.state.step % ctx.ckpt_interval == 0 {
        if let Some(mgr) = ctx.ckpt.as_mut() {
            let t0 = Instant::now();
            match ctx.state.to_snapshot() {
                Ok(snap) => {
                    if let Err(e) = mgr.checkpoint(ctx.state.step, snap.tensors) {
                        return StepOutcome::Fatal(e);
                    }
                    let _ = ctx.event_tx.send(WorkerEvent::CheckpointTaken {
                        rank: ctx.rank,
                        step: ctx.state.step,
                        k0_s: t0.elapsed().as_secs_f64(),
                    });
                }
                Err(e) => return StepOutcome::Fatal(e),
            }
        }
    }

    StepOutcome::Completed
}

/// Parked: blocking command loop during recovery. State transfers run
/// over the real stream plane; a failed transfer reports
/// `RestoreFailed` and keeps the worker parked (the controller decides
/// whether to retry the episode or tear down).
fn park(ctx: &mut WorkerCtx) -> Disposition {
    loop {
        let cmd = match ctx.cmd_rx.recv() {
            Ok(c) => c,
            Err(_) => return Disposition::Exit, // controller gone
        };
        match cmd {
            WorkerCommand::Stop => {
                send_stopped(ctx);
                return Disposition::Exit;
            }
            WorkerCommand::ServeState { listener, shard, epoch, receivers, fence, trace } => {
                match serve_shard(ctx, &listener, shard, epoch, receivers, &fence, trace) {
                    Ok((bytes, wall_s)) => {
                        let _ = ctx.event_tx.send(WorkerEvent::StateServed {
                            rank: ctx.rank,
                            targets: receivers,
                            bytes,
                            wall_s,
                        });
                    }
                    Err(e) => {
                        log::warn("worker", || {
                            format!("rank {}: serve failed: {e}", ctx.rank)
                        });
                        let _ = ctx.event_tx.send(WorkerEvent::RestoreFailed {
                            rank: ctx.rank,
                            retryable: e.retryable(),
                            detail: e.to_string(),
                        });
                    }
                }
            }
            WorkerCommand::RestoreState {
                source_rank,
                source_addr,
                shard,
                epoch,
                expect_step,
                fence,
            } => match fetch_shard(ctx, source_addr, shard, epoch, expect_step, &fence) {
                Ok((bytes, wall_s)) => {
                    let _ = ctx.event_tx.send(WorkerEvent::StateRestored {
                        rank: ctx.rank,
                        shard,
                        source: source_rank,
                        bytes,
                        wall_s,
                    });
                }
                Err(e) => {
                    log::warn("worker", || {
                        format!("rank {}: restore failed: {e}", ctx.rank)
                    });
                    let _ = ctx.event_tx.send(WorkerEvent::RestoreFailed {
                        rank: ctx.rank,
                        retryable: e.retryable(),
                        detail: e.to_string(),
                    });
                }
            },
            WorkerCommand::Continue { resume_step } => {
                assert_eq!(
                    ctx.state.step, resume_step,
                    "worker {} resume step mismatch",
                    ctx.rank
                );
                ctx.board
                    .step_tag
                    .store(resume_step as i64, Ordering::SeqCst);
                return Disposition::KeepRunning;
            }
        }
    }
}

/// Source side of a shard transfer: snapshot once, then stream it to
/// each receiver in turn over the pre-bound listener (the fenced,
/// stall-bounded serve loop lives in `comms::state_stream`).
fn serve_shard(
    ctx: &WorkerCtx,
    listener: &TcpListener,
    shard: ShardId,
    epoch: u64,
    receivers: usize,
    fence: &EpochFence,
    trace: Option<TraceCtx>,
) -> Result<(u64, f64), RestoreError> {
    let snap = ctx
        .state
        .to_snapshot()
        .map_err(|e| RestoreError::Fatal(e.context("snapshot for serve")))?;
    let stats = serve_listener(
        listener,
        &snap,
        shard,
        epoch,
        receivers,
        fence,
        &StreamConfig { trace, ..Default::default() },
    )?;
    Ok((stats.bytes, stats.wall_s))
}

/// Target side of a shard transfer: claim nothing (the controller
/// already routed the source address), connect, fetch, install.
fn fetch_shard(
    ctx: &mut WorkerCtx,
    source_addr: SocketAddr,
    shard: ShardId,
    epoch: u64,
    expect_step: u64,
    fence: &EpochFence,
) -> Result<(u64, f64), RestoreError> {
    let expect = Expect { epoch, shard, step: Some(expect_step) };
    let (snap, stats) = fetch_from_addr(source_addr, &expect, fence)?;
    let state = WorkerState::from_snapshot(&ctx.bundle, &snap)
        .map_err(|e| RestoreError::Fatal(e.context("installing restored state")))?;
    ctx.state = state;
    Ok((stats.bytes, stats.wall_s))
}

/// Concatenate gradient literals into one flat f32 buffer (a single
/// fused allreduce, like gradient-bucket fusion in real frameworks).
pub fn flatten_grads(grads: &[xla::Literal]) -> Result<Vec<f32>> {
    let mut total = 0;
    for g in grads {
        total += g.element_count();
    }
    let mut flat = Vec::with_capacity(total);
    for g in grads {
        flat.extend(crate::runtime::to_f32_vec(g)?);
    }
    Ok(flat)
}

fn unflatten_grads(ctx: &WorkerCtx, flat: &[f32]) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(ctx.bundle.manifest.params.len());
    let mut pos = 0;
    for spec in &ctx.bundle.manifest.params {
        let n = spec.elements();
        out.push(crate::runtime::literal_f32(&spec.shape, &flat[pos..pos + n])?);
        pos += n;
    }
    anyhow::ensure!(pos == flat.len(), "gradient buffer size mismatch");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::tcp_store::TcpStoreServer;

    #[test]
    fn heartbeat_emitter_retries_until_store_is_up() {
        // Regression (§11 satellite): the emitter used to exit
        // silently when its first connect failed, so a worker that
        // started before the store was bound never leased in. The
        // bounded backoff must carry it across the gap.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe); // port free: the emitter's first connects fail

        let board = MonitorBoard::new();
        board.step_tag.store(4, Ordering::SeqCst);
        let hb = spawn_heartbeat(
            3,
            board.clone(),
            HeartbeatCfg {
                store: StoreEndpoints::one(addr),
                interval: Duration::from_millis(10),
                incarnation: 2,
            },
        );
        std::thread::sleep(Duration::from_millis(80));
        let server = TcpStoreServer::start_on(addr).expect("rebind probed port");

        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if let Some(b) = server.beats().iter().find(|b| b.rank == 3) {
                assert_eq!(b.incarnation, 2);
                assert_eq!(b.step_tag, 4);
                break;
            }
            assert!(
                Instant::now() < deadline,
                "emitter never leased in after the store came up"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        board.alive.store(false, Ordering::SeqCst);
        hb.join().unwrap();
    }

    #[test]
    fn heartbeat_emitter_walks_full_endpoint_set() {
        // Satellite bugfix: the backoff loop used to retry a single
        // address — a worker started during a primary crash never
        // leased in even though a live endpoint was one probe away.
        // With the first endpoint dead, the emitter must still reach
        // the second.
        let dead = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let dead_addr = dead.local_addr().unwrap();
        drop(dead); // nothing listens here again
        let live = TcpStoreServer::start().unwrap();

        let board = MonitorBoard::new();
        board.step_tag.store(9, Ordering::SeqCst);
        let hb = spawn_heartbeat(
            5,
            board.clone(),
            HeartbeatCfg {
                store: StoreEndpoints::new(vec![dead_addr, live.addr()]),
                interval: Duration::from_millis(10),
                incarnation: 1,
            },
        );
        let deadline = Instant::now() + Duration::from_secs(10);
        while !live.beats().iter().any(|b| b.rank == 5 && b.step_tag == 9) {
            assert!(
                Instant::now() < deadline,
                "emitter never walked past the dead endpoint"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        board.alive.store(false, Ordering::SeqCst);
        hb.join().unwrap();
    }

    #[test]
    fn node_agent_coalesces_beats_into_one_frame_per_interval() {
        let server = TcpStoreServer::start().unwrap();
        let members: Vec<NodeRank> = (0..4)
            .map(|rank| {
                let board = MonitorBoard::new();
                board.step_tag.store(7, Ordering::SeqCst);
                NodeRank { rank, incarnation: 1, board }
            })
            .collect();
        let boards: Vec<Arc<MonitorBoard>> =
            members.iter().map(|m| m.board.clone()).collect();
        let agent = spawn_node_heartbeat(
            members,
            NodeAgentCfg {
                store: server.endpoints(),
                interval: Duration::from_millis(10),
            },
        );
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.beats().len() < 4 {
            assert!(Instant::now() < deadline, "agent never pushed all ranks");
            std::thread::sleep(Duration::from_millis(5));
        }
        // coalescing: 4 ranks' beats ride one Batch frame per
        // interval, so logical ops outnumber wire frames ~4x (one
        // frame is the session's discovery probe, which carries no
        // logical store op)
        let snap = server.metrics_snapshot();
        let frames = snap.counter("store.frames").saturating_sub(1);
        let requests = snap.counter("store.requests").saturating_sub(1);
        assert!(
            requests >= 3 * frames,
            "beats must be coalesced: {requests} ops over {frames} frames"
        );
        for b in boards.iter() {
            b.alive.store(false, Ordering::SeqCst);
        }
        agent.join().unwrap();
    }

    #[test]
    fn node_agent_dying_gasp_carries_device_code() {
        let server = TcpStoreServer::start().unwrap();
        let victim = MonitorBoard::new();
        let peer = MonitorBoard::new();
        let members = vec![
            NodeRank { rank: 0, incarnation: 1, board: victim.clone() },
            NodeRank { rank: 1, incarnation: 1, board: peer.clone() },
        ];
        let agent = spawn_node_heartbeat(
            members,
            NodeAgentCfg {
                store: server.endpoints(),
                interval: Duration::from_millis(10),
            },
        );
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.beats().len() < 2 {
            assert!(Instant::now() < deadline, "agent never pushed");
            std::thread::sleep(Duration::from_millis(5));
        }
        // hardware death: the code is stored before alive drops, and
        // the agent's next batch must still carry it
        let code = kind_code(FailureKind::Network);
        victim.device_error.store(code, Ordering::SeqCst);
        victim.alive.store(false, Ordering::SeqCst);
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let beats = server.beats();
            let b = beats.iter().find(|b| b.rank == 0).unwrap();
            if b.device_code == code {
                break;
            }
            assert!(Instant::now() < deadline, "dying gasp never reached the wire");
            std::thread::sleep(Duration::from_millis(5));
        }
        peer.alive.store(false, Ordering::SeqCst);
        agent.join().unwrap();
    }

    #[test]
    fn node_agent_survives_primary_failover_between_beats() {
        // Regression: a beat interval that fires mid-failover used to
        // kill the agent on the first failed flush — every lease on
        // the node then expired even though a replica was standing by.
        // The failed tick must coalesce into the next interval's batch
        // and keep beating on the promoted survivor.
        let mut set = crate::comms::ReplicaSet::start(1).unwrap();
        let members: Vec<NodeRank> = (0..2)
            .map(|rank| {
                let board = MonitorBoard::new();
                board.step_tag.store(1, Ordering::SeqCst);
                NodeRank { rank, incarnation: 1, board }
            })
            .collect();
        let boards: Vec<Arc<MonitorBoard>> =
            members.iter().map(|m| m.board.clone()).collect();
        let agent = spawn_node_heartbeat(
            members,
            NodeAgentCfg {
                store: set.endpoints(),
                interval: Duration::from_millis(10),
            },
        );
        let deadline = Instant::now() + Duration::from_secs(10);
        while set.primary_server().unwrap().beats().len() < 2 {
            assert!(Instant::now() < deadline, "agent never leased in");
            std::thread::sleep(Duration::from_millis(5));
        }
        // Kill the primary between two beats: the next flush fails
        // against the dead endpoint while the session re-discovers.
        set.kill_primary();
        for b in boards.iter() {
            b.step_tag.store(2, Ordering::SeqCst);
        }
        // Both ranks' post-kill beats must land on the promoted
        // replica — the tick was carried, not dropped, so the lease
        // keeps refreshing across the failover window.
        let survivor = &set.replica_servers()[0];
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let beats = survivor.beats();
            let fresh = (0..2u64).all(|r| {
                beats.iter().any(|b| b.rank == r && b.step_tag == 2)
            });
            if fresh {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "beats never resumed on the promoted replica: {beats:?}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        for b in boards.iter() {
            b.alive.store(false, Ordering::SeqCst);
        }
        agent.join().unwrap();
    }
}
