//! Pure-Rust erasure codec for the in-memory redundancy tier
//! (DESIGN.md §16): a shard's canonical snapshot encoding is split
//! into `k` equal data stripes plus `m` parity stripes such that *any*
//! `k` of the `k+m` stripes reconstruct the original bytes exactly.
//!
//! The code is a systematic Reed-Solomon-lite over GF(256)
//! (polynomial 0x11d, the AES/QR field):
//!
//! * `m == 1` uses the plain XOR parity row (all-ones coefficients) —
//!   the RAID-5 fast path, still MDS for a single erasure;
//! * `m >= 2` uses a Cauchy parity matrix `C[j][i] = 1/(x_j ^ y_i)`
//!   with `y_i = i` (data rows) and `x_j = k + j` (parity rows). Every
//!   square submatrix of a Cauchy matrix is nonsingular, so any `k`
//!   surviving rows of the generator `[I; C]` are invertible —
//!   the "any k of k+m" guarantee reconstruction relies on.
//!
//! Decoding inverts the k×k survivor matrix with Gauss-Jordan over
//! GF(256). Everything is table-driven byte arithmetic — zero external
//! crates, no unsafe.

use anyhow::{bail, ensure, Result};
use std::sync::OnceLock;

/// Field polynomial for GF(2^8): x^8 + x^4 + x^3 + x^2 + 1.
const GF_POLY: u16 = 0x11d;

/// log/exp tables for GF(256); exp is doubled so `exp[log a + log b]`
/// never needs a modulo.
struct Tables {
    log: [u8; 256],
    exp: [u8; 512],
}

fn tables() -> &'static Tables {
    static T: OnceLock<Tables> = OnceLock::new();
    T.get_or_init(|| {
        let mut log = [0u8; 256];
        let mut exp = [0u8; 512];
        let mut x: u16 = 1;
        for i in 0..255 {
            exp[i] = x as u8;
            log[x as usize] = i as u8;
            x <<= 1;
            if x & 0x100 != 0 {
                x ^= GF_POLY;
            }
        }
        for i in 255..512 {
            exp[i] = exp[i - 255];
        }
        Tables { log, exp }
    })
}

/// GF(256) multiply.
#[inline]
fn gf_mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    let t = tables();
    t.exp[t.log[a as usize] as usize + t.log[b as usize] as usize]
}

/// GF(256) multiplicative inverse (`a != 0`).
#[inline]
fn gf_inv(a: u8) -> u8 {
    debug_assert_ne!(a, 0, "gf_inv(0)");
    let t = tables();
    t.exp[255 - t.log[a as usize] as usize]
}

/// `dst[i] ^= c * src[i]` — the hot loop of both encode and decode.
fn gf_mul_acc(dst: &mut [u8], src: &[u8], c: u8) {
    match c {
        0 => {}
        1 => {
            for (d, s) in dst.iter_mut().zip(src) {
                *d ^= s;
            }
        }
        _ => {
            let t = tables();
            let lc = t.log[c as usize] as usize;
            for (d, s) in dst.iter_mut().zip(src) {
                if *s != 0 {
                    *d ^= t.exp[lc + t.log[*s as usize] as usize];
                }
            }
        }
    }
}

/// Stripe-count shape of the code: `k` data stripes, `m` parity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ErasureConfig {
    pub k: usize,
    pub m: usize,
}

impl Default for ErasureConfig {
    /// 2+1: tolerate any single stripe-holder loss at 50% overhead —
    /// the smallest shape that exercises real parity.
    fn default() -> Self {
        ErasureConfig { k: 2, m: 1 }
    }
}

impl ErasureConfig {
    pub fn new(k: usize, m: usize) -> Result<Self> {
        let cfg = ErasureConfig { k, m };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.k >= 1, "erasure k must be >= 1 (got {})", self.k);
        ensure!(self.m >= 1, "erasure m must be >= 1 (got {})", self.m);
        // Cauchy evaluation points y_i = i (i < k) and x_j = k + j must
        // all be distinct field elements.
        ensure!(
            self.k + self.m <= 255,
            "erasure k+m must fit GF(256) ({}+{} > 255)",
            self.k,
            self.m
        );
        Ok(())
    }

    /// Total stripes produced per shard.
    pub fn total(&self) -> usize {
        self.k + self.m
    }

    /// Stripe length for a payload of `data_len` bytes (zero-padded to
    /// a k-multiple).
    pub fn stripe_len(&self, data_len: usize) -> usize {
        data_len.div_ceil(self.k)
    }

    /// Parity coefficient for parity row `j`, data column `i`.
    fn coeff(&self, j: usize, i: usize) -> u8 {
        if self.m == 1 {
            1 // XOR fast path: RAID-5 parity row
        } else {
            gf_inv(((self.k + j) ^ i) as u8)
        }
    }
}

/// Encode `data` into `k + m` stripes (k data, then m parity), each
/// `stripe_len(data.len())` bytes; the last data stripe is zero-padded.
pub fn encode_stripes(data: &[u8], cfg: &ErasureConfig) -> Result<Vec<Vec<u8>>> {
    cfg.validate()?;
    let sl = cfg.stripe_len(data.len());
    let mut stripes = Vec::with_capacity(cfg.total());
    for i in 0..cfg.k {
        let start = (i * sl).min(data.len());
        let end = ((i + 1) * sl).min(data.len());
        let mut s = data[start..end].to_vec();
        s.resize(sl, 0);
        stripes.push(s);
    }
    for j in 0..cfg.m {
        let mut p = vec![0u8; sl];
        for i in 0..cfg.k {
            gf_mul_acc(&mut p, &stripes[i], cfg.coeff(j, i));
        }
        stripes.push(p);
    }
    Ok(stripes)
}

/// Reconstruct the original `data_len` bytes from any `k` surviving
/// stripes. `stripes[i]` is `Some` when stripe `i` (data for `i < k`,
/// parity otherwise) survived; all present stripes must share one
/// length consistent with `data_len`.
pub fn reconstruct(
    stripes: &[Option<Vec<u8>>],
    cfg: &ErasureConfig,
    data_len: usize,
) -> Result<Vec<u8>> {
    cfg.validate()?;
    ensure!(
        stripes.len() == cfg.total(),
        "expected {} stripe slots, got {}",
        cfg.total(),
        stripes.len()
    );
    let sl = cfg.stripe_len(data_len);
    let present: Vec<usize> = (0..stripes.len()).filter(|&i| stripes[i].is_some()).collect();
    ensure!(
        present.len() >= cfg.k,
        "need {} stripes to reconstruct, only {} survive",
        cfg.k,
        present.len()
    );
    for &i in &present {
        let got = stripes[i].as_ref().unwrap().len();
        ensure!(
            got == sl,
            "stripe {i} length {got} != expected {sl} for data_len {data_len}"
        );
    }
    if data_len == 0 {
        return Ok(Vec::new());
    }

    // Fast path: every data stripe survived — concatenate.
    if (0..cfg.k).all(|i| stripes[i].is_some()) {
        return Ok(concat_data(stripes, cfg, data_len, sl));
    }

    // Take the first k surviving rows of the generator [I; C] and
    // invert that k×k system over GF(256).
    let rows = &present[..cfg.k];
    let k = cfg.k;
    // a = survivor rows; inv starts as identity and receives a^-1.
    let mut a = vec![vec![0u8; k]; k];
    let mut inv = vec![vec![0u8; k]; k];
    for (r, &idx) in rows.iter().enumerate() {
        if idx < k {
            a[r][idx] = 1;
        } else {
            for i in 0..k {
                a[r][i] = cfg.coeff(idx - k, i);
            }
        }
        inv[r][r] = 1;
    }
    // Gauss-Jordan with partial pivoting (any nonzero pivot works in a
    // field; Cauchy structure guarantees one exists).
    for col in 0..k {
        let Some(pivot) = (col..k).find(|&r| a[r][col] != 0) else {
            bail!("singular survivor matrix (rows {rows:?})");
        };
        a.swap(col, pivot);
        inv.swap(col, pivot);
        let pinv = gf_inv(a[col][col]);
        for i in 0..k {
            a[col][i] = gf_mul(a[col][i], pinv);
            inv[col][i] = gf_mul(inv[col][i], pinv);
        }
        for r in 0..k {
            if r != col && a[r][col] != 0 {
                let f = a[r][col];
                for i in 0..k {
                    a[r][i] ^= gf_mul(f, a[col][i]);
                    inv[r][i] ^= gf_mul(f, inv[col][i]);
                }
            }
        }
    }

    // data_i = Σ_r inv[i][r] * survivor_r  (byte-wise).
    let mut data = vec![0u8; k * sl];
    for i in 0..k {
        let dst = &mut data[i * sl..(i + 1) * sl];
        for (r, &idx) in rows.iter().enumerate() {
            gf_mul_acc(dst, stripes[idx].as_ref().unwrap(), inv[i][r]);
        }
    }
    data.truncate(data_len);
    Ok(data)
}

fn concat_data(
    stripes: &[Option<Vec<u8>>],
    cfg: &ErasureConfig,
    data_len: usize,
    sl: usize,
) -> Vec<u8> {
    let mut data = Vec::with_capacity(cfg.k * sl);
    for s in stripes.iter().take(cfg.k) {
        data.extend_from_slice(s.as_ref().unwrap());
    }
    data.truncate(data_len);
    data
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic xorshift bytes — tests stay reproducible.
    fn bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut x = seed | 1;
        (0..n)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x >> 24) as u8
            })
            .collect()
    }

    #[test]
    fn field_algebra_holds() {
        // every nonzero element has an inverse and mul round-trips it
        for a in 1..=255u8 {
            assert_eq!(gf_mul(a, gf_inv(a)), 1, "a={a}");
            assert_eq!(gf_mul(a, 1), a);
            assert_eq!(gf_mul(a, 0), 0);
        }
        // distributivity spot-check over the generator walk
        for a in [3u8, 29, 127, 200] {
            for b in [5u8, 77, 255] {
                for c in [9u8, 64] {
                    assert_eq!(gf_mul(a, b ^ c), gf_mul(a, b) ^ gf_mul(a, c));
                }
            }
        }
    }

    #[test]
    fn xor_fast_path_is_plain_parity() {
        let cfg = ErasureConfig::new(3, 1).unwrap();
        let data = bytes(301, 7);
        let stripes = encode_stripes(&data, &cfg).unwrap();
        assert_eq!(stripes.len(), 4);
        let sl = cfg.stripe_len(data.len());
        for b in 0..sl {
            assert_eq!(
                stripes[3][b],
                stripes[0][b] ^ stripes[1][b] ^ stripes[2][b]
            );
        }
    }

    #[test]
    fn every_erasure_pattern_reconstructs_bit_exact() {
        // k=3, m=2: all C(5,>=3) survivor subsets must round-trip.
        let cfg = ErasureConfig::new(3, 2).unwrap();
        let data = bytes(1000, 42); // not a k-multiple: exercises padding
        let stripes = encode_stripes(&data, &cfg).unwrap();
        for mask in 0u32..32 {
            if mask.count_ones() < 3 {
                continue;
            }
            let subset: Vec<Option<Vec<u8>>> = (0..5)
                .map(|i| {
                    if mask & (1 << i) != 0 {
                        Some(stripes[i].clone())
                    } else {
                        None
                    }
                })
                .collect();
            let back = reconstruct(&subset, &cfg, data.len()).unwrap();
            assert_eq!(back, data, "mask {mask:05b}");
        }
    }

    #[test]
    fn single_parity_covers_any_single_loss() {
        let cfg = ErasureConfig::default(); // 2+1
        let data = bytes(513, 9);
        let stripes = encode_stripes(&data, &cfg).unwrap();
        for lost in 0..3 {
            let subset: Vec<Option<Vec<u8>>> = (0..3)
                .map(|i| if i == lost { None } else { Some(stripes[i].clone()) })
                .collect();
            assert_eq!(reconstruct(&subset, &cfg, data.len()).unwrap(), data);
        }
    }

    #[test]
    fn wide_shapes_round_trip() {
        // a wider Cauchy shape, losing exactly m stripes
        let cfg = ErasureConfig::new(5, 3).unwrap();
        let data = bytes(4096, 1234);
        let mut stripes: Vec<Option<Vec<u8>>> =
            encode_stripes(&data, &cfg).unwrap().into_iter().map(Some).collect();
        stripes[0] = None; // a data stripe
        stripes[4] = None; // another data stripe
        stripes[6] = None; // a parity stripe
        assert_eq!(reconstruct(&stripes, &cfg, data.len()).unwrap(), data);
    }

    #[test]
    fn insufficient_survivors_is_an_error() {
        let cfg = ErasureConfig::new(2, 1).unwrap();
        let data = bytes(100, 3);
        let stripes = encode_stripes(&data, &cfg).unwrap();
        let subset = vec![Some(stripes[0].clone()), None, None];
        let err = reconstruct(&subset, &cfg, data.len()).unwrap_err();
        assert!(err.to_string().contains("only 1 survive"), "{err}");
    }

    #[test]
    fn length_and_shape_mismatches_are_errors() {
        let cfg = ErasureConfig::new(2, 1).unwrap();
        let data = bytes(64, 5);
        let stripes = encode_stripes(&data, &cfg).unwrap();
        // wrong slot count
        let short = vec![Some(stripes[0].clone()), Some(stripes[1].clone())];
        assert!(reconstruct(&short, &cfg, data.len()).is_err());
        // torn stripe (wrong length) must be rejected, not decoded
        let mut torn = stripes.clone();
        torn[1].truncate(10);
        let slots: Vec<Option<Vec<u8>>> = torn.into_iter().map(Some).collect();
        assert!(reconstruct(&slots, &cfg, data.len()).is_err());
        // invalid shapes
        assert!(ErasureConfig::new(0, 1).is_err());
        assert!(ErasureConfig::new(1, 0).is_err());
        assert!(ErasureConfig::new(200, 80).is_err());
    }

    #[test]
    fn empty_payload_round_trips() {
        let cfg = ErasureConfig::new(2, 1).unwrap();
        let stripes = encode_stripes(&[], &cfg).unwrap();
        assert!(stripes.iter().all(|s| s.is_empty()));
        let slots: Vec<Option<Vec<u8>>> = stripes.into_iter().map(Some).collect();
        assert_eq!(reconstruct(&slots, &cfg, 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn snapshot_encoding_survives_stripe_loss_bit_exact() {
        // end-to-end with the canonical snapshot codec: the property
        // the redundancy tier stakes recovery on
        use crate::checkpoint::{codec, Snapshot};
        let snap = Snapshot {
            step: 17,
            tensors: vec![bytes(400, 11).iter().map(|&b| b as f32 * 0.5).collect()],
        };
        let encoded = codec::encode_snapshot(&snap);
        let cfg = ErasureConfig::new(3, 2).unwrap();
        let mut stripes: Vec<Option<Vec<u8>>> =
            encode_stripes(&encoded, &cfg).unwrap().into_iter().map(Some).collect();
        stripes[1] = None;
        stripes[2] = None;
        let back = reconstruct(&stripes, &cfg, encoded.len()).unwrap();
        let decoded = codec::decode_snapshot(&back).unwrap();
        assert_eq!(decoded, snap);
        assert_eq!(decoded.content_hash(), snap.content_hash());
    }
}
