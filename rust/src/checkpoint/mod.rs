//! Two-phase periodic checkpointing — the baseline FlashRecovery makes
//! unnecessary (paper §II, Fig. 1/2).
//!
//! * **k0 (snapshot)**: copy device state into host memory. Training is
//!   stalled for this phase; its duration is the `k0` of eq. (1).
//! * **k1 (persist)**: write the snapshot to storage. May run on a
//!   background thread, overlapping training (`k1` "negligible").
//!
//! Binary format: `FLSH` magic, version, step, tensor count, then each
//! tensor as `u64 len | f32 data`, followed by an FNV-1a checksum over
//! everything before it. A truncated or bit-flipped file fails to load —
//! exercised by the failure-injection tests.

use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

const MAGIC: &[u8; 4] = b"FLSH";
const VERSION: u32 = 2; // v2: word-wise checksum (§Perf optimization 2)

/// Host-memory model state: one training rank's params + Adam moments.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub step: u64,
    /// params ++ m ++ v, each tensor a flat f32 vec in manifest order.
    pub tensors: Vec<Vec<f32>>,
}

impl Snapshot {
    pub fn total_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.len() * 4).sum()
    }
}

/// Word-wise mixing checksum (FNV-style but 8 bytes per round): byte-
/// at-a-time FNV costs ~2 ms/MB which dominates replica-restore encode
/// at tens of MB of model state; this runs ~8x faster with the same
/// bit-flip detection guarantees for our purposes.
fn fnv1a(data: &[u8], mut hash: u64) -> u64 {
    const K: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        hash = (hash ^ u64::from_le_bytes(c.try_into().unwrap())).wrapping_mul(K);
        hash ^= hash >> 29;
    }
    for b in chunks.remainder() {
        hash = (hash ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
    hash
}

/// Serialize a snapshot into any writer (file persist or the replica-
/// broadcast byte stream used by checkpoint-free recovery).
pub fn write_snapshot_to<W: Write>(mut w: W, snap: &Snapshot) -> Result<()> {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    let put = |w: &mut W, bytes: &[u8], hash: &mut u64| -> Result<()> {
        *hash = fnv1a(bytes, *hash);
        w.write_all(bytes)?;
        Ok(())
    };
    put(&mut w, MAGIC, &mut hash)?;
    put(&mut w, &VERSION.to_le_bytes(), &mut hash)?;
    put(&mut w, &snap.step.to_le_bytes(), &mut hash)?;
    put(&mut w, &(snap.tensors.len() as u64).to_le_bytes(), &mut hash)?;
    let mut buf = Vec::new();
    for t in &snap.tensors {
        put(&mut w, &(t.len() as u64).to_le_bytes(), &mut hash)?;
        // f32 slice -> bytes without bytemuck: fixed-size chunk copies
        // the compiler vectorises (§Perf optimization 3).
        buf.resize(t.len() * 4, 0);
        for (dst, x) in buf.chunks_exact_mut(4).zip(t.iter()) {
            dst.copy_from_slice(&x.to_le_bytes());
        }
        put(&mut w, &buf, &mut hash)?;
    }
    w.write_all(&hash.to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Serialize a snapshot to `path` (the k1 persist phase).
pub fn write_snapshot(path: &Path, snap: &Snapshot) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {tmp:?}"))?;
        write_snapshot_to(BufWriter::new(f), snap)?;
    }
    // Atomic rename so a crash mid-persist never corrupts the latest.
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Snapshot -> bytes (replica transfer payload).
pub fn encode_snapshot(snap: &Snapshot) -> Vec<u8> {
    let mut buf = Vec::with_capacity(snap.total_bytes() + 64);
    write_snapshot_to(&mut buf, snap).expect("vec write cannot fail");
    buf
}

/// Load + verify a snapshot from any reader.
pub fn read_snapshot_from<R: Read>(mut r: R) -> Result<Snapshot> {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;

    let take = |r: &mut R, n: usize, hash: &mut u64| -> Result<Vec<u8>> {
        let mut buf = vec![0u8; n];
        r.read_exact(&mut buf)?;
        *hash = fnv1a(&buf, *hash);
        Ok(buf)
    };

    let magic = take(&mut r, 4, &mut hash)?;
    if magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let version = u32::from_le_bytes(take(&mut r, 4, &mut hash)?.try_into().unwrap());
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let step = u64::from_le_bytes(take(&mut r, 8, &mut hash)?.try_into().unwrap());
    let count = u64::from_le_bytes(take(&mut r, 8, &mut hash)?.try_into().unwrap()) as usize;
    if count > 1_000_000 {
        bail!("implausible tensor count {count}");
    }
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let len = u64::from_le_bytes(take(&mut r, 8, &mut hash)?.try_into().unwrap()) as usize;
        if len > (1usize << 33) {
            bail!("implausible tensor length {len}");
        }
        let bytes = take(&mut r, len * 4, &mut hash)?;
        let mut t = Vec::with_capacity(len);
        for c in bytes.chunks_exact(4) {
            t.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        tensors.push(t);
    }
    let mut stored = [0u8; 8];
    r.read_exact(&mut stored)?;
    if u64::from_le_bytes(stored) != hash {
        bail!("checkpoint checksum mismatch (corrupt file)");
    }
    Ok(Snapshot { step, tensors })
}

/// Load + verify a snapshot file.
pub fn read_snapshot(path: &Path) -> Result<Snapshot> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    read_snapshot_from(BufReader::new(f))
}

/// Bytes -> snapshot (replica transfer payload).
pub fn decode_snapshot(bytes: &[u8]) -> Result<Snapshot> {
    read_snapshot_from(std::io::Cursor::new(bytes))
}

/// Timing of one checkpoint operation.
#[derive(Debug, Clone, Copy)]
pub struct CheckpointTiming {
    /// k0: snapshot into host memory (training stalled).
    pub snapshot_s: f64,
    /// k1: persist to storage (possibly overlapped).
    pub persist_s: f64,
}

enum PersistMsg {
    Write(PathBuf, Snapshot),
    Stop,
}

/// Manages periodic checkpoints for one training rank.
pub struct CheckpointManager {
    dir: PathBuf,
    rank: usize,
    keep: usize,
    persist_tx: Option<Sender<PersistMsg>>,
    persist_thread: Option<JoinHandle<()>>,
    /// Timings of completed (k0, k1) pairs, for the overhead model.
    pub timings: Vec<CheckpointTiming>,
}

impl CheckpointManager {
    pub fn new(dir: impl Into<PathBuf>, rank: usize, keep: usize, async_persist: bool) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let (persist_tx, persist_thread) = if async_persist {
            let (tx, rx) = channel::<PersistMsg>();
            let handle = std::thread::spawn(move || {
                while let Ok(PersistMsg::Write(path, snap)) = rx.recv() {
                    // Persist errors are logged, not fatal: the paper's k1
                    // overlaps training and failures surface on load.
                    if let Err(e) = write_snapshot(&path, &snap) {
                        eprintln!("[checkpoint] persist failed: {e:#}");
                    }
                }
            });
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };
        Ok(CheckpointManager {
            dir,
            rank,
            keep: keep.max(1),
            persist_tx,
            persist_thread,
            timings: Vec::new(),
        })
    }

    fn path_for(&self, step: u64) -> PathBuf {
        self.dir.join(format!("ckpt-rank{}-step{:010}.bin", self.rank, step))
    }

    /// Take a checkpoint: k0 builds the snapshot (blocking — the caller
    /// is the training loop, so this stall is the paper's k0), k1
    /// persists either inline or on the background thread.
    pub fn checkpoint(&mut self, step: u64, tensors: Vec<Vec<f32>>) -> Result<CheckpointTiming> {
        let t0 = Instant::now();
        let snap = Snapshot { step, tensors };
        let snapshot_s = t0.elapsed().as_secs_f64();

        let path = self.path_for(step);
        let t1 = Instant::now();
        let persist_s = match &self.persist_tx {
            Some(tx) => {
                tx.send(PersistMsg::Write(path, snap))
                    .map_err(|_| anyhow::anyhow!("persist thread gone"))?;
                0.0 // overlapped
            }
            None => {
                write_snapshot(&path, &snap)?;
                t1.elapsed().as_secs_f64()
            }
        };
        let timing = CheckpointTiming { snapshot_s, persist_s };
        self.timings.push(timing);
        self.prune()?;
        Ok(timing)
    }

    /// Wait for all queued persists to land (used before failover reads
    /// and in tests).
    pub fn drain(&mut self) {
        if let Some(tx) = self.persist_tx.take() {
            let _ = tx.send(PersistMsg::Stop);
            drop(tx);
            if let Some(h) = self.persist_thread.take() {
                let _ = h.join();
            }
        }
    }

    fn list(&self) -> Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        let prefix = format!("ckpt-rank{}-step", self.rank);
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            let name = match path.file_name().and_then(|n| n.to_str()) {
                Some(n) => n,
                None => continue,
            };
            if let Some(rest) = name.strip_prefix(&prefix) {
                if let Some(step_s) = rest.strip_suffix(".bin") {
                    if let Ok(step) = step_s.parse::<u64>() {
                        out.push((step, path.clone()));
                    }
                }
            }
        }
        out.sort();
        Ok(out)
    }

    fn prune(&self) -> Result<()> {
        let all = self.list()?;
        if all.len() > self.keep {
            for (_, path) in &all[..all.len() - self.keep] {
                std::fs::remove_file(path).ok();
            }
        }
        Ok(())
    }

    /// Load the most recent valid checkpoint (skipping corrupt files,
    /// which a mid-persist failure can produce).
    pub fn load_latest(&self) -> Result<Option<Snapshot>> {
        for (_, path) in self.list()?.into_iter().rev() {
            match read_snapshot(&path) {
                Ok(s) => return Ok(Some(s)),
                Err(e) => eprintln!("[checkpoint] skipping {path:?}: {e:#}"),
            }
        }
        Ok(None)
    }
}

impl Drop for CheckpointManager {
    fn drop(&mut self) {
        self.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::temp_dir;

    fn snap(step: u64) -> Snapshot {
        Snapshot {
            step,
            tensors: vec![vec![1.0, 2.0, 3.0], vec![step as f32; 5]],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = temp_dir("ckpt").unwrap();
        let path = dir.join("a.bin");
        write_snapshot(&path, &snap(7)).unwrap();
        let back = read_snapshot(&path).unwrap();
        assert_eq!(back, snap(7));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn byte_roundtrip_for_replica_transfer() {
        let s = snap(42);
        let bytes = encode_snapshot(&s);
        assert_eq!(decode_snapshot(&bytes).unwrap(), s);
        // corruption detected in the byte path too
        let mut bad = bytes.clone();
        bad[10] ^= 0x40;
        assert!(decode_snapshot(&bad).is_err());
    }

    #[test]
    fn detects_corruption() {
        let dir = temp_dir("ckpt").unwrap();
        let path = dir.join("a.bin");
        write_snapshot(&path, &snap(7)).unwrap();
        // flip one byte in the middle
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_snapshot(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn detects_truncation() {
        let dir = temp_dir("ckpt").unwrap();
        let path = dir.join("a.bin");
        write_snapshot(&path, &snap(7)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(read_snapshot(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manager_keeps_latest_and_prunes() {
        let dir = temp_dir("ckpt").unwrap();
        let mut mgr = CheckpointManager::new(&dir, 0, 2, false).unwrap();
        for step in [10, 20, 30] {
            mgr.checkpoint(step, snap(step).tensors).unwrap();
        }
        let latest = mgr.load_latest().unwrap().unwrap();
        assert_eq!(latest.step, 30);
        // only `keep`=2 files remain
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(files.len(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn async_persist_lands_after_drain() {
        let dir = temp_dir("ckpt").unwrap();
        let mut mgr = CheckpointManager::new(&dir, 1, 2, true).unwrap();
        let t = mgr.checkpoint(5, snap(5).tensors).unwrap();
        assert_eq!(t.persist_s, 0.0); // overlapped
        mgr.drain();
        let latest = CheckpointManager::new(&dir, 1, 2, false)
            .unwrap()
            .load_latest()
            .unwrap()
            .unwrap();
        assert_eq!(latest.step, 5);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_latest_skips_corrupt_and_falls_back() {
        let dir = temp_dir("ckpt").unwrap();
        let mut mgr = CheckpointManager::new(&dir, 0, 5, false).unwrap();
        mgr.checkpoint(10, snap(10).tensors).unwrap();
        mgr.checkpoint(20, snap(20).tensors).unwrap();
        // corrupt the newest
        let newest = dir.join("ckpt-rank0-step0000000020.bin");
        let mut bytes = std::fs::read(&newest).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 1;
        std::fs::write(&newest, &bytes).unwrap();
        let latest = mgr.load_latest().unwrap().unwrap();
        assert_eq!(latest.step, 10);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn ranks_do_not_collide() {
        let dir = temp_dir("ckpt").unwrap();
        let mut m0 = CheckpointManager::new(&dir, 0, 2, false).unwrap();
        let mut m1 = CheckpointManager::new(&dir, 1, 2, false).unwrap();
        m0.checkpoint(1, snap(1).tensors).unwrap();
        m1.checkpoint(2, snap(2).tensors).unwrap();
        assert_eq!(m0.load_latest().unwrap().unwrap().step, 1);
        assert_eq!(m1.load_latest().unwrap().unwrap().step, 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_dir_yields_none() {
        let dir = temp_dir("ckpt").unwrap();
        let mgr = CheckpointManager::new(dir.join("sub"), 0, 2, false).unwrap();
        assert!(mgr.load_latest().unwrap().is_none());
        std::fs::remove_dir_all(dir).ok();
    }
}
