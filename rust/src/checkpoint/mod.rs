//! Two-phase periodic checkpointing — the baseline FlashRecovery makes
//! unnecessary (paper §II, Fig. 1/2) — plus the snapshot container the
//! checkpoint-free restore path streams between replicas.
//!
//! * **k0 (snapshot)**: copy device state into host memory. Training is
//!   stalled for this phase; its duration is the `k0` of eq. (1).
//! * **k1 (persist)**: write the snapshot to storage. May run on a
//!   background thread, overlapping training (`k1` "negligible").
//!
//! The binary format and the streaming encoder live in [`codec`]; both
//! the file persist path and `comms::state_stream` (chunked socket
//! transfer) share it, so a snapshot has exactly one canonical byte
//! encoding.

pub mod codec;
pub mod erasure;

pub use codec::{
    decode_snapshot, encode_snapshot, read_snapshot_from, write_snapshot_to,
    SnapshotStream,
};
pub use erasure::{encode_stripes, reconstruct, ErasureConfig};

use crate::util::hash::{fnv1a, fnv1a_f32, FNV_OFFSET};
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

/// Host-memory model state: one training rank's params + Adam moments.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub step: u64,
    /// params ++ m ++ v, each tensor a flat f32 vec in manifest order.
    pub tensors: Vec<Vec<f32>>,
}

impl Snapshot {
    pub fn total_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.len() * 4).sum()
    }

    /// Word-wise FNV over step + every tensor's exact bits (hashed in
    /// place, no byte copy): two snapshots with equal hashes are
    /// byte-identical replicas — the invariant checkpoint-free restore
    /// must preserve, mirrored by `WorkerState::param_hash` on the
    /// device side.
    pub fn content_hash(&self) -> u64 {
        let mut h = fnv1a(&self.step.to_le_bytes(), FNV_OFFSET);
        for t in &self.tensors {
            h = fnv1a_f32(t, h);
        }
        h
    }
}

/// Serialize a snapshot to `path` (the k1 persist phase).
pub fn write_snapshot(path: &Path, snap: &Snapshot) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {tmp:?}"))?;
        write_snapshot_to(BufWriter::new(f), snap)?;
    }
    // Atomic rename so a crash mid-persist never corrupts the latest.
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load + verify a snapshot file. Counts `ckpt.file_reads` on the
/// global registry — the §16 wipeout scenario asserts this stays flat
/// across a redundancy-tier recovery (zero checkpoint reads).
pub fn read_snapshot(path: &Path) -> Result<Snapshot> {
    crate::telemetry::global().inc("ckpt.file_reads");
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    read_snapshot_from(BufReader::new(f))
}

/// Timing of one checkpoint operation.
#[derive(Debug, Clone, Copy)]
pub struct CheckpointTiming {
    /// k0: snapshot into host memory (training stalled).
    pub snapshot_s: f64,
    /// k1: persist to storage (possibly overlapped).
    pub persist_s: f64,
}

enum PersistMsg {
    Write(PathBuf, Snapshot),
    Stop,
}

/// Manages periodic checkpoints for one training rank.
pub struct CheckpointManager {
    dir: PathBuf,
    rank: usize,
    keep: usize,
    persist_tx: Option<Sender<PersistMsg>>,
    persist_thread: Option<JoinHandle<()>>,
    /// Timings of completed (k0, k1) pairs, for the overhead model.
    pub timings: Vec<CheckpointTiming>,
}

impl CheckpointManager {
    pub fn new(
        dir: impl Into<PathBuf>,
        rank: usize,
        keep: usize,
        async_persist: bool,
    ) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let (persist_tx, persist_thread) = if async_persist {
            let (tx, rx) = channel::<PersistMsg>();
            let handle = std::thread::spawn(move || {
                while let Ok(PersistMsg::Write(path, snap)) = rx.recv() {
                    // Persist errors are logged, not fatal: the paper's k1
                    // overlaps training and failures surface on load.
                    if let Err(e) = write_snapshot(&path, &snap) {
                        eprintln!("[checkpoint] persist failed: {e:#}");
                    }
                }
            });
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };
        Ok(CheckpointManager {
            dir,
            rank,
            keep: keep.max(1),
            persist_tx,
            persist_thread,
            timings: Vec::new(),
        })
    }

    fn path_for(&self, step: u64) -> PathBuf {
        self.dir.join(format!("ckpt-rank{}-step{:010}.bin", self.rank, step))
    }

    /// Take a checkpoint: k0 builds the snapshot (blocking — the caller
    /// is the training loop, so this stall is the paper's k0), k1
    /// persists either inline or on the background thread.
    pub fn checkpoint(&mut self, step: u64, tensors: Vec<Vec<f32>>) -> Result<CheckpointTiming> {
        let t0 = Instant::now();
        let snap = Snapshot { step, tensors };
        let snapshot_s = t0.elapsed().as_secs_f64();

        let path = self.path_for(step);
        let t1 = Instant::now();
        let persist_s = match &self.persist_tx {
            Some(tx) => {
                tx.send(PersistMsg::Write(path, snap))
                    .map_err(|_| anyhow::anyhow!("persist thread gone"))?;
                0.0 // overlapped
            }
            None => {
                write_snapshot(&path, &snap)?;
                t1.elapsed().as_secs_f64()
            }
        };
        let timing = CheckpointTiming { snapshot_s, persist_s };
        self.timings.push(timing);
        self.prune()?;
        Ok(timing)
    }

    /// Wait for all queued persists to land (used before failover reads
    /// and in tests).
    pub fn drain(&mut self) {
        if let Some(tx) = self.persist_tx.take() {
            let _ = tx.send(PersistMsg::Stop);
            drop(tx);
            if let Some(h) = self.persist_thread.take() {
                let _ = h.join();
            }
        }
    }

    fn list(&self) -> Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        let prefix = format!("ckpt-rank{}-step", self.rank);
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            let name = match path.file_name().and_then(|n| n.to_str()) {
                Some(n) => n,
                None => continue,
            };
            if let Some(rest) = name.strip_prefix(&prefix) {
                if let Some(step_s) = rest.strip_suffix(".bin") {
                    if let Ok(step) = step_s.parse::<u64>() {
                        out.push((step, path.clone()));
                    }
                }
            }
        }
        out.sort();
        Ok(out)
    }

    fn prune(&self) -> Result<()> {
        let all = self.list()?;
        if all.len() > self.keep {
            for (_, path) in &all[..all.len() - self.keep] {
                std::fs::remove_file(path).ok();
            }
        }
        Ok(())
    }

    /// Load the most recent valid checkpoint (skipping corrupt files,
    /// which a mid-persist failure can produce).
    pub fn load_latest(&self) -> Result<Option<Snapshot>> {
        for (_, path) in self.list()?.into_iter().rev() {
            match read_snapshot(&path) {
                Ok(s) => return Ok(Some(s)),
                Err(e) => eprintln!("[checkpoint] skipping {path:?}: {e:#}"),
            }
        }
        Ok(None)
    }
}

impl Drop for CheckpointManager {
    fn drop(&mut self) {
        self.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::temp_dir;

    fn snap(step: u64) -> Snapshot {
        Snapshot {
            step,
            tensors: vec![vec![1.0, 2.0, 3.0], vec![step as f32; 5]],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = temp_dir("ckpt").unwrap();
        let path = dir.join("a.bin");
        write_snapshot(&path, &snap(7)).unwrap();
        let back = read_snapshot(&path).unwrap();
        assert_eq!(back, snap(7));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn byte_roundtrip_for_replica_transfer() {
        let s = snap(42);
        let bytes = encode_snapshot(&s);
        assert_eq!(decode_snapshot(&bytes).unwrap(), s);
        // corruption detected in the byte path too
        let mut bad = bytes.clone();
        bad[10] ^= 0x40;
        assert!(decode_snapshot(&bad).is_err());
    }

    #[test]
    fn content_hash_tracks_replica_identity() {
        let a = snap(9);
        let mut b = snap(9);
        assert_eq!(a.content_hash(), b.content_hash());
        b.tensors[1][2] += 1e-6;
        assert_ne!(a.content_hash(), b.content_hash());
        let mut c = snap(9);
        c.step = 10; // same bits, different step: not the same state
        assert_ne!(a.content_hash(), c.content_hash());
    }

    #[test]
    fn detects_corruption() {
        let dir = temp_dir("ckpt").unwrap();
        let path = dir.join("a.bin");
        write_snapshot(&path, &snap(7)).unwrap();
        // flip one byte in the middle
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_snapshot(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn detects_truncation() {
        let dir = temp_dir("ckpt").unwrap();
        let path = dir.join("a.bin");
        write_snapshot(&path, &snap(7)).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(read_snapshot(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manager_keeps_latest_and_prunes() {
        let dir = temp_dir("ckpt").unwrap();
        let mut mgr = CheckpointManager::new(&dir, 0, 2, false).unwrap();
        for step in [10, 20, 30] {
            mgr.checkpoint(step, snap(step).tensors).unwrap();
        }
        let latest = mgr.load_latest().unwrap().unwrap();
        assert_eq!(latest.step, 30);
        // only `keep`=2 files remain
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(files.len(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn async_persist_lands_after_drain() {
        let dir = temp_dir("ckpt").unwrap();
        let mut mgr = CheckpointManager::new(&dir, 1, 2, true).unwrap();
        let t = mgr.checkpoint(5, snap(5).tensors).unwrap();
        assert_eq!(t.persist_s, 0.0); // overlapped
        mgr.drain();
        let latest = CheckpointManager::new(&dir, 1, 2, false)
            .unwrap()
            .load_latest()
            .unwrap()
            .unwrap();
        assert_eq!(latest.step, 5);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_latest_skips_corrupt_and_falls_back() {
        let dir = temp_dir("ckpt").unwrap();
        let mut mgr = CheckpointManager::new(&dir, 0, 5, false).unwrap();
        mgr.checkpoint(10, snap(10).tensors).unwrap();
        mgr.checkpoint(20, snap(20).tensors).unwrap();
        // corrupt the newest
        let newest = dir.join("ckpt-rank0-step0000000020.bin");
        let mut bytes = std::fs::read(&newest).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 1;
        std::fs::write(&newest, &bytes).unwrap();
        let latest = mgr.load_latest().unwrap().unwrap();
        assert_eq!(latest.step, 10);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn ranks_do_not_collide() {
        let dir = temp_dir("ckpt").unwrap();
        let mut m0 = CheckpointManager::new(&dir, 0, 2, false).unwrap();
        let mut m1 = CheckpointManager::new(&dir, 1, 2, false).unwrap();
        m0.checkpoint(1, snap(1).tensors).unwrap();
        m1.checkpoint(2, snap(2).tensors).unwrap();
        assert_eq!(m0.load_latest().unwrap().unwrap().step, 1);
        assert_eq!(m1.load_latest().unwrap().unwrap().step, 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_dir_yields_none() {
        let dir = temp_dir("ckpt").unwrap();
        let mgr = CheckpointManager::new(dir.join("sub"), 0, 2, false).unwrap();
        assert!(mgr.load_latest().unwrap().is_none());
        std::fs::remove_dir_all(dir).ok();
    }
}
