//! Snapshot binary codec — shared by the file persist path (k1) and
//! the state-stream plane (checkpoint-free replica restore).
//!
//! Format (version 2): `FLSH` magic, version, step, tensor count, then
//! each tensor as `u64 len | f32 data`, followed by a word-wise FNV
//! checksum over everything before it. A truncated or bit-flipped
//! payload fails to load — exercised by the failure-injection tests.
//!
//! [`SnapshotStream`] is the producer half as an [`std::io::Read`]: it
//! generates the canonical byte stream lazily, one tensor at a time,
//! so a multi-GB model state can be persisted *or* chunked onto a
//! socket without ever materialising the full encoding in memory.

use super::Snapshot;
use crate::util::hash::{fnv1a, FNV_OFFSET};
use anyhow::{bail, Result};
use std::io::{Read, Write};

pub(super) const MAGIC: &[u8; 4] = b"FLSH";
pub(super) const VERSION: u32 = 2; // v2: word-wise checksum

/// Exact length in bytes of a snapshot's canonical encoding.
pub fn encoded_len(snap: &Snapshot) -> usize {
    let header = 4 + 4 + 8 + 8;
    let tensors: usize = snap.tensors.iter().map(|t| 8 + t.len() * 4).sum();
    header + tensors + 8
}

/// Lazy reader over a snapshot's canonical encoding. Buffers at most
/// one tensor at a time; the trailing checksum is emitted once every
/// tensor has been drained. Field-by-field hashing matches the decode
/// path exactly, so bytes produced here round-trip through
/// [`read_snapshot_from`] regardless of how they were chunked.
pub struct SnapshotStream<'a> {
    snap: &'a Snapshot,
    buf: Vec<u8>,
    pos: usize,
    /// Next tensor to encode (== tensors.len() once all are drained).
    next: usize,
    hash: u64,
    trailer_emitted: bool,
}

impl<'a> SnapshotStream<'a> {
    pub fn new(snap: &'a Snapshot) -> Self {
        let mut hash = FNV_OFFSET;
        let mut buf = Vec::with_capacity(24);
        for field in [
            &MAGIC[..],
            &VERSION.to_le_bytes(),
            &snap.step.to_le_bytes(),
            &(snap.tensors.len() as u64).to_le_bytes(),
        ] {
            hash = fnv1a(field, hash);
            buf.extend_from_slice(field);
        }
        SnapshotStream { snap, buf, pos: 0, next: 0, hash, trailer_emitted: false }
    }

    /// Refill the internal buffer with the next section, or leave it
    /// empty when the stream is exhausted.
    fn refill(&mut self) {
        self.buf.clear();
        self.pos = 0;
        if self.next < self.snap.tensors.len() {
            let t = &self.snap.tensors[self.next];
            let len_bytes = (t.len() as u64).to_le_bytes();
            self.hash = fnv1a(&len_bytes, self.hash);
            self.buf.reserve(8 + t.len() * 4);
            self.buf.extend_from_slice(&len_bytes);
            // f32 slice -> bytes without bytemuck: fixed-size chunk
            // copies the compiler vectorises.
            let start = self.buf.len();
            self.buf.resize(start + t.len() * 4, 0);
            for (dst, x) in self.buf[start..].chunks_exact_mut(4).zip(t.iter()) {
                dst.copy_from_slice(&x.to_le_bytes());
            }
            self.hash = fnv1a(&self.buf[start..], self.hash);
            self.next += 1;
        } else if !self.trailer_emitted {
            self.buf.extend_from_slice(&self.hash.to_le_bytes());
            self.trailer_emitted = true;
        }
    }
}

impl Read for SnapshotStream<'_> {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        if self.pos == self.buf.len() {
            self.refill();
            if self.buf.is_empty() {
                return Ok(0); // exhausted
            }
        }
        let n = out.len().min(self.buf.len() - self.pos);
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Serialize a snapshot into any writer (file persist or a socket).
pub fn write_snapshot_to<W: Write>(mut w: W, snap: &Snapshot) -> Result<()> {
    let mut stream = SnapshotStream::new(snap);
    std::io::copy(&mut stream, &mut w)?;
    w.flush()?;
    Ok(())
}

/// Snapshot -> bytes (in-memory transfer payload).
pub fn encode_snapshot(snap: &Snapshot) -> Vec<u8> {
    let mut buf = Vec::with_capacity(encoded_len(snap));
    write_snapshot_to(&mut buf, snap).expect("vec write cannot fail");
    buf
}

/// Load + verify a snapshot from any reader.
pub fn read_snapshot_from<R: Read>(mut r: R) -> Result<Snapshot> {
    let mut hash = FNV_OFFSET;

    let take = |r: &mut R, n: usize, hash: &mut u64| -> Result<Vec<u8>> {
        let mut buf = vec![0u8; n];
        r.read_exact(&mut buf)?;
        *hash = fnv1a(&buf, *hash);
        Ok(buf)
    };

    let magic = take(&mut r, 4, &mut hash)?;
    if magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let version = u32::from_le_bytes(take(&mut r, 4, &mut hash)?.try_into().unwrap());
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let step = u64::from_le_bytes(take(&mut r, 8, &mut hash)?.try_into().unwrap());
    let count = u64::from_le_bytes(take(&mut r, 8, &mut hash)?.try_into().unwrap()) as usize;
    if count > 1_000_000 {
        bail!("implausible tensor count {count}");
    }
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let len = u64::from_le_bytes(take(&mut r, 8, &mut hash)?.try_into().unwrap()) as usize;
        if len > (1usize << 33) {
            bail!("implausible tensor length {len}");
        }
        let bytes = take(&mut r, len * 4, &mut hash)?;
        let mut t = Vec::with_capacity(len);
        for c in bytes.chunks_exact(4) {
            t.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        tensors.push(t);
    }
    let mut stored = [0u8; 8];
    r.read_exact(&mut stored)?;
    if u64::from_le_bytes(stored) != hash {
        bail!("checkpoint checksum mismatch (corrupt payload)");
    }
    Ok(Snapshot { step, tensors })
}

/// Bytes -> snapshot (in-memory transfer payload).
pub fn decode_snapshot(bytes: &[u8]) -> Result<Snapshot> {
    read_snapshot_from(std::io::Cursor::new(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(step: u64) -> Snapshot {
        Snapshot {
            step,
            tensors: vec![vec![1.5, -2.0, 3.25], vec![step as f32; 7], vec![]],
        }
    }

    #[test]
    fn stream_length_is_exact() {
        let s = snap(3);
        let bytes = encode_snapshot(&s);
        assert_eq!(bytes.len(), encoded_len(&s));
    }

    #[test]
    fn stream_roundtrips_regardless_of_read_granularity() {
        let s = snap(11);
        let reference = encode_snapshot(&s);
        // drain the stream one byte at a time: identical bytes
        let mut stream = SnapshotStream::new(&s);
        let mut out = Vec::new();
        let mut one = [0u8; 1];
        loop {
            match stream.read(&mut one).unwrap() {
                0 => break,
                n => out.extend_from_slice(&one[..n]),
            }
        }
        assert_eq!(out, reference);
        assert_eq!(decode_snapshot(&out).unwrap(), s);
    }

    #[test]
    fn empty_snapshot_roundtrips() {
        let s = Snapshot { step: 0, tensors: vec![] };
        assert_eq!(decode_snapshot(&encode_snapshot(&s)).unwrap(), s);
    }

    #[test]
    fn decode_rejects_flipped_bit_anywhere() {
        let s = snap(9);
        let bytes = encode_snapshot(&s);
        for at in [0, 5, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x08;
            assert!(decode_snapshot(&bad).is_err(), "flip at {at} undetected");
        }
    }
}
