//! Snapshot binary codec — shared by the file persist path (k1) and
//! the state-stream plane (checkpoint-free replica restore).
//!
//! Format (version 2): `FLSH` magic, version, step, tensor count, then
//! each tensor as `u64 len | f32 data`, followed by a word-wise FNV
//! checksum over everything before it. A truncated or bit-flipped
//! payload fails to load — exercised by the failure-injection tests.
//!
//! [`SnapshotStream`] is the producer half as an [`std::io::Read`]: it
//! generates the canonical byte stream lazily, one tensor at a time,
//! so a multi-GB model state can be persisted *or* chunked onto a
//! socket without ever materialising the full encoding in memory.

use super::Snapshot;
use crate::util::hash::{fnv1a, FNV_OFFSET};
use anyhow::{bail, Result};
use std::io::{Read, Write};

pub(super) const MAGIC: &[u8; 4] = b"FLSH";
pub(super) const VERSION: u32 = 2; // v2: word-wise checksum

/// Exact length in bytes of a snapshot's canonical encoding.
pub fn encoded_len(snap: &Snapshot) -> usize {
    let header = 4 + 4 + 8 + 8;
    let tensors: usize = snap.tensors.iter().map(|t| 8 + t.len() * 4).sum();
    header + tensors + 8
}

/// Lazy reader over a snapshot's canonical encoding. Buffers at most
/// one tensor at a time; the trailing checksum is emitted once every
/// tensor has been drained. Field-by-field hashing matches the decode
/// path exactly, so bytes produced here round-trip through
/// [`read_snapshot_from`] regardless of how they were chunked.
pub struct SnapshotStream<'a> {
    snap: &'a Snapshot,
    buf: Vec<u8>,
    pos: usize,
    /// Next tensor to encode (== tensors.len() once all are drained).
    next: usize,
    hash: u64,
    trailer_emitted: bool,
}

impl<'a> SnapshotStream<'a> {
    pub fn new(snap: &'a Snapshot) -> Self {
        let mut hash = FNV_OFFSET;
        let mut buf = Vec::with_capacity(24);
        for field in [
            &MAGIC[..],
            &VERSION.to_le_bytes(),
            &snap.step.to_le_bytes(),
            &(snap.tensors.len() as u64).to_le_bytes(),
        ] {
            hash = fnv1a(field, hash);
            buf.extend_from_slice(field);
        }
        SnapshotStream { snap, buf, pos: 0, next: 0, hash, trailer_emitted: false }
    }

    /// Refill the internal buffer with the next section, or leave it
    /// empty when the stream is exhausted.
    fn refill(&mut self) {
        self.buf.clear();
        self.pos = 0;
        if self.next < self.snap.tensors.len() {
            let t = &self.snap.tensors[self.next];
            let len_bytes = (t.len() as u64).to_le_bytes();
            self.hash = fnv1a(&len_bytes, self.hash);
            self.buf.reserve(8 + t.len() * 4);
            self.buf.extend_from_slice(&len_bytes);
            // f32 slice -> bytes without bytemuck: fixed-size chunk
            // copies the compiler vectorises.
            let start = self.buf.len();
            self.buf.resize(start + t.len() * 4, 0);
            for (dst, x) in self.buf[start..].chunks_exact_mut(4).zip(t.iter()) {
                dst.copy_from_slice(&x.to_le_bytes());
            }
            self.hash = fnv1a(&self.buf[start..], self.hash);
            self.next += 1;
        } else if !self.trailer_emitted {
            self.buf.extend_from_slice(&self.hash.to_le_bytes());
            self.trailer_emitted = true;
        }
    }
}

impl Read for SnapshotStream<'_> {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        if self.pos == self.buf.len() {
            self.refill();
            if self.buf.is_empty() {
                return Ok(0); // exhausted
            }
        }
        let n = out.len().min(self.buf.len() - self.pos);
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Incremental (push-based) snapshot decoder — the receive-side dual
/// of [`SnapshotStream`]. Feed verified byte runs in arrival order
/// ([`SnapshotDecoder::push`]) and close with
/// [`SnapshotDecoder::finish`]; the decoder parses fields in place, so
/// its own buffering never exceeds a few dozen bytes (one fixed-size
/// field plus sub-word carries) no matter how the stream was chunked —
/// a receiver's peak memory is the decoded tensors themselves plus one
/// in-flight chunk, not encoded + decoded at once (DESIGN.md §9).
///
/// Checksum discipline is identical to [`read_snapshot_from`]: the
/// trailing word-wise hash is verified over the same per-field
/// segmentation, with multi-word tensor data folded in 8-byte-aligned
/// runs (boundary-stable, see `util::hash::fnv1a`).
pub struct SnapshotDecoder {
    hash: u64,
    state: DecodeState,
    /// Partial fixed-size field (header / tensor length / trailer).
    pending: Vec<u8>,
    step: u64,
    tensors: Vec<Vec<f32>>,
    tensors_expected: usize,
    /// Bytes of the current tensor's data still to arrive.
    data_left: usize,
    tensor: Vec<f32>,
    /// Partial f32 carried across pushes (< 4 bytes).
    f32_carry: [u8; 4],
    f32_carry_len: usize,
    /// Partial hash word carried across pushes (< 8 bytes).
    hash_carry: [u8; 8],
    hash_carry_len: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DecodeState {
    Header,
    TensorLen,
    TensorData,
    Trailer,
    Done,
}

impl SnapshotDecoder {
    pub fn new() -> Self {
        SnapshotDecoder {
            hash: FNV_OFFSET,
            state: DecodeState::Header,
            pending: Vec::with_capacity(24),
            step: 0,
            tensors: Vec::new(),
            tensors_expected: 0,
            data_left: 0,
            tensor: Vec::new(),
            f32_carry: [0; 4],
            f32_carry_len: 0,
            hash_carry: [0; 8],
            hash_carry_len: 0,
        }
    }

    /// Bytes the decoder itself is buffering (excludes the decoded
    /// tensors, which are the output) — bounded by one fixed-size
    /// field plus the sub-word carries; asserted in tests.
    pub fn buffered_bytes(&self) -> usize {
        self.pending.len() + self.f32_carry_len + self.hash_carry_len
    }

    /// Accumulate into `pending` until it holds `need` bytes; returns
    /// the number of input bytes consumed, and whether the field is
    /// now complete.
    fn fill_pending(&mut self, data: &[u8], need: usize) -> (usize, bool) {
        let take = data.len().min(need - self.pending.len());
        self.pending.extend_from_slice(&data[..take]);
        (take, self.pending.len() == need)
    }

    /// Fold a run of the current tensor's data bytes into the field
    /// hash, preserving 8-byte alignment across pushes. `last` marks
    /// the end of the tensor's data, where the (< 8 byte) remainder is
    /// folded exactly as the contiguous reference would.
    fn hash_data(&mut self, mut run: &[u8], last: bool) {
        if self.hash_carry_len > 0 {
            let take = run.len().min(8 - self.hash_carry_len);
            self.hash_carry[self.hash_carry_len..self.hash_carry_len + take]
                .copy_from_slice(&run[..take]);
            self.hash_carry_len += take;
            run = &run[take..];
            if self.hash_carry_len == 8 {
                self.hash = fnv1a(&self.hash_carry, self.hash);
                self.hash_carry_len = 0;
            }
        }
        let aligned = run.len() & !7;
        if aligned > 0 {
            self.hash = fnv1a(&run[..aligned], self.hash);
        }
        let rest = &run[aligned..];
        self.hash_carry[self.hash_carry_len..self.hash_carry_len + rest.len()]
            .copy_from_slice(rest);
        self.hash_carry_len += rest.len();
        if last && self.hash_carry_len > 0 {
            self.hash = fnv1a(&self.hash_carry[..self.hash_carry_len], self.hash);
            self.hash_carry_len = 0;
        }
    }

    /// Feed the next run of stream bytes, in order.
    pub fn push(&mut self, mut data: &[u8]) -> Result<()> {
        while !data.is_empty() {
            match self.state {
                DecodeState::Header => {
                    let (used, complete) = self.fill_pending(data, 24);
                    data = &data[used..];
                    if !complete {
                        continue;
                    }
                    let buf = std::mem::take(&mut self.pending);
                    if &buf[0..4] != MAGIC {
                        bail!("bad checkpoint magic");
                    }
                    // field-by-field, matching the encode side
                    for (from, to) in [(0, 4), (4, 8), (8, 16), (16, 24)] {
                        self.hash = fnv1a(&buf[from..to], self.hash);
                    }
                    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
                    if version != VERSION {
                        bail!("unsupported checkpoint version {version}");
                    }
                    self.step = u64::from_le_bytes(buf[8..16].try_into().unwrap());
                    let count = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
                    if count > 1_000_000 {
                        bail!("implausible tensor count {count}");
                    }
                    self.tensors_expected = count;
                    self.pending = buf; // reuse the allocation
                    self.pending.clear();
                    self.state = if count == 0 {
                        DecodeState::Trailer
                    } else {
                        DecodeState::TensorLen
                    };
                }
                DecodeState::TensorLen => {
                    let (used, complete) = self.fill_pending(data, 8);
                    data = &data[used..];
                    if !complete {
                        continue;
                    }
                    self.hash = fnv1a(&self.pending, self.hash);
                    let len = u64::from_le_bytes(self.pending[..8].try_into().unwrap()) as usize;
                    self.pending.clear();
                    if len > (1usize << 33) {
                        bail!("implausible tensor length {len}");
                    }
                    // `len` is only a claim until the trailer hash
                    // verifies: cap the eager allocation and grow with
                    // the data that actually arrives
                    self.tensor = Vec::with_capacity(len.min(1 << 22));
                    self.data_left = len * 4;
                    self.state = if len == 0 {
                        self.finish_tensor();
                        self.next_after_tensor()
                    } else {
                        DecodeState::TensorData
                    };
                }
                DecodeState::TensorData => {
                    let take = data.len().min(self.data_left);
                    let (run, rest) = data.split_at(take);
                    data = rest;
                    self.data_left -= take;
                    let last = self.data_left == 0;
                    self.hash_data(run, last);
                    // parse f32s in place, carrying < 4-byte fragments
                    let mut run = run;
                    if self.f32_carry_len > 0 {
                        let need = 4 - self.f32_carry_len;
                        let take = run.len().min(need);
                        self.f32_carry[self.f32_carry_len..self.f32_carry_len + take]
                            .copy_from_slice(&run[..take]);
                        self.f32_carry_len += take;
                        run = &run[take..];
                        if self.f32_carry_len == 4 {
                            self.tensor.push(f32::from_le_bytes(self.f32_carry));
                            self.f32_carry_len = 0;
                        }
                    }
                    let mut words = run.chunks_exact(4);
                    for w in &mut words {
                        self.tensor.push(f32::from_le_bytes(w.try_into().unwrap()));
                    }
                    let rem = words.remainder();
                    self.f32_carry[..rem.len()].copy_from_slice(rem);
                    self.f32_carry_len = rem.len();
                    if last {
                        debug_assert_eq!(self.f32_carry_len, 0);
                        self.finish_tensor();
                        self.state = self.next_after_tensor();
                    }
                }
                DecodeState::Trailer => {
                    let (used, complete) = self.fill_pending(data, 8);
                    data = &data[used..];
                    if !complete {
                        continue;
                    }
                    let stored = u64::from_le_bytes(self.pending[..8].try_into().unwrap());
                    if stored != self.hash {
                        bail!("checkpoint checksum mismatch (corrupt payload)");
                    }
                    self.pending.clear();
                    self.state = DecodeState::Done;
                }
                DecodeState::Done => {
                    bail!("trailing bytes after snapshot trailer");
                }
            }
        }
        Ok(())
    }

    fn finish_tensor(&mut self) {
        self.tensors.push(std::mem::take(&mut self.tensor));
    }

    fn next_after_tensor(&self) -> DecodeState {
        if self.tensors.len() == self.tensors_expected {
            DecodeState::Trailer
        } else {
            DecodeState::TensorLen
        }
    }

    /// Close the stream: errors unless exactly one whole, checksummed
    /// snapshot was pushed.
    pub fn finish(self) -> Result<Snapshot> {
        if self.state != DecodeState::Done {
            bail!("truncated snapshot stream (state {:?})", self.state);
        }
        Ok(Snapshot { step: self.step, tensors: self.tensors })
    }
}

impl Default for SnapshotDecoder {
    fn default() -> Self {
        Self::new()
    }
}

/// Serialize a snapshot into any writer (file persist or a socket).
pub fn write_snapshot_to<W: Write>(mut w: W, snap: &Snapshot) -> Result<()> {
    let mut stream = SnapshotStream::new(snap);
    std::io::copy(&mut stream, &mut w)?;
    w.flush()?;
    Ok(())
}

/// Snapshot -> bytes (in-memory transfer payload).
pub fn encode_snapshot(snap: &Snapshot) -> Vec<u8> {
    let mut buf = Vec::with_capacity(encoded_len(snap));
    write_snapshot_to(&mut buf, snap).expect("vec write cannot fail");
    buf
}

/// Load + verify a snapshot from any reader.
pub fn read_snapshot_from<R: Read>(mut r: R) -> Result<Snapshot> {
    let mut hash = FNV_OFFSET;

    let take = |r: &mut R, n: usize, hash: &mut u64| -> Result<Vec<u8>> {
        let mut buf = vec![0u8; n];
        r.read_exact(&mut buf)?;
        *hash = fnv1a(&buf, *hash);
        Ok(buf)
    };

    let magic = take(&mut r, 4, &mut hash)?;
    if magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let version = u32::from_le_bytes(take(&mut r, 4, &mut hash)?.try_into().unwrap());
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let step = u64::from_le_bytes(take(&mut r, 8, &mut hash)?.try_into().unwrap());
    let count = u64::from_le_bytes(take(&mut r, 8, &mut hash)?.try_into().unwrap()) as usize;
    if count > 1_000_000 {
        bail!("implausible tensor count {count}");
    }
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let len = u64::from_le_bytes(take(&mut r, 8, &mut hash)?.try_into().unwrap()) as usize;
        if len > (1usize << 33) {
            bail!("implausible tensor length {len}");
        }
        let bytes = take(&mut r, len * 4, &mut hash)?;
        let mut t = Vec::with_capacity(len);
        for c in bytes.chunks_exact(4) {
            t.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        tensors.push(t);
    }
    let mut stored = [0u8; 8];
    r.read_exact(&mut stored)?;
    if u64::from_le_bytes(stored) != hash {
        bail!("checkpoint checksum mismatch (corrupt payload)");
    }
    Ok(Snapshot { step, tensors })
}

/// Bytes -> snapshot (in-memory transfer payload).
pub fn decode_snapshot(bytes: &[u8]) -> Result<Snapshot> {
    read_snapshot_from(std::io::Cursor::new(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(step: u64) -> Snapshot {
        Snapshot {
            step,
            tensors: vec![vec![1.5, -2.0, 3.25], vec![step as f32; 7], vec![]],
        }
    }

    #[test]
    fn stream_length_is_exact() {
        let s = snap(3);
        let bytes = encode_snapshot(&s);
        assert_eq!(bytes.len(), encoded_len(&s));
    }

    #[test]
    fn stream_roundtrips_regardless_of_read_granularity() {
        let s = snap(11);
        let reference = encode_snapshot(&s);
        // drain the stream one byte at a time: identical bytes
        let mut stream = SnapshotStream::new(&s);
        let mut out = Vec::new();
        let mut one = [0u8; 1];
        loop {
            match stream.read(&mut one).unwrap() {
                0 => break,
                n => out.extend_from_slice(&one[..n]),
            }
        }
        assert_eq!(out, reference);
        assert_eq!(decode_snapshot(&out).unwrap(), s);
    }

    #[test]
    fn empty_snapshot_roundtrips() {
        let s = Snapshot { step: 0, tensors: vec![] };
        assert_eq!(decode_snapshot(&encode_snapshot(&s)).unwrap(), s);
    }

    #[test]
    fn decode_rejects_flipped_bit_anywhere() {
        let s = snap(9);
        let bytes = encode_snapshot(&s);
        for at in [0, 5, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x08;
            assert!(decode_snapshot(&bad).is_err(), "flip at {at} undetected");
        }
    }

    /// Push `bytes` through an incremental decoder in runs of `chunk`
    /// bytes, asserting the decoder's own buffering stays bounded.
    fn incremental(bytes: &[u8], chunk: usize) -> Result<Snapshot> {
        let mut dec = SnapshotDecoder::new();
        for run in bytes.chunks(chunk.max(1)) {
            dec.push(run)?;
            assert!(
                dec.buffered_bytes() < 40,
                "decoder buffered {} bytes (chunk {chunk})",
                dec.buffered_bytes()
            );
        }
        dec.finish()
    }

    #[test]
    fn incremental_decoder_matches_reference_at_any_granularity() {
        // multi-tensor snapshot with odd lengths (exercises both the
        // f32 and the 8-byte hash carries across push boundaries)
        let s = Snapshot {
            step: 31,
            tensors: vec![
                (0..301).map(|i| i as f32 * 0.25).collect(),
                vec![],
                (0..64).map(|i| -(i as f32)).collect(),
                vec![f32::MIN, f32::MAX, 0.0],
            ],
        };
        let bytes = encode_snapshot(&s);
        for chunk in [1, 3, 7, 8, 13, 64, 4096, bytes.len()] {
            assert_eq!(incremental(&bytes, chunk).unwrap(), s, "chunk {chunk}");
        }
    }

    #[test]
    fn incremental_decoder_buffering_is_bounded_by_carries_not_payload() {
        // DESIGN §9 known limitation, resolved: the receive side used
        // to buffer the whole encoded payload before decoding (~2x
        // peak). The incremental decoder holds only a fixed-size field
        // plus sub-word carries, regardless of snapshot size.
        let s = Snapshot {
            step: 7,
            tensors: vec![vec![1.0; 50_000], vec![2.0; 30_001], vec![3.0; 11]],
        };
        let bytes = encode_snapshot(&s);
        assert!(bytes.len() > 300_000, "need a payload that would hurt to buffer");
        assert_eq!(incremental(&bytes, 1024).unwrap(), s);
    }

    #[test]
    fn incremental_decoder_rejects_corruption_and_truncation() {
        let s = snap(5);
        let bytes = encode_snapshot(&s);
        for at in [0, 9, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x10;
            assert!(incremental(&bad, 16).is_err(), "flip at {at} undetected");
        }
        // truncation at every state boundary fails in finish()
        for cut in [3, 20, 30, bytes.len() - 1] {
            let mut dec = SnapshotDecoder::new();
            dec.push(&bytes[..cut]).unwrap();
            assert!(dec.finish().is_err(), "truncation at {cut} undetected");
        }
        // trailing garbage is rejected eagerly
        let mut dec = SnapshotDecoder::new();
        dec.push(&bytes).unwrap();
        assert!(dec.push(&[0xFF]).is_err());
    }
}
