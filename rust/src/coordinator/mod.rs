//! The FlashRecovery coordinator — the paper's system contribution.
//!
//! * [`detection`] — active real-time failure detection (§III-C):
//!   the wire-plane [`LeaseMonitor`] (leased heartbeats, device-code
//!   classification, step-tag stall / silent-hang detection; DESIGN.md
//!   §10) plus the in-process board-scan fallback.
//! * [`ranktable`] — O(1) shared-file ranktable vs the O(n)
//!   collect/distribute baseline (§III-D, Tab. I).
//! * [`step_tag`] — the step-tag protocol deciding when to stop/clean/
//!   reset and whether to resume at step i or i+1 (§III-E).
//! * [`rendezvous`] — epoch-fenced communication-group reconstruction
//!   over the live TCP store: O(1) messages per surviving node,
//!   full join for replacements only (§III-D; DESIGN.md §8).
//! * [`restore`] — shard-aware restore planning (lost ZeRO shard ->
//!   surviving replica source) and streaming restore episodes over the
//!   live TCP plane (§III-E; DESIGN.md §9).
//! * [`controller`] — the global controller orchestrating detection,
//!   scale-independent restart, and checkpoint-free recovery over the
//!   real DP training engine.
//! * [`events`] — recovery episode records and run reports.

pub mod controller;
pub mod detection;
pub mod events;
pub mod ranktable;
pub mod rendezvous;
pub mod restore;
pub mod step_tag;

pub use controller::{
    adopt_coordination_state, encode_leases, parse_leases, AdoptedState, Controller,
    ControllerConfig, EpisodeCheckpoint, EpisodePhase, StandbyController, K_EPISODE,
    K_LEASES,
};
pub use detection::{
    detection_sweep, Detection, DetectionPath, DetectionSweepConfig,
    HeartbeatMonitor, LeaseConfig, LeaseMonitor,
};
pub use events::{RecoveryRecord, RunReport, ShardRestoreStat};
pub use ranktable::{original_update, RankEntry, Ranktable, SharedRanktable};
pub use rendezvous::{
    rebuild_episode, rebuild_sweep, EpisodeConfig, EpochAborted, NodeSession,
    RebuildOutcome, SweepConfig,
};
pub use restore::{
    plan_shard_restore, restore_episode, restore_sweep, RestoreOutcome, RestorePlan,
    RestoreSweepConfig, ShardReconstruction, ShardTransfer, TransferStat,
};
pub use step_tag::{decide, plan_restore, TagDecision};
