//! Epoch-fenced rendezvous: scale-independent communication-group
//! reconstruction over the live TCP store (paper §III-D; DESIGN.md §8).
//!
//! After a failure, the controller fences the cluster into a new
//! rendezvous epoch and the fleet rebuilds its DP/TP/PP groups with
//! *differentiated* node strategies:
//!
//! * **surviving nodes** keep their store connection and cached
//!   ranktable, and re-key into the new epoch by consuming one O(k)
//!   delta record (k = replacements) — **3 store messages** each,
//!   regardless of cluster size (pipelined into 2 round-trips via the
//!   store's `Batch` op, DESIGN.md §11);
//! * **replacement nodes** perform a full join: register their entry,
//!   fetch the full table (compact binary), derive their groups —
//!   **6 store messages** each (4 round-trips);
//! * the **coordinator** exchanges O(k) messages total.
//!
//! No per-node re-registration, no all-gather: total store traffic is
//! O(live participants + replacements), independent of world size —
//! the property `benches/group_rebuild.rs` measures and CI gates.

use super::ranktable::{RankEntry, Ranktable};
use crate::comms::group::{GroupSet, RekeyStats};
use crate::comms::replication::{StoreEndpoints, StoreSession};
use crate::comms::tcp_store::{FencedWait, TcpStoreServer};
use crate::comms::wire::{Bytes, Request, Response};
use crate::config::ParallelismConfig;
use crate::metrics::bench::BenchReport;
use crate::metrics::Histogram;
use crate::telemetry::log;
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

/// Published as an epoch's delta when the epoch was administratively
/// aborted (supervised-barrier early abort). Unambiguous: real deltas
/// are JSON objects.
const ABORT_MARKER: &[u8] = b"!abort";

/// A rendezvous epoch was aborted before its barrier released — an
/// agent died before arriving and the supervised barrier fenced the
/// cluster out of the epoch instead of letting everyone hang. The
/// episode is retryable: re-run with `from_epoch = current` (the
/// aborted epoch's keys are tombstoned and must not be reused).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpochAborted {
    pub current: u64,
}

impl std::fmt::Display for EpochAborted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rendezvous epoch aborted (supervised barrier); retry at epoch {}",
            self.current
        )
    }
}

impl std::error::Error for EpochAborted {}

/// Extract the retryable abort marker from an error chain.
pub fn epoch_aborted(e: &anyhow::Error) -> Option<EpochAborted> {
    e.downcast_ref::<EpochAborted>().copied()
}

/// Unwrap a fenced wait, translating supersession into the retryable
/// [`EpochAborted`] — the one conversion every barrier/join/table wait
/// shares.
fn fenced_value(w: FencedWait) -> Result<Bytes> {
    match w {
        FencedWait::Value(b) => Ok(b),
        FencedWait::Superseded { current } => Err(EpochAborted { current }.into()),
    }
}

/// [`fenced_value`] for batched sub-responses: a fenced wait inside a
/// pipelined sequence aborts retryably, a shutdown surfaces as an
/// error, anything else is a protocol violation.
fn fenced_response(r: Option<Response>) -> Result<Bytes> {
    match r {
        Some(Response::Value(b)) => Ok(b),
        Some(Response::EpochFenced { current }) => {
            Err(EpochAborted { current }.into())
        }
        Some(Response::NotFound) => bail!("store shut down during fenced wait"),
        other => bail!("unexpected batched response {other:?}"),
    }
}

/// Reject an abort-marker tombstone published as an epoch's delta.
fn check_delta(bytes: &[u8], epoch: u64) -> Result<()> {
    if bytes == ABORT_MARKER {
        return Err(EpochAborted { current: epoch }.into());
    }
    Ok(())
}

fn k_delta(epoch: u64) -> String {
    format!("rdzv/{epoch}/delta")
}

fn k_table(epoch: u64) -> String {
    format!("rdzv/{epoch}/table")
}

fn k_join(epoch: u64, rank: usize) -> String {
    format!("rdzv/{epoch}/join/{rank}")
}

fn k_arrived(epoch: u64) -> String {
    format!("rdzv/{epoch}/arrived")
}

fn k_go(epoch: u64) -> String {
    format!("rdzv/{epoch}/go")
}

/// The O(k) record the coordinator publishes per epoch: everything a
/// surviving node needs to re-key without refetching the table.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochRecord {
    pub epoch: u64,
    /// Ranktable version after the substitutions were applied.
    pub version: u64,
    pub world: usize,
    /// Live protocol participants this epoch (arrive-barrier size).
    pub participants: usize,
    /// The substituted entries only — not the whole table.
    pub subs: Vec<RankEntry>,
}

impl EpochRecord {
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("epoch", self.epoch)
            .set("version", self.version)
            .set("world", self.world)
            .set("participants", self.participants)
            .set(
                "subs",
                Json::Array(self.subs.iter().map(|e| e.to_json()).collect()),
            );
        o
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(EpochRecord {
            epoch: v.get("epoch").as_i64().context("epoch")? as u64,
            version: v.get("version").as_i64().context("version")? as u64,
            world: v.get("world").as_usize().context("world")?,
            participants: v.get("participants").as_usize().context("participants")?,
            subs: v
                .get("subs")
                .as_array()
                .context("subs")?
                .iter()
                .map(RankEntry::from_json)
                .collect::<Result<_>>()?,
        })
    }

    pub fn parse(bytes: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(bytes)?;
        Self::from_json(&Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?)
    }
}

/// Release half of the epoch barrier, given this participant's arrive
/// count `n`: the closing participant publishes the release key
/// *instead of* waiting on it (it just proved everyone arrived), so
/// the per-node budget stays deterministic. The wait is epoch-fenced:
/// a supervised-barrier abort releases arrived participants with a
/// retryable [`EpochAborted`] instead of a 300s socket-timeout hang.
fn release_barrier(
    client: &mut StoreSession,
    epoch: u64,
    n: i64,
    participants: usize,
) -> Result<()> {
    if n >= participants as i64 {
        client.set(&k_go(epoch), b"go")?;
    } else {
        fenced_value(client.wait_epoch(&k_go(epoch), epoch)?)?;
    }
    Ok(())
}

/// Arrive at the epoch barrier and release (2 messages). Survivors
/// pipeline the arrive into their delta batch instead; this is the
/// replacement path's tail.
fn arrive_and_release(
    client: &mut StoreSession,
    epoch: u64,
    participants: usize,
) -> Result<()> {
    let n = client.add(&k_arrived(epoch), 1)?;
    release_barrier(client, epoch, n, participants)
}

/// What a surviving node's rejoin cost: group bookkeeping plus the
/// store messages it actually sent (the O(1) budget under test).
#[derive(Debug, Clone, Copy)]
pub struct RejoinOutcome {
    pub rekey: RekeyStats,
    /// Store messages sent during the rejoin.
    pub ops: u64,
    /// Epoch actually joined (>= the requested one under churn).
    pub epoch: u64,
}

/// A node's persistent rendezvous state: failover-transparent store
/// session (over the full coordination-plane endpoint set), cached
/// ranktable, and its own three communication groups.
pub struct NodeSession {
    client: StoreSession,
    pub rank: usize,
    pub epoch: u64,
    pub table: Ranktable,
    pub groups: GroupSet,
}

impl NodeSession {
    /// Establish a surviving node's session from its cached state.
    pub fn start(
        store: StoreEndpoints,
        rank: usize,
        table: Ranktable,
        cfg: &ParallelismConfig,
        epoch: u64,
    ) -> Result<NodeSession> {
        let mut client = StoreSession::connect(store)?;
        client.hello(rank as u64)?;
        let groups = GroupSet::derive_for(&table, cfg, epoch, rank)?;
        Ok(NodeSession { client, rank, epoch, table, groups })
    }

    /// Store messages sent over this session's connection so far.
    pub fn ops_sent(&self) -> u64 {
        self.client.ops_sent()
    }

    /// Normal-node rejoin into epoch `target`: the fenced delta wait
    /// and the arrive `Add` go out **pipelined as one `Batch` frame**
    /// (one round-trip; the store executes them serially and skips the
    /// arrive if the wait is fenced), then the delta is applied to the
    /// cached table, groups re-key, and the barrier releases — still
    /// exactly 3 logical store messages regardless of cluster size,
    /// now in 2 round-trips. If the epoch was superseded mid-wait the
    /// rejoin chases the newest epoch; if a delta was missed entirely
    /// it falls back to one full-table fetch (still O(1) messages).
    ///
    /// Pipelining moves the arrive *before* the local delta apply /
    /// re-key: an arrive now attests "delta received", not "re-keyed".
    /// A survivor that fails between its arrive and its re-key no
    /// longer trips the supervised-barrier watchdog (the barrier can
    /// release); instead its error surfaces through the episode's
    /// thread join + table-convergence checks — a deliberate trade:
    /// the failure is reported immediately rather than after the
    /// watchdog's `join_deadline`, at the cost of the barrier itself
    /// certifying one step less.
    pub fn rejoin(
        &mut self,
        cfg: &ParallelismConfig,
        target: u64,
    ) -> Result<RejoinOutcome> {
        let ops0 = self.client.ops_sent();
        let mut target = target;
        let (rec, arrived) = loop {
            let mut resps = self
                .client
                .batch(vec![
                    Request::WaitEpoch { key: k_delta(target), epoch: target },
                    Request::Add { key: k_arrived(target), delta: 1 },
                ])?
                .into_iter();
            match resps.next() {
                Some(Response::Value(bytes)) => {
                    // the epoch we (possibly) chased into was aborted;
                    // the controller retries past the tombstone
                    check_delta(&bytes, target)?;
                    let rec = EpochRecord::parse(&bytes)?;
                    let n = match resps.next() {
                        Some(Response::Counter(n)) => n,
                        other => bail!("unexpected arrive response {other:?}"),
                    };
                    break (rec, n);
                }
                Some(Response::EpochFenced { current }) => target = current,
                Some(Response::NotFound) => {
                    bail!("store shut down during fenced wait")
                }
                other => bail!("unexpected rejoin response {other:?}"),
            }
        };
        let applied = self.apply_delta(&rec);
        let rekey = if applied.is_ok() && self.table.version == rec.version {
            self.groups.rekey(&rec.subs, target)
        } else {
            // Missed at least one epoch's delta (or the cached table
            // diverged): resync from the full binary table — one extra
            // message, not a re-registration.
            log::debug("rendezvous", || {
                format!(
                    "rank {} missed a delta; full-table resync at epoch {target}",
                    self.rank
                )
            });
            let bytes = fenced_value(self.client.wait_epoch(&k_table(target), target)?)?;
            self.table = Ranktable::decode_bin(&bytes)?;
            self.groups = GroupSet::derive_for(&self.table, cfg, target, self.rank)?;
            RekeyStats { rebuilt: self.groups.groups.len(), rekeyed: 0 }
        };
        self.epoch = target;
        release_barrier(&mut self.client, target, arrived, rec.participants)?;
        Ok(RejoinOutcome { rekey, ops: self.client.ops_sent() - ops0, epoch: target })
    }

    fn apply_delta(&mut self, rec: &EpochRecord) -> Result<()> {
        for e in &rec.subs {
            self.table.substitute(e.clone())?;
        }
        Ok(())
    }
}

/// Replacement-node full join into epoch `target`: register the new
/// entry, fetch the delta (for the barrier size) and the full binary
/// table — **pipelined as one `Batch` frame** (register + both fenced
/// waits in a single round-trip) — then derive groups and arrive.
/// Still 6 logical store messages, now in 4 round-trips (hello,
/// batch, arrive, release). Returns the session and the store
/// messages it cost.
pub fn replacement_join(
    store: StoreEndpoints,
    target: u64,
    entry: RankEntry,
    cfg: &ParallelismConfig,
) -> Result<(NodeSession, u64)> {
    let mut client = StoreSession::connect(store)?;
    client.hello(entry.rank as u64)?;
    let mut resps = client
        .batch(vec![
            Request::Set {
                key: k_join(target, entry.rank),
                value: entry.encode(),
            },
            Request::WaitEpoch { key: k_delta(target), epoch: target },
            Request::WaitEpoch { key: k_table(target), epoch: target },
        ])?
        .into_iter();
    match resps.next() {
        Some(Response::Ok) => {}
        other => bail!("unexpected join-register response {other:?}"),
    }
    let delta = fenced_response(resps.next())?;
    check_delta(&delta, target)?;
    let rec = EpochRecord::parse(&delta)?;
    let table = Ranktable::decode_bin(&fenced_response(resps.next())?)?;
    let groups = GroupSet::derive_for(&table, cfg, target, entry.rank)?;
    arrive_and_release(&mut client, target, rec.participants)?;
    let ops = client.ops_sent();
    let rank = entry.rank;
    Ok((NodeSession { client, rank, epoch: target, table, groups }, ops))
}

/// Coordinator-side message accounting for one epoch.
#[derive(Debug, Clone, Copy)]
pub struct CoordStats {
    pub epoch: u64,
    pub joins: usize,
    pub ops: u64,
}

/// Controller side of one rebuild epoch: fence the old epoch, harvest
/// the replacement registrations, publish the delta + binary table,
/// and wait for the arrive-barrier release. O(k) messages.
pub fn coordinate(
    client: &mut StoreSession,
    table: &mut Ranktable,
    failed: &[usize],
    target: u64,
    participants: usize,
) -> Result<CoordStats> {
    let ops0 = client.ops_sent();
    client.advance_epoch(target)?;
    let mut subs = Vec::with_capacity(failed.len());
    for &r in failed {
        // fenced: a replacement that dies before registering releases
        // the coordinator via the supervised-barrier abort
        let bytes = fenced_value(client.wait_epoch(&k_join(target, r), target)?)?;
        let entry = RankEntry::decode(&bytes)?;
        if entry.rank != r {
            bail!("replacement for rank {r} registered as rank {}", entry.rank);
        }
        subs.push(entry);
    }
    for e in &subs {
        table.substitute(e.clone())?;
    }
    let rec = EpochRecord {
        epoch: target,
        version: table.version,
        world: table.entries.len(),
        participants,
        subs,
    };
    client.set(&k_table(target), &table.encode_bin())?;
    client.set(&k_delta(target), rec.to_json().render().as_bytes())?;
    if participants == 0 {
        // nobody to arrive: release immediately so nothing dangles
        client.set(&k_go(target), b"go")?;
    }
    fenced_value(client.wait_epoch(&k_go(target), target)?)?;
    Ok(CoordStats { epoch: target, joins: failed.len(), ops: client.ops_sent() - ops0 })
}

/// Tombstone the epoch *after* `target` and fence everyone out of
/// `target` — **unless** `target`'s barrier already released (the
/// store's `AbortEpoch` op checks the release key and fences in one
/// atomic step, so "barrier won" vs "abort won" is a deterministic
/// order, never a mix). On abort, every fenced waiter (arrive barrier,
/// join harvest, delta chase) is released promptly with
/// [`EpochAborted`]. The tombstoned epoch `target + 1` must not be
/// reused — retries go to `target + 2` (i.e. `from_epoch = target + 1`).
fn abort_epoch(store: &StoreEndpoints, target: u64) {
    log::warn("rendezvous", || {
        format!("aborting epoch {target} (supervised barrier)")
    });
    if let Ok(mut c) = StoreSession::try_connect(store) {
        let _ = c.abort_epoch_unless(
            &k_go(target),
            &k_delta(target + 1),
            ABORT_MARKER,
            target + 1,
        );
    }
}

/// Supervised barrier: a watchdog that aborts epoch `target` if its
/// release key has not been published within `deadline`. Signal the
/// returned sender (or drop it after a successful episode) to stand
/// the watchdog down.
fn supervise_barrier(
    store: StoreEndpoints,
    target: u64,
    deadline: Duration,
) -> (std::sync::mpsc::Sender<()>, std::thread::JoinHandle<()>) {
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let handle = std::thread::spawn(move || {
        match rx.recv_timeout(deadline) {
            Err(RecvTimeoutError::Timeout) => {}
            _ => return, // episode finished (or its driver bailed) in time
        }
        // Deadline passed with the barrier possibly still closed: a
        // participant died before arriving (DESIGN.md §8). The abort
        // itself re-checks the release key atomically, so a barrier
        // that released at the last instant is left untouched.
        abort_epoch(&store, target);
    });
    (tx, handle)
}

/// How a rebuild episode is driven.
#[derive(Debug, Clone, Copy)]
pub struct EpisodeConfig {
    /// Cap on surviving nodes driven as *live* TCP agents. Every
    /// survivor runs the identical O(1)-message protocol concurrently,
    /// so a fixed sample bounds socket count while ranktable and group
    /// math still run at full cluster scale.
    pub live_survivors: usize,
    /// Supervised-barrier deadline: if the episode's arrive barrier
    /// has not released by then (a participant died before arriving),
    /// the watchdog aborts the epoch and every fenced waiter returns a
    /// retryable [`EpochAborted`] — never a 300s socket-timeout stall.
    pub join_deadline: Duration,
}

impl Default for EpisodeConfig {
    fn default() -> Self {
        EpisodeConfig {
            live_survivors: 32,
            join_deadline: Duration::from_secs(120),
        }
    }
}

impl EpisodeConfig {
    /// Derive the episode deadlines from one [`Timeouts`] config — the
    /// §15 seam impaired campaigns use so a slow or healing link widens
    /// the supervised barrier instead of tripping a false abort.
    ///
    /// [`Timeouts`]: crate::config::Timeouts
    pub fn from_timeouts(t: &crate::config::Timeouts, live_survivors: usize) -> Self {
        EpisodeConfig {
            live_survivors,
            join_deadline: t.join_deadline,
        }
    }
}

/// Outcome of one full rebuild episode.
#[derive(Debug, Clone)]
pub struct RebuildOutcome {
    pub epoch: u64,
    /// Fence -> barrier release, the paper's reconstruction cost.
    pub wall_s: f64,
    /// The post-substitution table every participant converged on.
    pub table: Ranktable,
    pub world: usize,
    pub replacements: usize,
    pub live_survivors: usize,
    /// Max store messages any surviving node spent (O(1) budget).
    pub survivor_ops_max: u64,
    /// Max store messages any replacement node spent.
    pub replacement_ops_max: u64,
    pub coordinator_ops: u64,
    /// Groups whose communicator needed re-establishment.
    pub groups_rebuilt: usize,
    /// Groups that only re-stamped the epoch.
    pub groups_rekeyed: usize,
}

/// Evenly-strided sample of `ranks`, at most `cap` entries.
fn sample_stride(ranks: &[usize], cap: usize) -> Vec<usize> {
    if cap == 0 || ranks.is_empty() {
        return Vec::new();
    }
    if ranks.len() <= cap {
        return ranks.to_vec();
    }
    let step = ranks.len() as f64 / cap as f64;
    (0..cap).map(|i| ranks[(i as f64 * step) as usize]).collect()
}

/// Drive one rebuild episode end to end over a live coordination
/// plane (the full `StoreEndpoints` set — every participant is a
/// failover-transparent [`StoreSession`], so a primary crash
/// mid-episode re-parks waits on the promoted replica instead of
/// failing the episode): surviving nodes (sampled), replacement
/// joins, and the coordinator, each as a real TCP client. Returns
/// once every participant has converged on the new table and epoch.
///
/// Failure semantics: the barrier is *supervised* — an agent that dies
/// before arriving trips the watchdog at `opts.join_deadline`, the
/// epoch is aborted, and every fenced waiter (including this function)
/// returns a retryable [`EpochAborted`] instead of stalling on the
/// store's 300s client read timeout. Retry with
/// `from_epoch = aborted.current` (the tombstoned epoch is skipped).
/// Store hygiene: advancing into the new epoch prunes every
/// `rdzv/…`/`restore/…` key (and arrive counter) of epochs `<= e-2`
/// server-side — only `e-1` is ever needed for late resync — so the
/// key count stays bounded by two epochs' worth across arbitrarily
/// many recoveries (the `DelPrefix` wire op covers ad-hoc pruning).
pub fn rebuild_episode(
    store: &StoreEndpoints,
    table: &Ranktable,
    cfg: &ParallelismConfig,
    failed: &[usize],
    replacements: &[RankEntry],
    from_epoch: u64,
    opts: &EpisodeConfig,
) -> Result<RebuildOutcome> {
    if failed.len() != replacements.len() {
        bail!(
            "{} failed ranks but {} replacement entries",
            failed.len(),
            replacements.len()
        );
    }
    for (f, r) in failed.iter().zip(replacements) {
        if r.rank != *f {
            bail!("replacement entry rank {} does not match failed rank {f}", r.rank);
        }
    }
    let world = cfg.world_size();
    if table.entries.len() != world {
        bail!("table has {} entries, topology world is {world}", table.entries.len());
    }
    let target = from_epoch + 1;
    log::info("rendezvous", || {
        format!(
            "rebuild episode: epoch {target}, {} failed, world {world}",
            failed.len()
        )
    });

    // Pre-existing state: survivors already hold store connections and
    // the cached table from `from_epoch` — established outside the
    // timed region, like the long-lived connections they model.
    let survivors: Vec<usize> = (0..world).filter(|r| !failed.contains(r)).collect();
    let sample = sample_stride(&survivors, opts.live_survivors);
    let mut sessions = Vec::with_capacity(sample.len());
    for &rank in &sample {
        sessions.push(NodeSession::start(
            store.clone(),
            rank,
            table.clone(),
            cfg,
            from_epoch,
        )?);
    }
    let mut coord = StoreSession::connect(store.clone())?;
    coord.hello(u64::MAX)?;
    let participants = sample.len() + replacements.len();

    let t0 = Instant::now();
    // Supervised barrier (DESIGN.md §8): if any participant dies
    // before arriving, the watchdog fences the epoch at the deadline
    // and every blocked agent returns EpochAborted instead of hanging.
    let (watch_tx, watchdog) = supervise_barrier(store.clone(), target, opts.join_deadline);
    let mut survivor_threads = Vec::with_capacity(sessions.len());
    for mut s in sessions {
        let cfg = cfg.clone();
        survivor_threads.push(std::thread::spawn(
            move || -> Result<(NodeSession, RejoinOutcome)> {
                let out = s.rejoin(&cfg, target)?;
                Ok((s, out))
            },
        ));
    }
    let mut repl_threads = Vec::with_capacity(replacements.len());
    for entry in replacements.iter().cloned() {
        let cfg = cfg.clone();
        let store = store.clone();
        repl_threads.push(std::thread::spawn(move || {
            replacement_join(store, target, entry, &cfg)
        }));
    }
    let mut coord_table = table.clone();
    let coord_res = coordinate(&mut coord, &mut coord_table, failed, target, participants);
    if coord_res.is_err() {
        // Release every blocked agent promptly (idempotent when the
        // watchdog already fired), then collect them below.
        abort_epoch(store, target);
    }
    let _ = watch_tx.send(());
    let _ = watchdog.join();

    // Join every agent before surfacing any error — an abort must not
    // leave threads behind.
    let mut agent_err: Option<anyhow::Error> = None;
    let mut survivors_done: Vec<(NodeSession, RejoinOutcome)> = Vec::new();
    for h in survivor_threads {
        match h.join() {
            Ok(Ok(pair)) => survivors_done.push(pair),
            Ok(Err(e)) => {
                agent_err.get_or_insert(e);
            }
            Err(_) => {
                agent_err.get_or_insert(anyhow::anyhow!("survivor agent panicked"));
            }
        }
    }
    let mut replacements_done: Vec<(NodeSession, u64)> = Vec::new();
    for h in repl_threads {
        match h.join() {
            Ok(Ok(pair)) => replacements_done.push(pair),
            Ok(Err(e)) => {
                agent_err.get_or_insert(e);
            }
            Err(_) => {
                agent_err.get_or_insert(anyhow::anyhow!("replacement agent panicked"));
            }
        }
    }
    let stats = coord_res?;
    if let Some(e) = agent_err {
        return Err(e);
    }

    let mut survivor_ops_max = 0u64;
    for (s, out) in survivors_done {
        if s.table != coord_table || s.epoch != target {
            bail!("survivor {} diverged after rejoin", s.rank);
        }
        survivor_ops_max = survivor_ops_max.max(out.ops);
    }
    let mut replacement_ops_max = 0u64;
    for (s, ops) in replacements_done {
        if s.table != coord_table || s.epoch != target {
            bail!("replacement {} diverged after join", s.rank);
        }
        replacement_ops_max = replacement_ops_max.max(ops);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    log::debug("rendezvous", || {
        format!(
            "epoch {target} converged in {:.1}ms: survivor ops {survivor_ops_max}, \
             replacement ops {replacement_ops_max}, coordinator ops {}",
            wall_s * 1e3,
            stats.ops
        )
    });

    // Bookkeeping off the timed path: full-set rebuilt/re-keyed split.
    let mut full = GroupSet::derive(table, cfg, from_epoch)?;
    let gs = full.rekey(replacements, target);
    Ok(RebuildOutcome {
        epoch: target,
        wall_s,
        table: coord_table,
        world,
        replacements: replacements.len(),
        live_survivors: sample.len(),
        survivor_ops_max,
        replacement_ops_max,
        coordinator_ops: stats.ops,
        groups_rebuilt: gs.rebuilt,
        groups_rekeyed: gs.rekeyed,
    })
}

// ---------------------------------------------------------------- sweep

/// Scale-sweep configuration for the `group_rebuild` bench and the
/// `flashrecovery bench rebuild` CLI.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Simulated cluster sizes (ranktable/group math at full scale).
    pub scales: Vec<usize>,
    /// Measured episodes per scale (one extra warmup is discarded).
    pub samples: u32,
    /// Failed ranks per episode.
    pub failures: usize,
    /// Live surviving-node agents per episode.
    pub live_survivors: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            scales: vec![256, 1024, 4096, 8192],
            samples: 7,
            failures: 2,
            live_survivors: 32,
        }
    }
}

/// Topology for `n` simulated ranks: the common tp=8, pp=4 megatron
/// shape when divisible, pure DP otherwise.
pub fn topology_for(n: usize) -> ParallelismConfig {
    if n >= 64 && n % 32 == 0 {
        ParallelismConfig::new(n / 32, 4, 8)
    } else {
        ParallelismConfig::dp(n)
    }
}

fn sweep_entry(rank: usize) -> RankEntry {
    RankEntry {
        rank,
        node: rank / 8,
        device: rank % 8,
        addr: format!("10.{}.{}.{}:2900", rank / 2000, (rank / 8) % 250, rank % 8),
    }
}

/// Run the rebuild scale sweep and report per-scale wall-clock
/// quantiles and message budgets. Column 0 (`p50 ms`) is the value
/// CI's bench gate compares against the committed baseline.
pub fn rebuild_sweep(cfg: &SweepConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new(
        "group_rebuild: epoch-fenced rendezvous, scale sweep",
        &["p50 ms", "mean ms", "max ms", "survivor msgs", "repl msgs", "coord msgs"],
    );
    for &n in &cfg.scales {
        if n < 2 {
            bail!("sweep scale must be >= 2 ranks (got {n})");
        }
        let par = topology_for(n);
        let failures = cfg.failures.clamp(1, n / 2);
        let server = TcpStoreServer::start()?;
        let mut table = Ranktable::new((0..n).map(sweep_entry).collect());
        let mut epoch = 0u64;
        let mut h = Histogram::new();
        let (mut surv_msgs, mut repl_msgs, mut coord_msgs) = (0u64, 0u64, 0u64);
        for i in 0..=cfg.samples {
            let failed: Vec<usize> =
                (0..failures).map(|j| (j * n / failures + 1) % n).collect();
            let replacements: Vec<RankEntry> = failed
                .iter()
                .map(|&r| RankEntry {
                    rank: r,
                    node: n + epoch as usize * failures + r,
                    device: 0,
                    addr: format!("10.200.{}.{}:2900", epoch % 250, r % 250),
                })
                .collect();
            let out = rebuild_episode(
                &server.endpoints(),
                &table,
                &par,
                &failed,
                &replacements,
                epoch,
                &EpisodeConfig {
                    live_survivors: cfg.live_survivors,
                    ..Default::default()
                },
            )?;
            epoch = out.epoch;
            table = out.table;
            if i > 0 {
                // episode 0 is warmup (server threads, allocator)
                h.record(out.wall_s);
                surv_msgs = surv_msgs.max(out.survivor_ops_max);
                repl_msgs = repl_msgs.max(out.replacement_ops_max);
                coord_msgs = coord_msgs.max(out.coordinator_ops);
            }
        }
        report.row(
            format!("n={n}"),
            vec![
                h.p50() * 1e3,
                h.mean() * 1e3,
                h.max() * 1e3,
                surv_msgs as f64,
                repl_msgs as f64,
                coord_msgs as f64,
            ],
        );
    }
    report.note(format!(
        "{} samples/scale (+1 warmup), {} replacement(s)/episode, {} live \
         survivor agents; ranktable + group math at full scale",
        cfg.samples, cfg.failures, cfg.live_survivors
    ));
    report.note(
        "scale-independence: survivor msgs stay O(1), wall-clock near-flat \
         across the sweep",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(rank: usize) -> RankEntry {
        RankEntry {
            rank,
            node: rank,
            device: 0,
            addr: format!("10.0.0.{rank}:2900"),
        }
    }

    fn table(n: usize) -> Ranktable {
        Ranktable::new((0..n).map(entry).collect())
    }

    fn replacement(rank: usize, tag: usize) -> RankEntry {
        RankEntry {
            rank,
            node: 100 + tag,
            device: 0,
            addr: format!("10.9.{tag}.{rank}:2900"),
        }
    }

    #[test]
    fn epoch_record_roundtrip() {
        let rec = EpochRecord {
            epoch: 3,
            version: 5,
            world: 8,
            participants: 7,
            subs: vec![replacement(2, 0)],
        };
        let back = EpochRecord::parse(rec.to_json().render().as_bytes()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn episode_converges_all_participants() {
        let cfg = ParallelismConfig::new(2, 2, 2);
        let server = TcpStoreServer::start().unwrap();
        let t = table(8);
        let out = rebuild_episode(
            &server.endpoints(),
            &t,
            &cfg,
            &[3],
            &[replacement(3, 0)],
            0,
            &EpisodeConfig { live_survivors: 8, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.epoch, 1);
        assert_eq!(out.live_survivors, 7);
        assert_eq!(out.replacements, 1);
        assert_eq!(out.table.entries[3], replacement(3, 0));
        assert_eq!(out.table.version, 2);
        // rank 3 sits in one group per kind
        assert_eq!(out.groups_rebuilt, 3);
        assert_eq!(out.groups_rekeyed + out.groups_rebuilt, 2 * 2 + 2 * 2 + 2 * 2);
        // deterministic message budgets: survivors exactly 3 (fenced
        // delta wait + arrive pipelined in one batch frame, release),
        // replacements exactly 6, coordinator k + 4
        assert_eq!(out.survivor_ops_max, 3);
        assert_eq!(out.replacement_ops_max, 6);
        assert_eq!(out.coordinator_ops, 1 + 4);
    }

    #[test]
    fn sequential_episodes_advance_epoch_and_version() {
        let cfg = ParallelismConfig::dp(4);
        let server = TcpStoreServer::start().unwrap();
        let mut t = table(4);
        let mut epoch = 0;
        for i in 0..3 {
            let out = rebuild_episode(
                &server.endpoints(),
                &t,
                &cfg,
                &[1],
                &[replacement(1, i)],
                epoch,
                &EpisodeConfig { live_survivors: 4, ..Default::default() },
            )
            .unwrap();
            epoch = out.epoch;
            t = out.table;
        }
        assert_eq!(epoch, 3);
        assert_eq!(t.version, 4); // three substitutions
        assert_eq!(t.entries[1], replacement(1, 2));
        assert_eq!(server.epoch(), 3);
    }

    #[test]
    fn store_keys_stay_bounded_across_many_episodes() {
        // DESIGN §8 known limitation, resolved: per-epoch keys
        // (rdzv/<e>/…, restore/<e>/…) used to be retained forever —
        // one leaked key set per recovery. Epoch advance now prunes
        // epochs <= e-2, so ten recovery episodes end with the same
        // bounded key count as two.
        let cfg = ParallelismConfig::dp(4);
        let server = TcpStoreServer::start().unwrap();
        let mut t = table(4);
        let mut epoch = 0;
        let mut count_after_two = 0i64;
        for i in 0..10 {
            let out = rebuild_episode(
                &server.endpoints(),
                &t,
                &cfg,
                &[1],
                &[replacement(1, i)],
                epoch,
                &EpisodeConfig { live_survivors: 4, ..Default::default() },
            )
            .unwrap();
            epoch = out.epoch;
            t = out.table;
            if i == 1 {
                count_after_two = server.metrics_snapshot().gauge("store.keys");
            }
        }
        assert_eq!(epoch, 10);
        // keys for at most epochs {e-1, e}: 4 map keys each (delta,
        // table, join/1, go) -> hard bound 8, and no growth vs run #2
        let snap = server.metrics_snapshot();
        assert!(
            snap.gauge("store.keys") <= count_after_two.max(8),
            "store leaked: {} keys after 10 episodes vs {} after 2",
            snap.gauge("store.keys"),
            count_after_two
        );
        assert!(
            snap.gauge("store.counters") <= 2,
            "arrive counters leaked: {}",
            snap.gauge("store.counters")
        );
    }

    #[test]
    fn stale_session_resyncs_via_full_table() {
        // A session left behind at epoch 0 rejoins while the cluster is
        // already at epoch 2: its fenced wait is superseded, it chases
        // the newest epoch, detects the missed delta via the version
        // gap, and resyncs from the binary table — without hanging.
        let cfg = ParallelismConfig::dp(4);
        let server = TcpStoreServer::start().unwrap();
        let t0 = table(4);
        let mut session =
            NodeSession::start(server.endpoints(), 0, t0.clone(), &cfg, 0).unwrap();

        // two epochs happen without this session participating
        let mut coord_table = t0;
        let mut coord = StoreSession::connect(server.endpoints()).unwrap();
        coord_table.substitute(replacement(1, 0)).unwrap();
        coord_table.substitute(replacement(2, 1)).unwrap();
        coord.advance_epoch(2).unwrap();
        let rec = EpochRecord {
            epoch: 2,
            version: coord_table.version,
            world: 4,
            participants: 1,
            subs: vec![replacement(2, 1)], // epoch 1's sub is missing
        };
        coord.set(&k_table(2), &coord_table.encode_bin()).unwrap();
        coord.set(&k_delta(2), rec.to_json().render().as_bytes()).unwrap();

        let out = session.rejoin(&cfg, 1).unwrap();
        assert_eq!(out.epoch, 2);
        assert_eq!(session.table, coord_table);
        assert_eq!(session.groups.epoch, 2);
        // executed ops: superseded batch stops at the fenced wait (1),
        // the retried batch runs delta wait + arrive (2), then the
        // table resync fetch (1) and the release wait (1)
        assert_eq!(out.ops, 5);
    }

    #[test]
    fn dead_participant_aborts_epoch_instead_of_stalling() {
        // DESIGN §8 known limitation (1), resolved: an agent that died
        // before arriving used to stall the episode until the store's
        // 300s client read timeout. The supervised barrier now aborts
        // the epoch at the join deadline, releasing every fenced
        // waiter with a retryable EpochAborted; a retry past the
        // tombstoned epoch converges.
        let cfg = ParallelismConfig::dp(4);
        let server = TcpStoreServer::start().unwrap();
        let t = table(4);

        // one live survivor that WILL arrive; the second expected
        // participant never does (it died before arriving)
        let mut s =
            NodeSession::start(server.endpoints(), 0, t.clone(), &cfg, 0).unwrap();
        let cfg2 = cfg.clone();
        let survivor = std::thread::spawn(move || s.rejoin(&cfg2, 1));

        let (tx, watchdog) =
            supervise_barrier(server.endpoints(), 1, Duration::from_millis(400));
        let mut coord = StoreSession::connect(server.endpoints()).unwrap();
        let mut ct = t.clone();
        let no_failed: [usize; 0] = [];
        let t0 = Instant::now();
        let coord_res = coordinate(&mut coord, &mut ct, &no_failed, 1, 2);
        let _ = tx.send(());
        watchdog.join().unwrap();

        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "abort must be prompt, not a read-timeout stall"
        );
        let cerr = coord_res.unwrap_err();
        assert_eq!(
            epoch_aborted(&cerr),
            Some(EpochAborted { current: 2 }),
            "{cerr:#}"
        );
        let serr = survivor.join().unwrap().unwrap_err();
        assert!(epoch_aborted(&serr).is_some(), "{serr:#}");
        assert_eq!(server.epoch(), 2, "abort must fence the epoch");

        // retry past the tombstone (from_epoch = aborted current) with
        // the participants that actually exist: converges
        let out = rebuild_episode(
            &server.endpoints(),
            &t,
            &cfg,
            &[1],
            &[replacement(1, 0)],
            2,
            &EpisodeConfig { live_survivors: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.epoch, 3);
        assert_eq!(out.table.entries[1], replacement(1, 0));
    }

    #[test]
    fn watchdog_stands_down_after_release() {
        // A completed barrier must not be aborted retroactively.
        let server = TcpStoreServer::start().unwrap();
        let mut c = StoreSession::connect(server.endpoints()).unwrap();
        c.set(&k_go(1), b"go").unwrap();
        let (tx, watchdog) =
            supervise_barrier(server.endpoints(), 1, Duration::from_millis(50));
        // deliberately do NOT signal before the deadline
        std::thread::sleep(Duration::from_millis(150));
        watchdog.join().unwrap();
        drop(tx);
        assert_eq!(server.epoch(), 0, "released barrier must not be aborted");
        assert_eq!(c.get(&k_delta(2)).unwrap(), None, "no tombstone");
    }

    #[test]
    fn episode_rejects_mismatched_replacements() {
        let cfg = ParallelismConfig::dp(4);
        let server = TcpStoreServer::start().unwrap();
        let t = table(4);
        let opts = EpisodeConfig::default();
        assert!(
            rebuild_episode(&server.endpoints(), &t, &cfg, &[1], &[], 0, &opts).is_err()
        );
        assert!(rebuild_episode(
            &server.endpoints(),
            &t,
            &cfg,
            &[1],
            &[replacement(2, 0)],
            0,
            &opts
        )
        .is_err());
    }

    #[test]
    fn sample_stride_bounds_and_spreads() {
        let ranks: Vec<usize> = (0..100).collect();
        assert_eq!(sample_stride(&ranks, 0), Vec::<usize>::new());
        assert_eq!(sample_stride(&ranks, 200), ranks);
        let s = sample_stride(&ranks, 10);
        assert_eq!(s.len(), 10);
        assert_eq!(s[0], 0);
        assert!(s.windows(2).all(|w| w[0] < w[1]), "strictly increasing");
        assert!(*s.last().unwrap() >= 90);
    }

    #[test]
    fn topology_covers_world() {
        for n in [64usize, 256, 1024, 8192, 100] {
            let p = topology_for(n);
            assert_eq!(p.world_size(), n);
            p.validate().unwrap();
        }
    }
}
