//! Step-tag protocol (paper §III-E-c): deciding *when* it is safe to
//! issue stop/clean/reset and *which step* to resume from.
//!
//! Tags reported by each training process:
//! * `i`  — executing forward/backward of step i (params at version i);
//! * `-1` — executing the optimizer step (update in flight);
//! * `i+1`— optimizer step complete (params at version i+1).
//!
//! Because the gradient allreduce is a barrier immediately before the
//! optimizer step, a failure before the barrier leaves every surviving
//! process at tag `i` (resume from step i), and a failure after it lets
//! every survivor finish the update and reach `i+1` (resume from i+1).
//! A survivor can transiently report `-1`; the controller must wait it
//! out before acting — acting while an update is in flight could reset
//! a device mid-write.

/// The paper's "in optimizer step" tag.
pub const TAG_OPTIMIZER: i64 = -1;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TagDecision {
    /// Some survivor is mid-optimizer: do NOT stop/clean/reset yet.
    Wait,
    /// Safe to act: resume from `resume_step`; ranks whose state is at
    /// `resume_step` are valid replica sources.
    Act { resume_step: u64 },
}

/// Decide from the survivors' current tags (device-plugin heartbeats).
///
/// `tags` must be non-empty and contain only `-1` or step indices.
pub fn decide(tags: &[i64]) -> TagDecision {
    assert!(!tags.is_empty(), "no survivor tags");
    if tags.iter().any(|&t| t == TAG_OPTIMIZER) {
        return TagDecision::Wait;
    }
    let max = tags.iter().copied().max().unwrap();
    debug_assert!(max >= 0);
    TagDecision::Act { resume_step: max as u64 }
}

/// Given each survivor's *state* step (completed updates), classify who
/// serves as a replica source and who must be restored alongside the
/// replacement ranks. Returns (resume_step, source_ranks, behind_ranks).
pub fn plan_restore(survivor_steps: &[(usize, u64)]) -> (u64, Vec<usize>, Vec<usize>) {
    assert!(!survivor_steps.is_empty());
    let resume = survivor_steps.iter().map(|&(_, s)| s).max().unwrap();
    let mut sources = Vec::new();
    let mut behind = Vec::new();
    for &(rank, s) in survivor_steps {
        if s == resume {
            sources.push(rank);
        } else {
            behind.push(rank);
        }
    }
    (resume, sources, behind)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn all_in_fwd_bwd_resumes_at_i() {
        assert_eq!(decide(&[5, 5, 5]), TagDecision::Act { resume_step: 5 });
    }

    #[test]
    fn all_past_optimizer_resumes_at_i_plus_1() {
        assert_eq!(decide(&[6, 6, 6]), TagDecision::Act { resume_step: 6 });
    }

    #[test]
    fn any_optimizer_in_flight_waits() {
        assert_eq!(decide(&[6, TAG_OPTIMIZER, 5]), TagDecision::Wait);
        assert_eq!(decide(&[TAG_OPTIMIZER]), TagDecision::Wait);
    }

    #[test]
    fn mixed_tags_resume_at_max() {
        // Failure raced the barrier: some ranks updated, some aborted
        // mid-allreduce. Resume at the updated version; the laggards
        // are restored from a replica.
        assert_eq!(decide(&[5, 6]), TagDecision::Act { resume_step: 6 });
    }

    #[test]
    fn plan_restore_splits_sources_and_behind() {
        let (resume, sources, behind) =
            plan_restore(&[(0, 6), (2, 5), (3, 6)]);
        assert_eq!(resume, 6);
        assert_eq!(sources, vec![0, 3]);
        assert_eq!(behind, vec![2]);
    }

    #[test]
    fn plan_restore_all_equal_has_no_behind() {
        let (resume, sources, behind) = plan_restore(&[(0, 4), (1, 4)]);
        assert_eq!(resume, 4);
        assert_eq!(sources, vec![0, 1]);
        assert!(behind.is_empty());
    }

    #[test]
    #[should_panic]
    fn empty_tags_panics() {
        decide(&[]);
    }

    #[test]
    fn prop_decision_never_loses_an_update() {
        // Whatever mix of i / i+1 the survivors report, the chosen
        // resume step equals the most-updated surviving state, so no
        // completed optimizer work is discarded and laggards always
        // have a source.
        prop::check("tag decision", 300, |rng| {
            let i = rng.below(1000) as i64;
            let n = 1 + rng.below(8) as usize;
            let tags: Vec<i64> = (0..n)
                .map(|_| if rng.bool(0.5) { i } else { i + 1 })
                .collect();
            match decide(&tags) {
                TagDecision::Wait => Err("unexpected wait".into()),
                TagDecision::Act { resume_step } => {
                    let max = *tags.iter().max().unwrap() as u64;
                    prop::assert_eq_prop(&resume_step, &max)?;
                    let steps: Vec<(usize, u64)> = tags
                        .iter()
                        .enumerate()
                        .map(|(r, &t)| (r, t as u64))
                        .collect();
                    let (resume, sources, behind) = plan_restore(&steps);
                    prop::assert_eq_prop(&resume, &max)?;
                    prop::assert_prop(!sources.is_empty(), "no source")?;
                    prop::assert_eq_prop(&(sources.len() + behind.len()), &n)
                }
            }
        });
    }
}
