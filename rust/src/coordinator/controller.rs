//! The global controller (paper §III-C/D/E): spawns and monitors DP
//! workers, detects failures via heartbeats + device plugins, and
//! drives recovery — checkpoint-free FlashRecovery or the vanilla
//! timeout + checkpoint-reload baseline.
//!
//! Real execution plane: every "device" is an OS thread running actual
//! training steps through the AOT-compiled PJRT executables; the
//! collective allreduce is the barrier the step-tag protocol brackets.

use super::detection::{Detection, HeartbeatMonitor, LeaseConfig, LeaseMonitor};
use super::events::{RecoveryRecord, RunReport, ShardRestoreStat};
use super::ranktable::{RankEntry, Ranktable, SharedRanktable};
use super::rendezvous::{rebuild_episode, EpisodeConfig, RebuildOutcome};
use super::restore::{plan_shard_restore, restore_episode, RestoreOutcome, RestorePlan};
use crate::checkpoint::{CheckpointManager, Snapshot};

use crate::comms::replication::{ReplicaSet, StoreEndpoints, StoreSession};
use crate::comms::state_stream::{EpochFence, StreamConfig};
use crate::comms::{Collective, CollectiveError};
use crate::config::{ParallelismConfig, RecoveryMode};
use crate::runtime::ModelBundle;
use crate::telemetry::{global, log, trace};
use crate::training::data::{DataConfig, DataIterator};
use crate::training::state::WorkerState;
use crate::training::worker::{
    now_ms, spawn_heartbeat, worker_main, FailurePlan, HeartbeatCfg, MonitorBoard,
    WorkerCommand, WorkerCtx, WorkerEvent,
};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Controller/engine configuration for a real training run.
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Data-parallel degree (worker thread count).
    pub dp: usize,
    /// Optimizer steps to run.
    pub steps: u64,
    /// Seed for init + data (all DP ranks share the init seed so their
    /// model states are true replicas).
    pub seed: u64,
    pub mode: RecoveryMode,
    /// Heartbeat scan period.
    pub heartbeat_interval: Duration,
    /// Collective timeout — the vanilla baseline's detection latency.
    pub collective_timeout: Duration,
    /// Periodic checkpoint interval in steps (0 = never; FlashRecovery
    /// runs with 0 by design).
    pub ckpt_interval: u64,
    pub ckpt_dir: PathBuf,
    /// Scripted failures (injected into the matching worker thread).
    pub failures: Vec<FailurePlan>,
    /// Hard wall-clock cap for the whole run.
    pub max_wall: Duration,
    /// Shared-file ranktable location (maintained across recoveries).
    pub ranktable_path: Option<PathBuf>,
    /// Rebuild communication groups over a live TCP store during flash
    /// recovery (epoch-fenced rendezvous, DESIGN.md §8) instead of
    /// substituting the ranktable in place.
    pub rebuild_groups: bool,
    /// ZeRO partition-group size for the replica-location model (1 =
    /// vanilla DP, fully replicated; must divide `dp`). Worker states
    /// are physically full replicas either way — the shard model
    /// drives restore *planning*: which surviving replica serves which
    /// lost rank, and when no replica survives (checkpoint fallback).
    pub zero_shards: usize,
    /// Store replicas behind the coordination plane (DESIGN.md §13).
    /// 0 = a plain un-replicated primary; 1–2 = every mutating store
    /// op is quorum-acked onto that many standby replicas, and a
    /// standby controller can adopt the lease table + in-flight
    /// episode checkpoint after a primary crash.
    pub store_replicas: usize,
}

impl ControllerConfig {
    pub fn flash(dp: usize, steps: u64) -> Self {
        ControllerConfig {
            dp,
            steps,
            seed: 0,
            mode: RecoveryMode::Flash,
            heartbeat_interval: Duration::from_millis(100),
            collective_timeout: Duration::from_secs(3600),
            ckpt_interval: 0,
            ckpt_dir: std::env::temp_dir().join("flashrec-ckpt"),
            failures: Vec::new(),
            max_wall: Duration::from_secs(1800),
            ranktable_path: None,
            rebuild_groups: true,
            zero_shards: 1,
            store_replicas: 0,
        }
    }

    pub fn vanilla(dp: usize, steps: u64, ckpt_interval: u64, timeout: Duration) -> Self {
        let mut c = Self::flash(dp, steps);
        c.mode = RecoveryMode::Vanilla;
        c.ckpt_interval = ckpt_interval;
        c.collective_timeout = timeout;
        c
    }

    /// Build from a declarative [`crate::config::JobConfig`] (the
    /// JSON-file config system; see `flashrecovery train --config`).
    pub fn from_job(job: &crate::config::JobConfig) -> anyhow::Result<Self> {
        job.validate()?;
        if job.parallelism.pp != 1 || job.parallelism.tp != 1 {
            anyhow::bail!(
                "the real execution plane runs DP-only (pp=tp=1); \
                 model-parallel topologies are exercised by the replica-\
                 location logic and the simulator (DESIGN.md §5)"
            );
        }
        let mut c = Self::flash(job.parallelism.dp, job.steps);
        c.seed = job.seed;
        c.zero_shards = job.parallelism.zero.shards();
        c.mode = job.recovery.mode;
        c.heartbeat_interval =
            Duration::from_secs_f64(job.cluster.heartbeat_interval_s.max(0.01));
        c.collective_timeout =
            Duration::from_secs_f64(job.cluster.collective_timeout_s.max(0.1));
        c.ckpt_interval = job.checkpoint.interval_steps;
        c.ckpt_dir = PathBuf::from(&job.checkpoint.dir);
        Ok(c)
    }
}

// ---------------------------------------------------------------------------
// Replicated coordination state (DESIGN.md §13): what a standby
// controller adopts after the primary controller dies.
// ---------------------------------------------------------------------------

/// Store key holding the serialized lease table (rank -> incarnation).
pub const K_LEASES: &str = "ctl/leases";
/// Store key holding the in-flight recovery episode checkpoint.
pub const K_EPISODE: &str = "ctl/episode";

/// Where a recovery episode was when its checkpoint was written.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EpisodePhase {
    /// Failure detected; the fleet is parking, no epoch advanced yet.
    Detection,
    /// Replacements spawned; the rendezvous epoch is being rebuilt.
    Rebuild,
    /// Groups rebuilt; shard transfers are (or are about to be) in
    /// flight at the checkpointed epoch.
    Restore,
}

impl EpisodePhase {
    pub fn as_str(&self) -> &'static str {
        match self {
            EpisodePhase::Detection => "detection",
            EpisodePhase::Rebuild => "rebuild",
            EpisodePhase::Restore => "restore",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "detection" => Ok(EpisodePhase::Detection),
            "rebuild" => Ok(EpisodePhase::Rebuild),
            "restore" => Ok(EpisodePhase::Restore),
            other => bail!("unknown episode phase {other:?}"),
        }
    }
}

/// The in-flight [`RecoveryRecord`] skeleton, persisted to the
/// replicated store at each phase boundary of `flash_recover` and
/// deleted when the episode completes. `key=value;` encoded so a
/// standby built at a different version can still parse the fields it
/// knows.
#[derive(Debug, Clone, PartialEq)]
pub struct EpisodeCheckpoint {
    pub phase: EpisodePhase,
    /// Rendezvous epoch the episode targets (phase >= Rebuild).
    pub epoch: u64,
    pub dead: Vec<usize>,
    /// Resume step from the restore plan (0 while unplanned).
    pub resume_step: u64,
    pub detection_s: f64,
    pub rebuild_s: f64,
}

impl EpisodeCheckpoint {
    pub fn encode(&self) -> Vec<u8> {
        let dead: Vec<String> = self.dead.iter().map(|r| r.to_string()).collect();
        format!(
            "phase={};epoch={};dead={};resume_step={};detection_s={:.6};rebuild_s={:.6}",
            self.phase.as_str(),
            self.epoch,
            dead.join(" "),
            self.resume_step,
            self.detection_s,
            self.rebuild_s,
        )
        .into_bytes()
    }

    pub fn parse(bytes: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(bytes).context("episode checkpoint utf8")?;
        let mut phase = None;
        let mut epoch = 0u64;
        let mut dead = Vec::new();
        let mut resume_step = 0u64;
        let mut detection_s = 0.0f64;
        let mut rebuild_s = 0.0f64;
        for kv in text.split(';') {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("episode checkpoint field {kv:?}"))?;
            match k {
                "phase" => phase = Some(EpisodePhase::parse(v)?),
                "epoch" => epoch = v.parse().context("epoch")?,
                "dead" => {
                    dead = v
                        .split_whitespace()
                        .map(str::parse)
                        .collect::<Result<_, _>>()
                        .context("dead ranks")?
                }
                "resume_step" => resume_step = v.parse().context("resume_step")?,
                "detection_s" => detection_s = v.parse().context("detection_s")?,
                "rebuild_s" => rebuild_s = v.parse().context("rebuild_s")?,
                _ => {} // forward-compatible: ignore unknown fields
            }
        }
        Ok(EpisodeCheckpoint {
            phase: phase.context("episode checkpoint missing phase")?,
            epoch,
            dead,
            resume_step,
            detection_s,
            rebuild_s,
        })
    }
}

/// `rank:incarnation` pairs, comma-joined. Empty table -> empty value.
pub fn encode_leases(leases: &[(usize, u64)]) -> Vec<u8> {
    let parts: Vec<String> =
        leases.iter().map(|(r, i)| format!("{r}:{i}")).collect();
    parts.join(",").into_bytes()
}

pub fn parse_leases(bytes: &[u8]) -> Result<Vec<(usize, u64)>> {
    let text = std::str::from_utf8(bytes).context("lease table utf8")?;
    text.split(',')
        .filter(|s| !s.is_empty())
        .map(|pair| {
            let (r, i) = pair
                .split_once(':')
                .with_context(|| format!("lease entry {pair:?}"))?;
            Ok((r.parse().context("rank")?, i.parse().context("incarnation")?))
        })
        .collect()
}

/// Everything a standby controller recovers from the replicated store.
#[derive(Debug, Clone)]
pub struct AdoptedState {
    pub leases: Vec<(usize, u64)>,
    pub episode: Option<EpisodeCheckpoint>,
    /// The beat table as the surviving store saw it: the promoted
    /// standby resumes stall detection from the workers' last reported
    /// step tags and device codes instead of a blank slate.
    pub beats: Vec<crate::comms::tcp_store::BeatRecord>,
}

/// Read the lease table, in-flight episode checkpoint, and replicated
/// beat table back out of the (possibly failed-over) coordination
/// plane.
pub fn adopt_coordination_state(session: &mut StoreSession) -> Result<AdoptedState> {
    let leases = match session.get(K_LEASES)? {
        Some(b) => parse_leases(&b)?,
        None => Vec::new(),
    };
    let episode = match session.get(K_EPISODE)? {
        Some(b) => Some(EpisodeCheckpoint::parse(&b)?),
        None => None,
    };
    let beats = session.beats()?;
    Ok(AdoptedState { leases, episode, beats })
}

/// A standby controller: connects to the surviving coordination plane
/// (discovering the promoted primary if the original died), adopts the
/// replicated lease table and episode checkpoint, and resumes a
/// half-finished detection -> rebuild -> restore episode where the dead
/// controller left off.
pub struct StandbyController {
    session: StoreSession,
    pub adopted: AdoptedState,
}

impl StandbyController {
    pub fn adopt(store: &StoreEndpoints) -> Result<StandbyController> {
        let mut session = StoreSession::try_connect(store)?;
        let adopted = adopt_coordination_state(&mut session)?;
        Ok(StandbyController { session, adopted })
    }

    /// Re-open every adopted lease in a fresh monitor with a full
    /// grace window: adopted workers are presumed alive until they
    /// miss beats against the *new* controller's clock, so adoption
    /// itself can never false-positive a detection. Adopted beats are
    /// replayed restamped to admission time — step tags, progress
    /// marks, and device codes carry across the failover (a silently
    /// stalled worker is caught after one stall window instead of
    /// never) without backdating anyone's grace.
    pub fn resume_lease_monitor(&self, cfg: LeaseConfig) -> LeaseMonitor {
        let mut m = LeaseMonitor::new(cfg);
        let now = Instant::now();
        for &(rank, inc) in &self.adopted.leases {
            m.admit(rank, inc, now);
        }
        for b in &self.adopted.beats {
            m.observe(b.rank as usize, b.incarnation, b.step_tag, b.device_code, now);
        }
        m
    }

    /// Finish the rendezvous the dead controller left mid-flight
    /// (adopted phase <= Rebuild): re-drives the epoch-fenced episode
    /// from the store's *current* epoch — safe because a half-applied
    /// epoch advance is fenced, never resumable — then rolls the
    /// checkpoint forward to the restore phase.
    pub fn resume_rebuild(
        &mut self,
        table: &Ranktable,
        par: &ParallelismConfig,
        replacements: &[RankEntry],
        opts: &EpisodeConfig,
    ) -> Result<RebuildOutcome> {
        let ck = self
            .adopted
            .episode
            .clone()
            .context("no adopted episode to resume")?;
        if ck.phase > EpisodePhase::Rebuild {
            bail!("episode already past rebuild (phase {:?})", ck.phase);
        }
        let from = self.session.stats()?.gauge("store.epoch").max(0) as u64;
        let eps = self.session.endpoints().clone();
        let out = rebuild_episode(&eps, table, par, &ck.dead, replacements, from, opts)?;
        let next = EpisodeCheckpoint {
            phase: EpisodePhase::Restore,
            epoch: out.epoch,
            ..ck
        };
        self.checkpoint(&next)?;
        self.adopted.episode = Some(next);
        Ok(out)
    }

    /// Finish the shard-restore leg at the adopted epoch, then clear
    /// the episode checkpoint — the episode is over.
    pub fn resume_restore(
        &mut self,
        plan: &RestorePlan,
        states: &std::collections::BTreeMap<usize, Snapshot>,
        fence: &EpochFence,
        stream: &StreamConfig,
    ) -> Result<RestoreOutcome> {
        let epoch = self
            .adopted
            .episode
            .as_ref()
            .context("no adopted episode to resume")?
            .epoch;
        let eps = self.session.endpoints().clone();
        let out = restore_episode(&eps, plan, states, epoch, fence, stream)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        self.clear_episode()?;
        Ok(out)
    }

    /// Overwrite the replicated episode checkpoint.
    pub fn checkpoint(&mut self, ck: &EpisodeCheckpoint) -> Result<()> {
        self.session.set(K_EPISODE, &ck.encode())
    }

    /// Delete the replicated episode checkpoint (episode complete).
    pub fn clear_episode(&mut self) -> Result<()> {
        self.session.del_prefix(K_EPISODE)?;
        self.adopted.episode = None;
        Ok(())
    }
}

struct WorkerHandle {
    #[allow(dead_code)]
    rank: usize,
    cmd_tx: Sender<WorkerCommand>,
    board: Arc<MonitorBoard>,
    thread: Option<std::thread::JoinHandle<()>>,
    /// Heartbeat emitter pushing this worker's beats to the live
    /// plane; `None` when the plane is down. Exits on its own within
    /// one push interval of the worker's death.
    hb: Option<std::thread::JoinHandle<()>>,
}

/// Worker heartbeat push interval: half the controller's scan period
/// (floored at 5 ms), so a 3-miss lease expires within ~1.5 scans.
fn hb_emit_interval(cfg: &ControllerConfig) -> Duration {
    (cfg.heartbeat_interval / 2).max(Duration::from_millis(5))
}

/// The controller: owns the worker fleet for one training run.
pub struct Controller {
    bundle: Arc<ModelBundle>,
    cfg: ControllerConfig,
    collective: Arc<Collective>,
    event_tx: Sender<WorkerEvent>,
    event_rx: Receiver<WorkerEvent>,
    monitor: HeartbeatMonitor,
    workers: BTreeMap<usize, WorkerHandle>,
    ranktable: Ranktable,
    shared_rt: Option<SharedRanktable>,
    /// Live coordination plane for group reconstruction, heartbeats,
    /// and state discovery — a primary store plus
    /// `cfg.store_replicas` quorum-acked replicas (DESIGN.md §13);
    /// `None` when disabled or the local bind failed (recovery then
    /// degrades to in-place ranktable substitution and board-scan
    /// detection).
    rebuild_plane: Option<ReplicaSet>,
    /// Controller's own session onto the plane, used to persist the
    /// lease table and episode checkpoints a standby would adopt.
    ctl: Option<StoreSession>,
    /// Wire-plane detection over leased heartbeats (DESIGN.md §10);
    /// present exactly when `rebuild_plane` is.
    lease: Option<LeaseMonitor>,
    /// Reused snapshot buffer for the per-scan beat drain — the scan
    /// runs every heartbeat interval for the whole job, so it must not
    /// allocate a fresh Vec each time.
    beat_scratch: Vec<crate::comms::tcp_store::BeatRecord>,
    rebuild_epoch: u64,
    report: RunReport,
    stopped: BTreeMap<usize, u64>, // rank -> param hash
    parked: BTreeMap<usize, (u64, CollectiveError)>, // rank -> (state step, err)
    /// rank -> scripted failures already consumed (workers die only via
    /// plans, so each death advances its rank's cursor by one).
    plans_fired: BTreeMap<usize, usize>,
}

impl Controller {
    pub fn new(bundle: Arc<ModelBundle>, cfg: ControllerConfig) -> Result<Self> {
        if cfg.dp == 0 {
            bail!("dp must be >= 1");
        }
        if cfg.zero_shards == 0 || cfg.dp % cfg.zero_shards != 0 {
            bail!(
                "zero_shards={} must divide dp={}",
                cfg.zero_shards,
                cfg.dp
            );
        }
        let (event_tx, event_rx) = channel();
        let collective = Collective::new(cfg.dp, cfg.collective_timeout);
        let entries = (0..cfg.dp)
            .map(|rank| RankEntry {
                rank,
                node: rank, // one simulated device per node in real mode
                device: 0,
                addr: format!("127.0.0.1:{}", 29000 + rank),
            })
            .collect();
        let ranktable = Ranktable::new(entries);
        let shared_rt = cfg.ranktable_path.clone().map(SharedRanktable::new);
        // Vanilla recovery re-establishes everything from scratch and
        // never drives an episode — don't bind a listener for it.
        let rebuild_plane = if cfg.rebuild_groups && cfg.mode == RecoveryMode::Flash {
            ReplicaSet::start(cfg.store_replicas).ok()
        } else {
            None
        };
        let ctl = rebuild_plane.as_ref().and_then(|p| p.session().ok());
        let lease = rebuild_plane.as_ref().map(|_| {
            LeaseMonitor::new(LeaseConfig {
                interval: hb_emit_interval(&cfg),
                lease_misses: 3,
                // slack for slow PJRT steps: lockstep keeps the group
                // within one tag of the median, so margin 2 plus ten
                // scan periods of patience cannot false-positive
                stall_after: cfg.heartbeat_interval * 10,
                stall_margin: 2,
            })
        });
        Ok(Controller {
            bundle,
            cfg,
            collective,
            event_tx,
            event_rx,
            monitor: HeartbeatMonitor::new(),
            workers: BTreeMap::new(),
            ranktable,
            shared_rt,
            rebuild_plane,
            ctl,
            lease,
            beat_scratch: Vec::new(),
            rebuild_epoch: 0,
            report: RunReport::default(),
            stopped: BTreeMap::new(),
            parked: BTreeMap::new(),
            plans_fired: BTreeMap::new(),
        })
    }

    fn data_iter(&self) -> DataIterator {
        let d = &self.bundle.manifest.dims;
        DataIterator::new(DataConfig::for_model(
            d.vocab,
            d.seq,
            d.batch,
            self.cfg.seed.wrapping_add(1),
        ))
    }

    fn ckpt_manager_for(&self, rank: usize) -> Result<Option<CheckpointManager>> {
        // Rank 0 writes checkpoints (states are DP replicas).
        if self.cfg.ckpt_interval > 0 && rank == 0 {
            Ok(Some(CheckpointManager::new(&self.cfg.ckpt_dir, 0, 2, true)?))
        } else {
            Ok(None)
        }
    }

    fn spawn_worker(
        &mut self,
        rank: usize,
        state: WorkerState,
        start_parked: bool,
        failure: Option<FailurePlan>,
    ) -> Result<()> {
        let (cmd_tx, cmd_rx) = channel();
        let board = MonitorBoard::new();
        board.step_tag.store(state.step as i64, Ordering::SeqCst);
        let ctx = WorkerCtx {
            rank,
            bundle: self.bundle.clone(),
            data: self.data_iter(),
            collective: self.collective.clone(),
            cmd_rx,
            event_tx: self.event_tx.clone(),
            board: board.clone(),
            failure,
            ckpt: self.ckpt_manager_for(rank)?,
            ckpt_interval: self.cfg.ckpt_interval,
            state,
            max_steps: self.cfg.steps,
            start_parked,
            redundancy: None,
        };
        let thread = std::thread::Builder::new()
            .name(format!("worker-{rank}"))
            .spawn(move || worker_main(ctx))?;
        self.monitor.watch(rank, board.clone());
        // Light the wire plane for this worker: open its lease and
        // spawn the heartbeat emitter under the fresh incarnation.
        let hb = match (self.lease.as_mut(), self.rebuild_plane.as_ref()) {
            (Some(lease), Some(server)) => {
                let inc = self
                    .monitor
                    .incarnation_of(rank)
                    .expect("rank was just watched");
                lease.admit(rank, inc, Instant::now());
                Some(spawn_heartbeat(
                    rank,
                    board.clone(),
                    HeartbeatCfg {
                        store: server.endpoints(),
                        interval: hb_emit_interval(&self.cfg),
                        incarnation: inc,
                    },
                ))
            }
            _ => None,
        };
        if let Some(old) = self.workers.insert(
            rank,
            WorkerHandle { rank, cmd_tx, board, thread: Some(thread), hb },
        ) {
            // Join the previous worker thread and its emitter. Drop
            // the command sender *first*: a stall-detected worker is
            // dead to the cluster but its thread may still be parked,
            // and a parked worker only exits once its channel closes —
            // joining while holding the sender would deadlock.
            let WorkerHandle { cmd_tx: old_tx, thread: old_thread, hb: old_hb, .. } = old;
            drop(old_tx);
            if let Some(t) = old_thread {
                let _ = t.join();
            }
            if let Some(h) = old_hb {
                let _ = h.join();
            }
        }
        self.persist_leases();
        Ok(())
    }

    /// Replicate the live lease table (rank -> incarnation) so a
    /// standby controller can adopt it after a primary-controller
    /// crash. Best-effort: a plane hiccup degrades adoption fidelity,
    /// never the training run.
    fn persist_leases(&mut self) {
        if self.ctl.is_none() {
            return;
        }
        let leases: Vec<(usize, u64)> = self
            .workers
            .keys()
            .copied()
            .filter(|r| !self.stopped.contains_key(r))
            .filter_map(|r| Some((r, self.monitor.incarnation_of(r)?)))
            .collect();
        let encoded = encode_leases(&leases);
        if let Some(ctl) = self.ctl.as_mut() {
            if let Err(e) = ctl.set(K_LEASES, &encoded) {
                log::warn("controller", || format!("lease persist failed: {e}"));
            }
        }
    }

    /// Replicate an episode checkpoint at a phase boundary.
    fn persist_episode(&mut self, ck: &EpisodeCheckpoint) {
        if let Some(ctl) = self.ctl.as_mut() {
            if let Err(e) = ctl.set(K_EPISODE, &ck.encode()) {
                log::warn("controller", || {
                    format!("episode checkpoint persist failed: {e}")
                });
            }
        }
    }

    /// Drop the episode checkpoint — the episode completed.
    fn clear_episode(&mut self) {
        if let Some(ctl) = self.ctl.as_mut() {
            let _ = ctl.del_prefix(K_EPISODE);
        }
    }

    fn publish_ranktable(&self) -> Result<()> {
        if let Some(rt) = &self.shared_rt {
            rt.publish(&self.ranktable)?;
        }
        Ok(())
    }

    /// Run the whole job; returns the report with losses + recoveries.
    pub fn run(mut self) -> Result<RunReport> {
        let start = Instant::now();
        // initial fleet: identical replicas from the shared init seed
        for rank in 0..self.cfg.dp {
            let state = WorkerState::init(&self.bundle, self.cfg.seed as i32)?;
            let failure = self.plan_for(rank);
            self.spawn_worker(rank, state, false, failure)?;
        }
        self.publish_ranktable()?;

        let mut last_scan = Instant::now();
        loop {
            if start.elapsed() > self.cfg.max_wall {
                self.stop_all();
                bail!("run exceeded max_wall {:?}", self.cfg.max_wall);
            }
            // ---- event pump ------------------------------------------
            match self.event_rx.recv_timeout(self.cfg.heartbeat_interval / 2) {
                Ok(ev) => self.handle_event(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("all workers gone"),
            }
            while let Ok(ev) = self.event_rx.try_recv() {
                self.handle_event(ev);
            }
            if self.stopped.len() == self.cfg.dp {
                break;
            }

            // ---- heartbeat scan (detection) ---------------------------
            if last_scan.elapsed() >= self.cfg.heartbeat_interval {
                last_scan = Instant::now();
                // wire plane first (measured latencies), board scan as
                // the authoritative fallback; dedup by rank
                let mut detections = self.wire_scan();
                for d in self.monitor.scan() {
                    if !detections.iter().any(|e| e.rank == d.rank) {
                        detections.push(d);
                    }
                }
                let mut detections: Vec<_> = detections
                    .into_iter()
                    .filter(|d| !self.stopped.contains_key(&d.rank))
                    .collect();
                // board detections that won the race still get the
                // wire plane's measured last-good-beat gap
                if let Some(lease) = self.lease.as_ref() {
                    let now = Instant::now();
                    for d in detections.iter_mut() {
                        if d.latency_s.is_none() {
                            d.latency_s = lease.since_last_beat(d.rank, now);
                        }
                    }
                }
                if !detections.is_empty() {
                    let dead: Vec<usize> =
                        detections.iter().map(|d| d.rank).collect();
                    match self.cfg.mode {
                        RecoveryMode::Flash => self.flash_recover(&detections)?,
                        RecoveryMode::Vanilla => {
                            self.vanilla_recover(&detections, dead)?
                        }
                    }
                }
            }
        }

        // ---- wrap up -------------------------------------------------
        for (_, w) in self.workers.iter_mut() {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
            if let Some(h) = w.hb.take() {
                let _ = h.join();
            }
        }
        let hashes: Vec<u64> = self.stopped.values().copied().collect();
        self.report.final_param_divergence =
            if hashes.windows(2).all(|w| w[0] == w[1]) { 0.0 } else { f32::NAN };
        self.report.final_step = self.cfg.steps;
        self.report.wall_s = start.elapsed().as_secs_f64();
        Ok(self.report)
    }

    /// The next unconsumed scripted failure for `rank` (plans fire in
    /// step order; every death advances the rank's cursor via
    /// [`Self::consume_plan`]). This is what a replacement worker
    /// inherits, so a flaky rank can be made to fail repeatedly (chaos
    /// flap campaigns) without ever re-triggering a spent plan.
    fn plan_for(&self, rank: usize) -> Option<FailurePlan> {
        let fired = self.plans_fired.get(&rank).copied().unwrap_or(0);
        let mut plans: Vec<FailurePlan> = self
            .cfg
            .failures
            .iter()
            .copied()
            .filter(|f| f.rank == rank)
            .collect();
        plans.sort_by_key(|f| f.step);
        plans.get(fired).copied()
    }

    /// Record that `rank`'s current plan fired (the worker died).
    fn consume_plan(&mut self, rank: usize) {
        *self.plans_fired.entry(rank).or_insert(0) += 1;
    }

    /// Scan the live heartbeat plane (when up): drain the store's
    /// beat records into the lease monitor and return new wire
    /// detections — lease expiries, pushed device codes, and step-tag
    /// stalls the board scan cannot see.
    fn wire_scan(&mut self) -> Vec<Detection> {
        let primary = self.rebuild_plane.as_ref().and_then(|p| p.primary_server());
        let (lease, server) = match (self.lease.as_mut(), primary) {
            (Some(lease), Some(server)) => (lease, server),
            _ => return Vec::new(),
        };
        server.beats_into(&mut self.beat_scratch);
        for b in &self.beat_scratch {
            lease.observe_beat(b);
        }
        lease.scan(Instant::now())
    }

    fn handle_event(&mut self, ev: WorkerEvent) {
        match ev {
            WorkerEvent::Loss { rank, step, loss } => {
                if rank == 0 {
                    // last-write-wins: recovery replays overwrite cleanly
                    match self.report.losses.iter_mut().find(|(s, _)| *s == step) {
                        Some(slot) => slot.1 = loss,
                        None => self.report.losses.push((step, loss)),
                    }
                }
            }
            WorkerEvent::Parked { rank, state_step, err } => {
                self.parked.insert(rank, (state_step, err));
            }
            WorkerEvent::Stopped { rank, param_hash, .. } => {
                self.stopped.insert(rank, param_hash);
                self.monitor.unwatch(rank);
                if let Some(lease) = self.lease.as_mut() {
                    lease.evict(rank);
                }
                self.persist_leases();
            }
            WorkerEvent::CheckpointTaken { k0_s, .. } => {
                self.report.checkpoints_taken += 1;
                self.report.checkpoint_stall_s += k0_s;
            }
            // State-transfer completions are consumed by the restore
            // wait loop; seen here they are stragglers from an episode
            // the controller already gave up on.
            WorkerEvent::StateServed { .. } | WorkerEvent::StateRestored { .. } => {}
            WorkerEvent::RestoreFailed { rank, ref detail, .. } => {
                log::warn("controller", || {
                    format!("late restore failure from rank {rank}: {detail}")
                });
            }
        }
    }

    /// Wait until every rank in `ranks` has parked (or deadline).
    /// Ranks that *die* while we wait — a failure striking mid-recovery
    /// — are returned instead of waited on, so the caller can fold them
    /// into the episode rather than time out.
    fn await_parked(&mut self, ranks: &[usize], deadline: Duration) -> Result<Vec<usize>> {
        let t0 = Instant::now();
        let mut newly_dead: Vec<usize> = Vec::new();
        loop {
            let waiting: Vec<usize> = ranks
                .iter()
                .copied()
                .filter(|r| !self.parked.contains_key(r) && !newly_dead.contains(r))
                .collect();
            if waiting.is_empty() {
                return Ok(newly_dead);
            }
            for d in self.monitor.scan() {
                if waiting.contains(&d.rank) {
                    newly_dead.push(d.rank);
                }
            }
            if t0.elapsed() > deadline {
                bail!("ranks {waiting:?} never parked");
            }
            match self.event_rx.recv_timeout(Duration::from_millis(50)) {
                Ok(ev) => self.handle_event(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("workers gone"),
            }
        }
    }

    fn first_death_ms(&self, ranks: &[usize]) -> Option<u64> {
        ranks
            .iter()
            .filter_map(|r| {
                let w = self.workers.get(r)?;
                let t = w.board.death_at_ms.load(Ordering::SeqCst);
                (t > 0).then_some(t)
            })
            .min()
    }

    /// FlashRecovery (paper §III-D/E): selective recreation of failed
    /// ranks, replica-based state restore, resume at step i or i+1.
    fn flash_recover(&mut self, detections: &[super::detection::Detection]) -> Result<()> {
        let mut episode = trace::root("flash_recover", "controller");
        let t_aware = Instant::now();
        let mut dead: Vec<usize> = detections.iter().map(|d| d.rank).collect();
        log::info("controller", || {
            format!("flash recovery: detected ranks {dead:?} ({:?})", detections[0].kind)
        });
        // Detection latency: *measured* on the wire (last good
        // heartbeat -> detection) whenever the live plane is up; the
        // in-process boards' ground-truth death stamps only when it
        // is not (DESIGN.md §10).
        let measured = detections.iter().filter_map(|d| d.latency_s).reduce(f64::max);
        let detection_measured = measured.is_some();
        let detection_s = measured.unwrap_or_else(|| {
            self.first_death_ms(&dead)
                .map(|d_ms| (now_ms().saturating_sub(d_ms)) as f64 / 1e3)
                .unwrap_or(0.0)
        });

        // Episode checkpoint (DESIGN.md §13): replicate the in-flight
        // RecoveryRecord skeleton at each phase boundary so a standby
        // controller can adopt and resume a half-finished episode.
        self.persist_episode(&EpisodeCheckpoint {
            phase: EpisodePhase::Detection,
            epoch: self.rebuild_epoch + 1,
            dead: dead.clone(),
            resume_step: 0,
            detection_s,
            rebuild_s: 0.0,
        });

        // 1. stop/clean/reset: poison the collective so survivors park.
        self.collective.poison();

        let mut survivors: Vec<usize> = (0..self.cfg.dp)
            .filter(|r| !dead.contains(r) && !self.stopped.contains_key(r))
            .collect();
        if survivors.is_empty() {
            // whole DP group lost: checkpoint fallback (paper §III-G.1)
            return self.vanilla_recover(detections, dead);
        }
        // Ranks that die while the fleet parks (a failure during
        // recovery) are folded into this episode instead of timing the
        // recovery out.
        let newly_dead = self.await_parked(&survivors, Duration::from_secs(120))?;
        for r in newly_dead {
            survivors.retain(|s| *s != r);
            if !dead.contains(&r) {
                dead.push(r);
            }
        }
        if survivors.is_empty() {
            return self.vanilla_recover(detections, dead);
        }

        // 2. step determination + restore planning from the survivors'
        // states (§III-E-b): the planner maps every lost ZeRO shard to
        // a surviving replica source; a shard with no live replica
        // forces the checkpoint fallback (§III-G.1, `can_recover`).
        let steps: Vec<(usize, u64)> = survivors
            .iter()
            .map(|r| (*r, self.parked[r].0))
            .collect();
        let par = ParallelismConfig::dp(self.cfg.dp).with_zero(self.cfg.zero_shards);
        let plan = plan_shard_restore(&par, &steps, &dead);
        let resume_step = plan.resume_step;
        let failed_at_step = steps.iter().map(|&(_, s)| s).min().unwrap();
        if !plan.replica_feasible() {
            return self.vanilla_recover(detections, dead);
        }
        self.persist_episode(&EpisodeCheckpoint {
            phase: EpisodePhase::Rebuild,
            epoch: self.rebuild_epoch + 1,
            dead: dead.clone(),
            resume_step,
            detection_s,
            rebuild_s: 0.0,
        });

        // 3. limited recreation: spawn replacements for failed ranks
        // only. A replacement inherits its rank's next scripted failure
        // (if any) so flap campaigns can kill the same rank repeatedly.
        let mut replacement_entries: Vec<RankEntry> = Vec::with_capacity(dead.len());
        for &rank in &dead {
            self.consume_plan(rank);
            let state = WorkerState::init(&self.bundle, self.cfg.seed as i32)?;
            let next_plan = self.plan_for(rank);
            self.spawn_worker(rank, state, true, next_plan)?;
            // the replacement "node"'s new resource entry
            replacement_entries.push(RankEntry {
                rank,
                node: self.cfg.dp + self.report.recoveries.len() + rank,
                device: 0,
                addr: format!("127.0.0.1:{}", 31000 + rank),
            });
        }

        // 3b. group reconstruction over the live TCP plane: survivors
        // re-key into the new epoch with O(1) messages each, only the
        // replacements perform a full join (DESIGN.md §8). Each rank's
        // rendezvous agent runs the real client protocol against the
        // controller's store; the updated table every participant
        // converged on becomes the published ranktable.
        let mut span_rebuild = episode.child("rebuild", "controller");
        let t_rebuild = Instant::now();
        let mut rebuild_s = 0.0;
        if let Some(server) = &self.rebuild_plane {
            let outcome = rebuild_episode(
                &server.endpoints(),
                &self.ranktable,
                &par,
                &dead,
                &replacement_entries,
                self.rebuild_epoch,
                &EpisodeConfig {
                    live_survivors: survivors.len(),
                    ..Default::default()
                },
            )?;
            self.rebuild_epoch = outcome.epoch;
            self.ranktable = outcome.table;
            rebuild_s = t_rebuild.elapsed().as_secs_f64();
        } else {
            // no live plane: in-place substitution fallback
            for entry in replacement_entries {
                self.ranktable.substitute(entry)?;
            }
        }
        span_rebuild.set_detail(format!("epoch={}", self.rebuild_epoch));
        span_rebuild.end();
        self.persist_episode(&EpisodeCheckpoint {
            phase: EpisodePhase::Restore,
            epoch: self.rebuild_epoch,
            dead: dead.clone(),
            resume_step,
            detection_s,
            rebuild_s,
        });
        self.publish_ranktable()?;
        let dead_replacements = self.await_parked(&dead, Duration::from_secs(120))?;
        if !dead_replacements.is_empty() {
            bail!("replacement ranks {dead_replacements:?} died before restore");
        }

        // 4. replica restore: shard-aware streaming over real sockets
        // (DESIGN.md §9). Every lost shard fetches from a surviving
        // replica of the same shard; distinct transfers run in
        // parallel instead of serialising through one broadcast root.
        let mut span_restore = episode.child("restore", "controller");
        let t_restore = Instant::now();
        let restore_epoch = self.rebuild_epoch;
        let fence = EpochFence::new(restore_epoch);
        let mut pending: BTreeSet<usize> = BTreeSet::new();
        for tr in &plan.transfers {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?;
            self.send(
                tr.source,
                WorkerCommand::ServeState {
                    listener,
                    shard: tr.shard,
                    epoch: restore_epoch,
                    receivers: tr.targets.len(),
                    fence: fence.clone(),
                    trace: span_restore.ctx(),
                },
            )?;
            for &target in &tr.targets {
                self.send(
                    target,
                    WorkerCommand::RestoreState {
                        source_rank: tr.source,
                        source_addr: addr,
                        shard: tr.shard,
                        epoch: restore_epoch,
                        expect_step: resume_step,
                        fence: fence.clone(),
                    },
                )?;
                pending.insert(target);
            }
        }
        let mut shard_restores: Vec<ShardRestoreStat> = Vec::new();
        let restore_deadline = Instant::now() + Duration::from_secs(180);
        while !pending.is_empty() {
            if Instant::now() > restore_deadline {
                bail!("restore stalled: ranks {pending:?} never reported");
            }
            match self.event_rx.recv_timeout(Duration::from_millis(100)) {
                Ok(WorkerEvent::StateRestored { rank, shard, source, bytes, wall_s }) => {
                    pending.remove(&rank);
                    shard_restores.push(ShardRestoreStat {
                        shard,
                        source,
                        target: rank,
                        bytes,
                        wall_s,
                    });
                }
                Ok(WorkerEvent::StateServed { .. }) => {}
                Ok(WorkerEvent::RestoreFailed { rank, retryable, detail }) => {
                    bail!(
                        "restore of rank {rank} failed (retryable={retryable}): {detail}"
                    );
                }
                Ok(ev) => self.handle_event(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("workers gone during restore")
                }
            }
        }
        let restore_s = t_restore.elapsed().as_secs_f64();
        span_restore.set_detail(format!(
            "transfers={} resume_step={resume_step}",
            shard_restores.len()
        ));
        span_restore.end();

        // 5. rebuild the communication group and continue training.
        self.collective.reset(self.cfg.dp);
        self.parked.clear();
        for rank in 0..self.cfg.dp {
            self.send(rank, WorkerCommand::Continue { resume_step })?;
        }
        // The recovered fleet gets a fresh lease grace: beats pushed
        // while workers sat parked carried frozen (pre-restore) tags,
        // which must not feed the stall detector as if they were
        // training-time silence.
        if let Some(lease) = self.lease.as_mut() {
            let now = Instant::now();
            for rank in 0..self.cfg.dp {
                if let Some(inc) = self.monitor.incarnation_of(rank) {
                    lease.admit(rank, inc, now);
                }
            }
        }
        self.persist_leases();
        self.clear_episode();

        let restart_s = t_aware.elapsed().as_secs_f64();
        episode.set_detail(format!("ranks={dead:?} resume_step={resume_step}"));
        episode.end();
        let reg = global();
        reg.observe("controller.detection_s", detection_s);
        reg.observe("controller.rebuild_s", rebuild_s);
        reg.observe("controller.restore_s", restore_s);
        reg.observe("controller.restart_s", restart_s);
        reg.inc("controller.flash_recoveries");
        log::info("controller", || {
            format!(
                "flash recovery done: ranks {dead:?} resume_step={resume_step} \
                 restart_s={restart_s:.3}"
            )
        });
        self.report.recoveries.push(RecoveryRecord {
            mode: RecoveryMode::Flash,
            failed_ranks: dead,
            kind: detections[0].kind,
            via_device_plugin: detections[0].via_device_plugin,
            failed_at_step,
            resume_step,
            lost_steps: 0, // checkpoint-free: at most the in-flight step
            detection_s,
            detection_measured,
            restart_s,
            restore_s,
            rebuild_s,
            total_s: detection_s + restart_s,
            shard_restores,
        });
        Ok(())
    }

    /// Vanilla baseline: wait out the collective timeout, tear down the
    /// whole fleet, reload the last checkpoint, restart everyone.
    /// `dead` is the full set of lost ranks — it can exceed the ranks
    /// in `detections` when a flash recovery folded in ranks that died
    /// mid-park before falling back here (`detections` then only
    /// carries the original episode's failure metadata).
    fn vanilla_recover(
        &mut self,
        detections: &[super::detection::Detection],
        mut dead: Vec<usize>,
    ) -> Result<()> {
        let death_ms = self.first_death_ms(&dead);

        // Passive detection: survivors discover the failure only when
        // the collective times out (or are poisoned by the first
        // timeout). The controller waits for them.
        let mut survivors: Vec<usize> = (0..self.cfg.dp)
            .filter(|r| !dead.contains(r) && !self.stopped.contains_key(r))
            .collect();
        // Survivors that die while waiting out the timeout join the
        // dead set — the whole fleet is torn down either way.
        let newly_dead = self.await_parked(
            &survivors,
            self.cfg.collective_timeout + Duration::from_secs(120),
        )?;
        for r in newly_dead {
            survivors.retain(|s| *s != r);
            if !dead.contains(&r) {
                dead.push(r);
            }
        }
        let detection_s = death_ms
            .map(|d_ms| (now_ms().saturating_sub(d_ms)) as f64 / 1e3)
            .unwrap_or(0.0);
        let t_restart = Instant::now();
        // Last step in flight: survivors' parked state, or — when the
        // whole group died (checkpoint-fallback path) — the dead ranks'
        // final step tags.
        let failed_at_step = survivors
            .iter()
            .map(|r| self.parked[r].0)
            .chain(dead.iter().filter_map(|r| {
                let tag = self
                    .workers
                    .get(r)?
                    .board
                    .step_tag
                    .load(Ordering::SeqCst);
                (tag >= 0).then_some(tag as u64)
            }))
            .max()
            .unwrap_or(0);

        // Indiscriminate teardown: stop everything, join all threads.
        // Stop goes to every rank (not just survivors): a
        // stall-detected worker counts as dead but its thread may
        // still be parked, and it must drain the command before the
        // join below.
        let ranks: Vec<usize> = self.workers.keys().copied().collect();
        for r in ranks {
            let _ = self.send(r, WorkerCommand::Stop);
        }
        for (_, w) in self.workers.iter_mut() {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
            if let Some(h) = w.hb.take() {
                let _ = h.join();
            }
        }
        // drain Stopped events; these are not "job complete" stops
        while let Ok(ev) = self.event_rx.try_recv() {
            if let WorkerEvent::Stopped { rank, .. } = ev {
                self.monitor.unwatch(rank);
            } else {
                self.handle_event(ev);
            }
        }
        self.stopped.clear();
        self.parked.clear();
        self.workers.clear();

        // Training resumption from the last checkpoint.
        let t_restore = Instant::now();
        let loader = CheckpointManager::new(&self.cfg.ckpt_dir, 0, 2, false)?;
        let snapshot = loader.load_latest()?;
        let (resume_step, states) = match snapshot {
            Some(snap) => {
                let step = snap.step;
                let states: Vec<WorkerState> = (0..self.cfg.dp)
                    .map(|_| WorkerState::from_snapshot(&self.bundle, &snap))
                    .collect::<Result<_>>()?;
                (step, states)
            }
            None => {
                // no checkpoint ever taken: restart from scratch
                let states: Vec<WorkerState> = (0..self.cfg.dp)
                    .map(|_| WorkerState::init(&self.bundle, self.cfg.seed as i32))
                    .collect::<Result<_>>()?;
                (0, states)
            }
        };
        let restore_s = t_restore.elapsed().as_secs_f64();

        // Full-fleet restart with a fresh communication group. Dead
        // ranks' plans are spent (advance their cursors); everyone else
        // keeps their next plan if its step is still ahead of the
        // replayed range.
        for &rank in &dead {
            self.consume_plan(rank);
        }
        self.collective.reset(self.cfg.dp);
        for (rank, state) in states.into_iter().enumerate() {
            let failure = self.plan_for(rank).filter(|f| f.step >= resume_step);
            self.spawn_worker(rank, state, false, failure)?;
        }
        self.publish_ranktable()?;

        self.clear_episode();
        let restart_s = t_restart.elapsed().as_secs_f64();
        global().inc("controller.vanilla_recoveries");
        log::info("controller", || {
            format!(
                "vanilla recovery done: ranks {dead:?} resume_step={resume_step} \
                 restart_s={restart_s:.3}"
            )
        });
        self.report.recoveries.push(RecoveryRecord {
            mode: RecoveryMode::Vanilla,
            failed_ranks: dead,
            kind: detections[0].kind,
            via_device_plugin: detections[0].via_device_plugin,
            failed_at_step,
            resume_step,
            lost_steps: failed_at_step.saturating_sub(resume_step),
            detection_s,
            // vanilla's detection model is the passive collective
            // timeout; even on a fallback from flash it reports the
            // boards' ground truth, not a wire measurement
            detection_measured: false,
            restart_s,
            restore_s,
            rebuild_s: 0.0, // vanilla re-establishes everything from scratch
            total_s: detection_s + restart_s,
            shard_restores: Vec::new(),
        });
        Ok(())
    }

    fn send(&self, rank: usize, cmd: WorkerCommand) -> Result<()> {
        self.workers
            .get(&rank)
            .with_context(|| format!("no worker {rank}"))?
            .cmd_tx
            .send(cmd)
            .map_err(|_| anyhow::anyhow!("worker {rank} channel closed"))
    }

    fn stop_all(&mut self) {
        for (_, w) in self.workers.iter() {
            let _ = w.cmd_tx.send(WorkerCommand::Stop);
        }
        self.collective.poison();
        for (_, w) in self.workers.iter_mut() {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
            if let Some(h) = w.hb.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::restore::synthetic_snapshot;

    #[test]
    fn episode_checkpoint_roundtrip() {
        let ck = EpisodeCheckpoint {
            phase: EpisodePhase::Rebuild,
            epoch: 4,
            dead: vec![1, 3, 7],
            resume_step: 9,
            detection_s: 0.25,
            rebuild_s: 0.125,
        };
        assert_eq!(EpisodeCheckpoint::parse(&ck.encode()).unwrap(), ck);
        // empty dead set and zero timings survive too
        let empty = EpisodeCheckpoint {
            phase: EpisodePhase::Detection,
            epoch: 0,
            dead: vec![],
            resume_step: 0,
            detection_s: 0.0,
            rebuild_s: 0.0,
        };
        assert_eq!(EpisodeCheckpoint::parse(&empty.encode()).unwrap(), empty);
        assert!(EpisodeCheckpoint::parse(b"phase=warp;epoch=1").is_err());
        assert!(EpisodeCheckpoint::parse(b"epoch=1").is_err(), "phase required");
    }

    #[test]
    fn lease_table_roundtrip() {
        let leases = vec![(0usize, 1u64), (2, 1), (4, 3)];
        assert_eq!(parse_leases(&encode_leases(&leases)).unwrap(), leases);
        assert_eq!(parse_leases(&encode_leases(&[])).unwrap(), Vec::new());
        assert!(parse_leases(b"0:1,borked").is_err());
    }

    #[test]
    fn standby_adopts_replicated_state_after_primary_crash() {
        let mut set = ReplicaSet::start(1).unwrap();
        let mut s = set.session().unwrap();
        let ck = EpisodeCheckpoint {
            phase: EpisodePhase::Restore,
            epoch: 4,
            dead: vec![1, 3],
            resume_step: 9,
            detection_s: 0.25,
            rebuild_s: 0.125,
        };
        s.set(K_EPISODE, &ck.encode()).unwrap();
        s.set(K_LEASES, &encode_leases(&[(0, 1), (2, 1), (4, 2)])).unwrap();
        let eps = set.endpoints();
        set.kill_primary();

        let standby = StandbyController::adopt(&eps).unwrap();
        assert_eq!(standby.adopted.leases, vec![(0, 1), (2, 1), (4, 2)]);
        assert_eq!(standby.adopted.episode, Some(ck));
        // adopted workers get a fresh grace window: no instant expiry
        let mut monitor = standby.resume_lease_monitor(LeaseConfig {
            interval: Duration::from_millis(5),
            lease_misses: 3,
            stall_after: Duration::from_secs(10),
            stall_margin: 2,
        });
        assert!(monitor.scan(Instant::now()).is_empty());
    }

    #[test]
    fn promoted_standby_sees_recent_beats_not_just_leases() {
        let mut set = ReplicaSet::start(1).unwrap();
        let mut s = set.session().unwrap();
        s.set(K_LEASES, &encode_leases(&[(0, 1), (2, 1), (5, 4)])).unwrap();
        // two healthy beats, plus one carrying a device-plugin report
        // the about-to-die controller never got to act on
        s.heartbeat(0, 1, 7, -1).unwrap();
        s.heartbeat(2, 1, 7, -1).unwrap();
        s.heartbeat(5, 4, 6, 2).unwrap();
        let eps = set.endpoints();
        set.kill_primary();

        let standby = StandbyController::adopt(&eps).unwrap();
        let mut beats = standby.adopted.beats.clone();
        beats.sort_by_key(|b| b.rank);
        assert_eq!(beats.len(), 3, "the replicated beat table survives failover");
        assert_eq!(
            (beats[0].rank, beats[0].incarnation, beats[0].step_tag),
            (0, 1, 7)
        );
        assert_eq!(beats[2].device_code, 2);
        assert!(
            beats[0].at.elapsed() < Duration::from_secs(30),
            "adopted beats must carry recent timestamps"
        );

        // the resumed monitor acts on the adopted beats: the sticky
        // device report fires immediately, the healthy ranks do not
        let mut monitor = standby.resume_lease_monitor(LeaseConfig::default());
        let found = monitor.scan(Instant::now());
        assert_eq!(found.len(), 1, "only the device report fires: {found:?}");
        assert_eq!(found[0].rank, 5);
        assert_eq!(
            found[0].path,
            crate::coordinator::DetectionPath::DevicePlugin
        );
    }

    #[test]
    fn standby_resumes_half_finished_episode() {
        let mut set = ReplicaSet::start(1).unwrap();
        let mut s = set.session().unwrap();
        // the dying controller got as far as planning (phase=Rebuild)
        let ck = EpisodeCheckpoint {
            phase: EpisodePhase::Rebuild,
            epoch: 1,
            dead: vec![1],
            resume_step: 5,
            detection_s: 0.1,
            rebuild_s: 0.0,
        };
        s.set(K_EPISODE, &ck.encode()).unwrap();
        let eps = set.endpoints();
        set.kill_primary();

        let mut standby = StandbyController::adopt(&eps).unwrap();
        let par = ParallelismConfig::dp(4);
        let table = Ranktable::new(
            (0..4)
                .map(|rank| RankEntry {
                    rank,
                    node: rank,
                    device: 0,
                    addr: format!("10.0.0.{rank}:2900"),
                })
                .collect(),
        );
        let replacement = RankEntry {
            rank: 1,
            node: 100,
            device: 0,
            addr: "10.9.0.1:2900".into(),
        };
        let out = standby
            .resume_rebuild(
                &table,
                &par,
                std::slice::from_ref(&replacement),
                &EpisodeConfig {
                    live_survivors: 3,
                    join_deadline: Duration::from_secs(30),
                },
            )
            .unwrap();
        assert_eq!(out.epoch, 1, "resumes the adopted episode's target epoch");
        assert_eq!(out.table.entries[1], replacement);
        let rolled = standby.adopted.episode.clone().unwrap();
        assert_eq!(rolled.phase, EpisodePhase::Restore);
        assert_eq!(rolled.epoch, 1);

        // restore leg: bit-exact state lands on the lost rank
        let par2 = ParallelismConfig::dp(2);
        let plan = plan_shard_restore(&par2, &[(1, 5)], &[0]);
        let states: BTreeMap<usize, Snapshot> =
            [(1usize, synthetic_snapshot(5, 300))].into_iter().collect();
        let fence = EpochFence::new(rolled.epoch);
        let out2 = standby
            .resume_restore(&plan, &states, &fence, &StreamConfig::default())
            .unwrap();
        assert_eq!(
            out2.restored[&0].content_hash(),
            states[&1].content_hash(),
            "restore must be bit-exact after controller failover"
        );
        // episode checkpoint cleared on completion — visible to peers
        assert!(standby.adopted.episode.is_none());
        let mut reader = StoreSession::try_connect(&eps).unwrap();
        assert_eq!(reader.get(K_EPISODE).unwrap(), None);
    }
}
