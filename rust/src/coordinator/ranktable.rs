//! Global ranktable management (paper §III-D, Tab. I).
//!
//! The ranktable records every device's resource info (node, device
//! slot, endpoint) for inter-device communication establishment. Two
//! update protocols are implemented:
//!
//! * **Original** — every device sends its entry to the master, which
//!   assembles and re-distributes the table: O(n) in cluster size
//!   (implemented over the collective's all-gather, the measured
//!   baseline of Tab. I row 1);
//! * **Shared file** — the FlashRecovery controller maintains the
//!   up-to-date table in one shared file; every device loads it
//!   directly, O(1) (Tab. I row 2). The write is atomic
//!   (write-to-temp + rename) so readers never observe a torn table.

use crate::comms::{Collective, CollectiveError};
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankEntry {
    pub rank: usize,
    pub node: usize,
    pub device: usize,
    /// Endpoint string (host:port or device URI).
    pub addr: String,
}

impl RankEntry {
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("rank", self.rank)
            .set("node", self.node)
            .set("device", self.device)
            .set("addr", self.addr.as_str());
        o
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(RankEntry {
            rank: v.get("rank").as_usize().context("rank")?,
            node: v.get("node").as_usize().context("node")?,
            device: v.get("device").as_usize().context("device")?,
            addr: v.get("addr").as_str().context("addr")?.to_string(),
        })
    }

    /// Wire encoding for the all-gather baseline.
    pub fn encode(&self) -> Vec<u8> {
        self.to_json().render().into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let text = std::str::from_utf8(bytes)?;
        Self::from_json(&Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?)
    }

    fn encode_bin_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.rank as u32).to_le_bytes());
        out.extend_from_slice(&(self.node as u32).to_le_bytes());
        out.extend_from_slice(&(self.device as u32).to_le_bytes());
        let addr = self.addr.as_bytes();
        out.extend_from_slice(&(addr.len() as u32).to_le_bytes());
        out.extend_from_slice(addr);
    }

    fn decode_bin_from(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let u32_at = |buf: &[u8], pos: &mut usize| -> Result<u32> {
            if *pos + 4 > buf.len() {
                bail!("ranktable binary underrun");
            }
            let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        let rank = u32_at(buf, pos)? as usize;
        let node = u32_at(buf, pos)? as usize;
        let device = u32_at(buf, pos)? as usize;
        let len = u32_at(buf, pos)? as usize;
        if *pos + len > buf.len() {
            bail!("ranktable binary underrun");
        }
        let addr = String::from_utf8(buf[*pos..*pos + len].to_vec())?;
        *pos += len;
        Ok(RankEntry { rank, node, device, addr })
    }
}

#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Ranktable {
    pub version: u64,
    pub entries: Vec<RankEntry>,
}

impl Ranktable {
    pub fn new(entries: Vec<RankEntry>) -> Self {
        Ranktable { version: 1, entries }
    }

    /// Replace the entry for `rank` (node substitution after recovery)
    /// and bump the version.
    pub fn substitute(&mut self, entry: RankEntry) -> Result<()> {
        let slot = self
            .entries
            .iter_mut()
            .find(|e| e.rank == entry.rank)
            .with_context(|| format!("rank {} not in ranktable", entry.rank))?;
        *slot = entry;
        self.version += 1;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        let mut ranks: Vec<usize> = self.entries.iter().map(|e| e.rank).collect();
        ranks.sort();
        for (i, r) in ranks.iter().enumerate() {
            if *r != i {
                bail!("ranktable ranks not contiguous: expected {i}, got {r}");
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("version", self.version).set(
            "entries",
            Json::Array(self.entries.iter().map(|e| e.to_json()).collect()),
        );
        o
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Ranktable {
            version: v.get("version").as_i64().context("version")? as u64,
            entries: v
                .get("entries")
                .as_array()
                .context("entries")?
                .iter()
                .map(RankEntry::from_json)
                .collect::<Result<_>>()?,
        })
    }

    /// Compact binary encoding — the rendezvous protocol's full-table
    /// payload for replacement joins. ~10x smaller and faster than the
    /// JSON form, which matters at 8k+ ranks where JSON serialization
    /// alone would put O(n) milliseconds on the rebuild critical path.
    pub fn encode_bin(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.entries.len() * 32);
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            e.encode_bin_into(&mut out);
        }
        out
    }

    pub fn decode_bin(buf: &[u8]) -> Result<Self> {
        if buf.len() < 12 {
            bail!("ranktable binary underrun");
        }
        let version = u64::from_le_bytes(buf[0..8].try_into().unwrap());
        let count = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
        let mut pos = 12;
        // cap pre-allocation: a corrupt count must error, not OOM
        let mut entries = Vec::with_capacity(count.min(1 << 20));
        for _ in 0..count {
            entries.push(RankEntry::decode_bin_from(buf, &mut pos)?);
        }
        Ok(Ranktable { version, entries })
    }
}

/// FlashRecovery's controller-maintained shared-file ranktable.
pub struct SharedRanktable {
    path: PathBuf,
}

impl SharedRanktable {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        SharedRanktable { path: path.into() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Controller side: atomically publish the latest table.
    pub fn publish(&self, table: &Ranktable) -> Result<()> {
        table.validate()?;
        let tmp = self.path.with_extension("tmp");
        std::fs::write(&tmp, table.to_json().render_pretty())
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &self.path)?;
        Ok(())
    }

    /// Device side: O(1) load, no negotiation with the master.
    pub fn load(&self) -> Result<Ranktable> {
        let text = std::fs::read_to_string(&self.path)
            .with_context(|| format!("reading ranktable {:?}", self.path))?;
        let table = Ranktable::from_json(
            &Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?,
        )?;
        table.validate()?;
        Ok(table)
    }
}

/// The original O(n) protocol: every rank contributes its entry via
/// all-gather (collect at master + distribute, collapsed into one
/// collective op), and each rank assembles the table locally.
pub fn original_update(
    group: &Collective,
    entry: &RankEntry,
) -> std::result::Result<Ranktable, CollectiveError> {
    let gathered = group.all_gather(entry.rank, entry.encode())?;
    let entries: Vec<RankEntry> = gathered
        .iter()
        .map(|b| RankEntry::decode(b).expect("peer sent malformed entry"))
        .collect();
    Ok(Ranktable::new(entries))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::temp_dir;
    use std::sync::Arc;
    use std::time::Duration;

    fn entry(rank: usize) -> RankEntry {
        RankEntry {
            rank,
            node: rank / 8,
            device: rank % 8,
            addr: format!("10.0.{}.{}:2900", rank / 8, rank % 8),
        }
    }

    fn table(n: usize) -> Ranktable {
        Ranktable::new((0..n).map(entry).collect())
    }

    #[test]
    fn json_roundtrip() {
        let t = table(16);
        let back = Ranktable::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn binary_roundtrip() {
        let mut t = table(32);
        t.version = 9;
        let bin = t.encode_bin();
        assert_eq!(Ranktable::decode_bin(&bin).unwrap(), t);
        // compact: well under the JSON rendering
        assert!(bin.len() < t.to_json().render().len());
        // truncation is an error, not a panic
        assert!(Ranktable::decode_bin(&bin[..bin.len() - 3]).is_err());
        assert!(Ranktable::decode_bin(&[1, 2]).is_err());
    }

    #[test]
    fn shared_file_publish_load() {
        let dir = temp_dir("rt").unwrap();
        let shared = SharedRanktable::new(dir.join("ranktable.json"));
        let t = table(8);
        shared.publish(&t).unwrap();
        assert_eq!(shared.load().unwrap(), t);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn substitute_bumps_version_and_replaces() {
        let mut t = table(4);
        let mut new = entry(2);
        new.node = 99;
        t.substitute(new.clone()).unwrap();
        assert_eq!(t.version, 2);
        assert_eq!(t.entries[2], new);
        assert!(t.substitute(entry(17)).is_err());
    }

    #[test]
    fn validate_rejects_gaps() {
        let mut t = table(3);
        t.entries.remove(1);
        assert!(t.validate().is_err());
    }

    #[test]
    fn publish_rejects_invalid_table() {
        let dir = temp_dir("rt").unwrap();
        let shared = SharedRanktable::new(dir.join("ranktable.json"));
        let mut t = table(3);
        t.entries[0].rank = 7;
        assert!(shared.publish(&t).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn original_update_assembles_identical_tables() {
        let n = 4;
        let group = Collective::new(n, Duration::from_secs(5));
        let mut handles = Vec::new();
        for rank in 0..n {
            let group: Arc<Collective> = group.clone();
            handles.push(std::thread::spawn(move || {
                original_update(&group, &entry(rank)).unwrap()
            }));
        }
        let tables: Vec<Ranktable> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for t in &tables {
            assert_eq!(t, &tables[0]);
            t.validate().unwrap();
            assert_eq!(t.entries.len(), n);
        }
    }

    #[test]
    fn load_missing_file_errors() {
        let dir = temp_dir("rt").unwrap();
        let shared = SharedRanktable::new(dir.join("nope.json"));
        assert!(shared.load().is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
