//! Shard-aware restore planning and streaming restore episodes — the
//! paper's "checkpoint-free recovery within one step" (§III-E, Fig. 6)
//! as a real protocol over the live TCP plane (DESIGN.md §9).
//!
//! [`plan_shard_restore`] grows the step-tag `plan_restore` into a full
//! planner: it maps every lost ZeRO shard to a surviving replica source
//! (the Fig. 3 replica-location model from `config::parallelism`) and
//! schedules per-shard transfers that run in parallel — one socket per
//! (source, targets) pair instead of one whole-model broadcast from a
//! single root. A shard whose replicas all died is reported as
//! *unsourced*, which is exactly `can_recover == false`: the episode
//! must fall back to the checkpoint path (paper §III-G.1).
//!
//! [`restore_episode`] drives a plan end to end over real sockets:
//! sources advertise ephemeral endpoints through the epoch-fenced
//! store, targets claim and fetch, and a mid-restore epoch bump aborts
//! every in-flight transfer with a retryable outcome — never a hang.

use crate::checkpoint::Snapshot;
use crate::comms::state_stream::{
    fetch_from_addr, serve_listener, transfer_tag, EpochFence, Expect, RestoreError,
    StreamConfig,
};
use crate::comms::replication::{StoreEndpoints, StoreSession};
use crate::comms::tcp_store::{FencedWait, TcpStoreServer};
use crate::config::{ParallelismConfig, ShardId};
use crate::metrics::bench::BenchReport;
use crate::metrics::Histogram;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener};
use std::time::Instant;

/// One scheduled transfer: `source` serves its state to `targets`,
/// which all hold (or must come to hold) the same model-state shard.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardTransfer {
    pub shard: ShardId,
    pub source: usize,
    pub targets: Vec<usize>,
}

/// One shard scheduled to be rebuilt from erasure-coded stripes rather
/// than copied from a live replica — the redundancy tier's fallback
/// when an entire ZeRO replica group died (DESIGN.md §16). Any `k` of
/// the listed `k + m` stripe sources suffice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardReconstruction {
    pub shard: ShardId,
    /// Step the stripes encode — always the plan's resume step.
    pub step: u64,
    /// Data/parity split the stripes were cut with.
    pub k: usize,
    pub m: usize,
    /// Surviving stripe sources: (stripe index, depot address).
    pub stripes: Vec<(usize, SocketAddr)>,
    /// Ranks that must come to hold the rebuilt shard.
    pub targets: Vec<usize>,
}

/// The full restore schedule for one recovery episode.
#[derive(Debug, Clone)]
pub struct RestorePlan {
    /// Step every rank resumes from (max over the survivors' states —
    /// dead ranks' progress is unrecoverable and ignored).
    pub resume_step: u64,
    /// Per-shard transfers; distinct transfers run in parallel.
    pub transfers: Vec<ShardTransfer>,
    /// Shards with restore targets but no surviving replica at the
    /// resume step — replica restore is impossible for them
    /// (`can_recover` false). [`RestorePlan::cover_unsourced`] moves
    /// shards the redundancy tier can rebuild into `reconstructions`;
    /// whatever stays here needs the checkpoint fallback.
    pub unsourced: Vec<ShardId>,
    /// Restore targets (dead + lagging members) of each unsourced
    /// shard, kept so redundancy coverage knows who must receive the
    /// rebuilt state.
    pub unsourced_targets: BTreeMap<ShardId, Vec<usize>>,
    /// Stripe reconstructions scheduled for shards with no live
    /// replica. Empty straight out of the planner; filled by
    /// [`RestorePlan::cover_unsourced`].
    pub reconstructions: Vec<ShardReconstruction>,
}

impl RestorePlan {
    /// True iff every lost or lagging shard has a live replica source.
    pub fn replica_feasible(&self) -> bool {
        self.unsourced.is_empty() && self.reconstructions.is_empty()
    }

    /// True iff every lost shard is recoverable without touching a
    /// checkpoint file — from a live replica or by stripe
    /// reconstruction.
    pub fn checkpoint_free(&self) -> bool {
        self.unsourced.is_empty()
    }

    /// Every rank scheduled to receive state.
    pub fn targets(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .transfers
            .iter()
            .flat_map(|t| t.targets.iter().copied())
            .chain(
                self.reconstructions
                    .iter()
                    .flat_map(|r| r.targets.iter().copied()),
            )
            .collect();
        out.sort_unstable();
        out
    }

    /// Redundancy-tier fallback: offer every unsourced shard to
    /// `cover`, which returns a stripe reconstruction when at least
    /// `k` of its `k + m` stripes survive at the resume step (and
    /// `None` when the shard is truly lost). Covered shards move from
    /// `unsourced` into `reconstructions`; whatever remains in
    /// `unsourced` afterwards genuinely requires the checkpoint path.
    pub fn cover_unsourced<F>(&mut self, mut cover: F)
    where
        F: FnMut(ShardId, u64, &[usize]) -> Option<ShardReconstruction>,
    {
        let mut still = Vec::new();
        for shard in std::mem::take(&mut self.unsourced) {
            let targets =
                self.unsourced_targets.get(&shard).cloned().unwrap_or_default();
            match cover(shard, self.resume_step, &targets) {
                Some(rc) => {
                    self.unsourced_targets.remove(&shard);
                    self.reconstructions.push(rc);
                }
                None => still.push(shard),
            }
        }
        self.unsourced = still;
    }
}

/// Map every lost shard to a surviving replica source and schedule the
/// transfers.
///
/// * `survivor_steps` — each surviving rank's *state* step (completed
///   optimizer updates). Ranks absent from both lists (already stopped)
///   are outside the episode.
/// * `lost` — dead ranks awaiting replacements.
///
/// Semantics:
/// * the resume step is the max over survivors only — a failure that
///   raced the barrier can leave the dead rank's step tag *ahead* of
///   every survivor, in which case the sole surviving replica (a
///   "laggard" relative to the dead rank) is still the only valid
///   source and its step wins;
/// * surviving ranks behind the resume step are restore targets too
///   (laggards), alongside the replacements;
/// * within a shard, targets are spread round-robin across all sources
///   at the resume step, so a wide DP group restores in parallel
///   instead of serialising through one root.
pub fn plan_shard_restore(
    par: &ParallelismConfig,
    survivor_steps: &[(usize, u64)],
    lost: &[usize],
) -> RestorePlan {
    assert!(!survivor_steps.is_empty(), "no survivors to plan from");
    let resume_step = survivor_steps.iter().map(|&(_, s)| s).max().unwrap();
    let step_of: BTreeMap<usize, u64> = survivor_steps.iter().copied().collect();

    let mut by_shard: BTreeMap<ShardId, Vec<usize>> = BTreeMap::new();
    for g in 0..par.world_size() {
        by_shard.entry(par.shard_id(g)).or_default().push(g);
    }

    let mut transfers = Vec::new();
    let mut unsourced = Vec::new();
    let mut unsourced_targets = BTreeMap::new();
    for (shard, members) in by_shard {
        let mut sources = Vec::new();
        let mut targets = Vec::new();
        for m in members {
            if lost.contains(&m) {
                targets.push(m);
            } else if let Some(&s) = step_of.get(&m) {
                if s == resume_step {
                    sources.push(m);
                } else {
                    targets.push(m); // laggard
                }
            }
        }
        if targets.is_empty() {
            continue;
        }
        if sources.is_empty() {
            unsourced.push(shard);
            unsourced_targets.insert(shard, targets);
            continue;
        }
        let mut per_source: Vec<Vec<usize>> = vec![Vec::new(); sources.len()];
        for (i, t) in targets.into_iter().enumerate() {
            per_source[i % sources.len()].push(t);
        }
        for (source, tg) in sources.into_iter().zip(per_source) {
            if !tg.is_empty() {
                transfers.push(ShardTransfer { shard, source, targets: tg });
            }
        }
    }
    RestorePlan {
        resume_step,
        transfers,
        unsourced,
        unsourced_targets,
        reconstructions: Vec::new(),
    }
}

/// One completed transfer's accounting.
#[derive(Debug, Clone, Copy)]
pub struct TransferStat {
    pub shard: ShardId,
    pub source: usize,
    pub target: usize,
    pub bytes: u64,
    pub chunks: u32,
    pub wall_s: f64,
}

/// Outcome of one restore episode.
#[derive(Debug)]
pub struct RestoreOutcome {
    pub epoch: u64,
    pub resume_step: u64,
    /// Whole-episode wall clock (all transfers, run in parallel).
    pub wall_s: f64,
    pub transfers: Vec<TransferStat>,
    /// rank -> restored state, for every target in the plan.
    pub restored: BTreeMap<usize, Snapshot>,
}

impl RestoreOutcome {
    pub fn bytes_moved(&self) -> u64 {
        self.transfers.iter().map(|t| t.bytes).sum()
    }
}

/// Advance the rendezvous epoch on both planes at once: the store
/// (releases blocked `ClaimRestore` waiters) and the in-memory fence
/// (aborts in-flight chunk transfers). This is what folding a
/// failure-during-recovery into the episode looks like on the wire.
pub fn bump_epoch(store: &StoreEndpoints, fence: &EpochFence, to: u64) -> Result<u64> {
    let mut client = StoreSession::try_connect(store)?;
    let now = client.advance_epoch(to)?;
    fence.advance(to);
    Ok(now)
}

fn fatal(e: anyhow::Error) -> RestoreError {
    RestoreError::Fatal(e)
}

/// Drive one restore episode over real sockets: every transfer's
/// source binds an ephemeral listener and advertises it through the
/// epoch-fenced store; every target claims its source, connects, and
/// fetches the shard. All transfers run concurrently. Returns
/// [`RestoreError::Superseded`] (retryable — replan at the new epoch)
/// the moment any side observes an epoch bump.
///
/// Abort contract: the caller folds a failure-during-recovery in by
/// calling [`bump_epoch`] with the same fence, which releases both
/// blocked claims (store side) and in-flight chunk streams (fence
/// side) promptly.
pub fn restore_episode(
    store: &StoreEndpoints,
    plan: &RestorePlan,
    states: &BTreeMap<usize, Snapshot>,
    epoch: u64,
    fence: &EpochFence,
    cfg: &StreamConfig,
) -> Result<RestoreOutcome, RestoreError> {
    if !plan.checkpoint_free() {
        return Err(fatal(anyhow!(
            "plan has unsourced shards {:?} — checkpoint fallback required",
            plan.unsourced
        )));
    }
    for tr in &plan.transfers {
        let src = states
            .get(&tr.source)
            .ok_or_else(|| fatal(anyhow!("no state for source rank {}", tr.source)))?;
        if src.step != plan.resume_step {
            return Err(fatal(anyhow!(
                "source rank {} is at step {}, plan resumes at {}",
                tr.source,
                src.step,
                plan.resume_step
            )));
        }
    }

    let t0 = Instant::now();
    // Bind every transfer's listener up front so targets can be told
    // their source address before any thread starts.
    let mut endpoints = Vec::with_capacity(plan.transfers.len());
    for tr in &plan.transfers {
        let listener = TcpListener::bind("127.0.0.1:0").map_err(|e| fatal(e.into()))?;
        let addr = listener.local_addr().map_err(|e| fatal(e.into()))?;
        endpoints.push((listener, addr, tr));
    }

    // All agents run as scoped threads borrowing the source snapshots
    // in place — no per-transfer deep copy of model state. Every
    // thread is joined before any error surfaces, so an abort never
    // leaves dangling agents behind.
    let mut superseded: Option<u64> = None;
    let mut first_fatal: Option<anyhow::Error> = None;
    let mut transfers = Vec::new();
    let mut restored = BTreeMap::new();
    std::thread::scope(|scope| {
        let mut source_threads = Vec::with_capacity(endpoints.len());
        let mut target_threads = Vec::new();
        for (listener, addr, tr) in &endpoints {
            let snap = &states[&tr.source];
            let tag = transfer_tag(tr.shard, tr.source);
            let (shard, receivers) = (tr.shard, tr.targets.len());
            source_threads.push(scope.spawn(move || -> Result<(), RestoreError> {
                let mut client = StoreSession::try_connect(store).map_err(fatal)?;
                match client.advertise_restore(epoch, tag, &addr.to_string()) {
                    Ok(None) => {}
                    Ok(Some(current)) => {
                        return Err(RestoreError::Superseded { current })
                    }
                    Err(e) => return Err(fatal(e)),
                }
                serve_listener(listener, snap, shard, epoch, receivers, fence, cfg)
                    .map(|_| ())
            }));

            for &target in &tr.targets {
                let (shard, source) = (tr.shard, tr.source);
                let resume = plan.resume_step;
                target_threads.push(scope.spawn(
                    move || -> Result<(TransferStat, Snapshot), RestoreError> {
                        let mut client =
                            StoreSession::try_connect(store).map_err(fatal)?;
                        let addr_bytes = match client
                            .claim_restore(epoch, transfer_tag(shard, source))
                            .map_err(fatal)?
                        {
                            FencedWait::Value(v) => v,
                            FencedWait::Superseded { current } => {
                                return Err(RestoreError::Superseded { current })
                            }
                        };
                        let addr: SocketAddr = std::str::from_utf8(&addr_bytes)
                            .map_err(|e| fatal(e.into()))?
                            .parse()
                            .map_err(|e: std::net::AddrParseError| fatal(e.into()))?;
                        let expect = Expect { epoch, shard, step: Some(resume) };
                        let (snap, stats) = fetch_from_addr(addr, &expect, fence)?;
                        Ok((
                            TransferStat {
                                shard,
                                source,
                                target,
                                bytes: stats.bytes,
                                chunks: stats.chunks,
                                wall_s: stats.wall_s,
                            },
                            snap,
                        ))
                    },
                ));
            }
        }

        for h in target_threads {
            match h.join() {
                Ok(Ok((stat, snap))) => {
                    restored.insert(stat.target, snap);
                    transfers.push(stat);
                }
                Ok(Err(RestoreError::Superseded { current })) => {
                    superseded = Some(superseded.unwrap_or(0).max(current));
                }
                Ok(Err(RestoreError::Fatal(e))) => {
                    first_fatal.get_or_insert(e);
                }
                Err(_) => {
                    first_fatal
                        .get_or_insert(anyhow!("restore target thread panicked"));
                }
            }
        }
        for h in source_threads {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(RestoreError::Superseded { current })) => {
                    superseded = Some(superseded.unwrap_or(0).max(current));
                }
                Ok(Err(RestoreError::Fatal(e))) => {
                    first_fatal.get_or_insert(e);
                }
                Err(_) => {
                    first_fatal
                        .get_or_insert(anyhow!("restore source thread panicked"));
                }
            }
        }
    });
    if let Some(current) = superseded {
        return Err(RestoreError::Superseded { current });
    }
    if let Some(e) = first_fatal {
        return Err(RestoreError::Fatal(e));
    }
    Ok(RestoreOutcome {
        epoch,
        resume_step: plan.resume_step,
        wall_s: t0.elapsed().as_secs_f64(),
        transfers,
        restored,
    })
}

/// Deterministic synthetic model state for socket-level restore tests,
/// chaos campaigns, and the bench sweep (three tensors, mimicking
/// params ++ m ++ v). Identical `(step, elems)` means identical bits —
/// the DP-replica invariant.
pub fn synthetic_snapshot(step: u64, elems: usize) -> Snapshot {
    let base = elems / 3;
    let mut tensors = Vec::with_capacity(3);
    for t in 0..3usize {
        let n = if t == 0 { elems - 2 * base } else { base };
        let v: Vec<f32> = (0..n)
            .map(|i| {
                let x = step
                    .wrapping_mul(0x9E37_79B9)
                    .wrapping_add((t * 1_000_003 + i) as u64 * 2_654_435_761)
                    % 100_000;
                x as f32 * 1e-5
            })
            .collect();
        tensors.push(v);
    }
    Snapshot { step, tensors }
}

// ---------------------------------------------------------------- sweep

/// Configuration for the `state_restore` bench and the
/// `flashrecovery bench restore` CLI.
#[derive(Debug, Clone)]
pub struct RestoreSweepConfig {
    /// Model sizes as f32 elements per rank snapshot.
    pub sizes: Vec<usize>,
    /// ZeRO shard counts; each shard group loses one rank per episode.
    pub shards: Vec<usize>,
    /// Measured episodes per cell (one extra warmup is discarded).
    pub samples: u32,
    pub chunk_bytes: usize,
}

impl Default for RestoreSweepConfig {
    fn default() -> Self {
        RestoreSweepConfig {
            sizes: vec![262_144, 1_048_576],
            shards: vec![2, 4],
            samples: 5,
            chunk_bytes: crate::comms::state_stream::DEFAULT_CHUNK_BYTES,
        }
    }
}

/// Run one (size, shards) cell: kill one rank per ZeRO shard group and
/// restore every lost shard from a distinct surviving replica, in
/// parallel. Returns the per-episode wall-clock histogram + MB moved.
fn run_parallel_cell(
    cfg: &RestoreSweepConfig,
    elems: usize,
    shards: usize,
    step: u64,
) -> Result<(Histogram, f64, usize)> {
    let par = ParallelismConfig::dp(2 * shards).with_zero(shards);
    par.validate()?;
    let lost: Vec<usize> = (0..shards).collect();
    let survivor_steps: Vec<(usize, u64)> =
        (shards..2 * shards).map(|r| (r, step)).collect();
    let plan = plan_shard_restore(&par, &survivor_steps, &lost);
    let states: BTreeMap<usize, Snapshot> = (shards..2 * shards)
        .map(|r| (r, synthetic_snapshot(step, elems)))
        .collect();
    run_cell(cfg, &plan, &states, false)
}

/// The single-source baseline: the same number of targets restored
/// from *one* surviving replica (the pre-refactor whole-model
/// broadcast shape), serialised through a single socket endpoint
/// (`serial_serve` models the lone source's single uplink).
fn run_single_source_cell(
    cfg: &RestoreSweepConfig,
    elems: usize,
    targets: usize,
    step: u64,
) -> Result<(Histogram, f64, usize)> {
    let par = ParallelismConfig::dp(targets + 1);
    let lost: Vec<usize> = (0..targets).collect();
    let survivor_steps = vec![(targets, step)];
    let plan = plan_shard_restore(&par, &survivor_steps, &lost);
    let states: BTreeMap<usize, Snapshot> =
        [(targets, synthetic_snapshot(step, elems))].into_iter().collect();
    run_cell(cfg, &plan, &states, true)
}

fn run_cell(
    cfg: &RestoreSweepConfig,
    plan: &RestorePlan,
    states: &BTreeMap<usize, Snapshot>,
    serial_serve: bool,
) -> Result<(Histogram, f64, usize)> {
    let server = TcpStoreServer::start()?;
    let stream_cfg = StreamConfig {
        chunk_bytes: cfg.chunk_bytes,
        serial_serve,
        ..Default::default()
    };
    let mut h = Histogram::new();
    let mut mb = 0.0;
    let mut transfers = 0;
    for i in 0..=cfg.samples {
        let epoch = (i + 1) as u64;
        let fence = EpochFence::new(epoch);
        let out =
            restore_episode(&server.endpoints(), plan, states, epoch, &fence, &stream_cfg)
                .map_err(|e| anyhow!("{e}"))?;
        if i > 0 {
            // episode 0 is warmup (server threads, allocator)
            h.record(out.wall_s);
            mb = out.bytes_moved() as f64 / 1e6;
            transfers = out.transfers.len();
        }
    }
    Ok((h, mb, transfers))
}

/// Run the restore scale sweep and report per-cell wall-clock
/// quantiles, bytes moved, and the single-source baseline. Column 0
/// (`p50 ms`) is what CI's bench gate compares against the committed
/// baseline; the last column is the serialized baseline the parallel
/// path must beat.
pub fn restore_sweep(cfg: &RestoreSweepConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new(
        "state_restore: shard-aware streaming restore, size x shard sweep",
        &["p50 ms", "mean ms", "max ms", "MB moved", "transfers", "1src p50 ms"],
    );
    for &elems in &cfg.sizes {
        for &shards in &cfg.shards {
            if shards < 2 {
                anyhow::bail!("sweep needs >= 2 shard groups (got {shards})");
            }
            let (h, mb, transfers) = run_parallel_cell(cfg, elems, shards, 7)?;
            let (single, _, _) = run_single_source_cell(cfg, elems, shards, 7)?;
            report.row(
                format!("elems={elems} shards={shards}"),
                vec![
                    h.p50() * 1e3,
                    h.mean() * 1e3,
                    h.max() * 1e3,
                    mb,
                    transfers as f64,
                    single.p50() * 1e3,
                ],
            );
        }
    }
    report.note(format!(
        "{} samples/cell (+1 warmup), one lost rank per ZeRO shard group, \
         chunk {} KiB; '1src' is the same target count restored through one \
         source (the pre-refactor broadcast shape)",
        cfg.samples,
        cfg.chunk_bytes / 1024
    ));
    report.note(
        "parallel per-shard restore must beat the single-source baseline at \
         the largest cell (asserted by benches/state_restore.rs)",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dp(n: usize) -> ParallelismConfig {
        ParallelismConfig::dp(n)
    }

    #[test]
    fn all_survivors_ahead_of_the_minimum_step() {
        // Failure after the barrier: every survivor finished the update
        // (i+1) while the dead rank stopped at i — resume at i+1, no
        // laggards, replacements are the only targets.
        let plan = plan_shard_restore(&dp(4), &[(1, 7), (2, 7), (3, 7)], &[0]);
        assert_eq!(plan.resume_step, 7);
        assert!(plan.replica_feasible());
        assert_eq!(plan.targets(), vec![0]);
        assert_eq!(plan.transfers.len(), 1);
        assert!([1, 2, 3].contains(&plan.transfers[0].source));
    }

    #[test]
    fn single_laggard_is_the_only_source_candidate() {
        // The dead rank raced ahead of the barrier before dying; the
        // sole survivor is "behind" the dead rank's progress but is
        // still the only valid source — its step defines the resume.
        let plan = plan_shard_restore(&dp(2), &[(1, 5)], &[0]);
        assert_eq!(plan.resume_step, 5);
        assert_eq!(plan.transfers.len(), 1);
        assert_eq!(plan.transfers[0].source, 1);
        assert_eq!(plan.transfers[0].targets, vec![0]);
        assert!(plan.replica_feasible());
    }

    #[test]
    fn mixed_laggards_and_replacements_in_one_episode() {
        // rank 0 dead, rank 2 parked behind the resume step: both are
        // targets, spread across the two up-to-date sources.
        let plan = plan_shard_restore(&dp(4), &[(1, 7), (2, 6), (3, 7)], &[0]);
        assert_eq!(plan.resume_step, 7);
        let mut targets = plan.targets();
        targets.sort_unstable();
        assert_eq!(targets, vec![0, 2]);
        let sources: Vec<usize> = plan.transfers.iter().map(|t| t.source).collect();
        assert_eq!(plan.transfers.len(), 2, "parallel transfers: {plan:?}");
        assert!(sources.contains(&1) && sources.contains(&3));
    }

    #[test]
    fn each_lost_zero_shard_maps_to_a_distinct_replica() {
        // dp=4, zero=2: shard groups {0,2} and {1,3}. Killing one rank
        // per group restores each shard from the surviving member of
        // the *same* group — two distinct sources, two transfers.
        let par = dp(4).with_zero(2);
        let plan = plan_shard_restore(&par, &[(2, 9), (3, 9)], &[0, 1]);
        assert_eq!(plan.transfers.len(), 2);
        let mut pairs: Vec<(usize, usize)> = plan
            .transfers
            .iter()
            .map(|t| (t.source, t.targets[0]))
            .collect();
        pairs.sort_unstable();
        assert_eq!(pairs, vec![(2, 0), (3, 1)]);
        for t in &plan.transfers {
            assert_eq!(par.shard_id(t.source), t.shard);
            assert_eq!(par.shard_id(t.targets[0]), t.shard);
        }
    }

    #[test]
    fn shard_without_surviving_replica_is_unsourced() {
        // Pure FSDP (zero == dp): no replicas exist, so a single loss
        // is unsourced — exactly can_recover() == false.
        let par = dp(4).with_zero(4);
        assert!(!par.can_recover(&[2]));
        let plan = plan_shard_restore(&par, &[(0, 3), (1, 3), (3, 3)], &[2]);
        assert!(!plan.replica_feasible());
        assert_eq!(plan.unsourced.len(), 1);
        assert_eq!(plan.unsourced[0], par.shard_id(2));
        assert!(plan.transfers.is_empty());
    }

    #[test]
    fn whole_group_loss_with_laggard_only_shard_is_unsourced() {
        // zero=2, dp=4: shard {1,3} loses rank 1 while rank 3 parked a
        // step behind the global resume — no source at resume for that
        // shard, so the plan demands checkpoint fallback.
        let par = dp(4).with_zero(2);
        let plan = plan_shard_restore(&par, &[(2, 7), (3, 6)], &[1]);
        assert_eq!(plan.resume_step, 7);
        assert!(!plan.replica_feasible());
        assert_eq!(plan.unsourced, vec![par.shard_id(1)]);
    }

    #[test]
    fn plan_skips_ranks_outside_the_episode() {
        // rank 3 neither survived nor died (already stopped): it is
        // not a target and not a source.
        let plan = plan_shard_restore(&dp(4), &[(1, 4), (2, 4)], &[0]);
        assert_eq!(plan.targets(), vec![0]);
        for t in &plan.transfers {
            assert_ne!(t.source, 3);
            assert!(!t.targets.contains(&3));
        }
    }

    #[test]
    fn episode_restores_over_real_sockets() {
        let par = dp(4).with_zero(2);
        let plan = plan_shard_restore(&par, &[(2, 9), (3, 9)], &[0, 1]);
        let states: BTreeMap<usize, Snapshot> = [2usize, 3]
            .into_iter()
            .map(|r| (r, synthetic_snapshot(9, 3000)))
            .collect();
        let server = TcpStoreServer::start().unwrap();
        let fence = EpochFence::new(1);
        let out = restore_episode(
            &server.endpoints(),
            &plan,
            &states,
            1,
            &fence,
            &StreamConfig::default(),
        )
        .unwrap();
        assert_eq!(out.resume_step, 9);
        assert_eq!(out.transfers.len(), 2);
        assert_eq!(out.restored.len(), 2);
        for (rank, snap) in &out.restored {
            assert_eq!(snap.step, 9, "rank {rank}");
            assert_eq!(snap.content_hash(), states[&2].content_hash());
        }
        // each lost shard came from a distinct surviving replica
        let mut sources: Vec<usize> = out.transfers.iter().map(|t| t.source).collect();
        sources.sort_unstable();
        assert_eq!(sources, vec![2, 3]);
    }

    #[test]
    fn unsourced_plan_is_rejected_by_the_episode_driver() {
        let par = dp(2).with_zero(2);
        let plan = plan_shard_restore(&par, &[(1, 3)], &[0]);
        assert!(!plan.replica_feasible());
        let server = TcpStoreServer::start().unwrap();
        let fence = EpochFence::new(1);
        let err = restore_episode(
            &server.endpoints(),
            &plan,
            &BTreeMap::new(),
            1,
            &fence,
            &StreamConfig::default(),
        )
        .unwrap_err();
        assert!(!err.retryable());
        assert!(err.to_string().contains("checkpoint fallback"), "{err}");
    }

    #[test]
    fn cover_unsourced_moves_shards_into_reconstructions() {
        let par = dp(4).with_zero(2);
        // whole replica group {1, 3} dead: shard zero=1 is unsourced
        let mut plan = plan_shard_restore(&par, &[(0, 9), (2, 9)], &[1, 3]);
        assert_eq!(plan.unsourced, vec![par.shard_id(1)]);
        assert_eq!(plan.unsourced_targets[&par.shard_id(1)], vec![1, 3]);
        let depot: SocketAddr = "127.0.0.1:9000".parse().unwrap();
        plan.cover_unsourced(|shard, step, targets| {
            assert_eq!(step, 9);
            Some(ShardReconstruction {
                shard,
                step,
                k: 2,
                m: 1,
                stripes: vec![(0, depot), (2, depot)],
                targets: targets.to_vec(),
            })
        });
        assert!(plan.checkpoint_free());
        assert!(
            !plan.replica_feasible(),
            "stripe rebuild is not a replica restore"
        );
        assert_eq!(plan.reconstructions.len(), 1);
        assert_eq!(plan.reconstructions[0].targets, vec![1, 3]);
        assert_eq!(plan.targets(), vec![1, 3]);
        assert!(plan.unsourced.is_empty());
        assert!(plan.unsourced_targets.is_empty());
    }

    #[test]
    fn cover_that_declines_leaves_shards_unsourced() {
        let par = dp(2).with_zero(2);
        let mut plan = plan_shard_restore(&par, &[(1, 3)], &[0]);
        plan.cover_unsourced(|_, _, _| None);
        assert!(!plan.checkpoint_free());
        assert_eq!(plan.unsourced, vec![par.shard_id(0)]);
        assert_eq!(plan.unsourced_targets[&par.shard_id(0)], vec![0]);
    }

    #[test]
    fn synthetic_snapshots_are_deterministic_replicas() {
        let a = synthetic_snapshot(5, 1000);
        let b = synthetic_snapshot(5, 1000);
        assert_eq!(a.content_hash(), b.content_hash());
        assert_ne!(
            a.content_hash(),
            synthetic_snapshot(6, 1000).content_hash()
        );
        assert_eq!(a.tensors.iter().map(Vec::len).sum::<usize>(), 1000);
    }
}
