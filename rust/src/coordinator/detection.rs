//! Active real-time failure detection (paper §III-C; DESIGN.md §10).
//!
//! Two monitors feed the controller:
//!
//! * [`HeartbeatMonitor`] — the in-process fallback: per-worker
//!   liveness (`alive`) and device-plugin (`device_error`) flags on the
//!   [`MonitorBoard`], scanned every heartbeat interval. Used when the
//!   live TCP plane is down.
//! * [`LeaseMonitor`] — detection as a *wire protocol*: every worker
//!   pushes `Heartbeat {rank, incarnation, step_tag, device_code}` to
//!   the controller's `TcpStoreServer` on a fixed interval, and the
//!   monitor derives three failure classes from the beat records:
//!   1. **device plugin** — a pushed `device_code` reports a hardware
//!      failure with its [`FailureKind`] before liveness is even lost;
//!   2. **lease expiry** — no beat within `lease_misses x interval`:
//!      process/node loss;
//!   3. **step-tag stall** — a rank whose step tag is frozen
//!      `stall_after` *and* behind the DP-group median by
//!      `stall_margin`: a silent hang / hard straggler. This is the
//!      failure class a liveness flag cannot see at all — a worker
//!      stuck in a collective keeps `alive == true` forever.
//!
//! Every wire detection carries a **measured** latency (last good
//! heartbeat → detection, on the controller's clock), which is what
//! `RecoveryRecord.detection_s` reports when the live plane is up —
//! replacing the passive baseline where peers discover a failure only
//! when a collective hangs into its (default 1800 s) timeout.

use crate::cluster::failure::FailureKind;
use crate::comms::tcp_store::{BeatRecord, TcpStoreServer};
use crate::metrics::bench::BenchReport;
use crate::metrics::Histogram;
use crate::training::worker::{
    kind_from_code, spawn_heartbeat, spawn_node_heartbeat, HeartbeatCfg,
    MonitorBoard, NodeAgentCfg, NodeRank,
};
use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which detection path noticed a failure first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DetectionPath {
    /// Hardware error code reported by the device plugin.
    DevicePlugin,
    /// In-process liveness flag observed false (board scan fallback).
    Liveness,
    /// Heartbeat lease expired on the wire: process/node loss.
    LeaseExpiry,
    /// Step tag frozen behind the DP-group median: silent hang.
    StepStall,
}

impl DetectionPath {
    pub fn name(&self) -> &'static str {
        match self {
            DetectionPath::DevicePlugin => "device_plugin",
            DetectionPath::Liveness => "liveness",
            DetectionPath::LeaseExpiry => "lease_expiry",
            DetectionPath::StepStall => "step_stall",
        }
    }
}

/// One detected failure.
#[derive(Debug, Clone)]
pub struct Detection {
    pub rank: usize,
    pub kind: FailureKind,
    /// Which path noticed it first.
    pub path: DetectionPath,
    /// Legacy alias of `path == DevicePlugin` (recovery records).
    pub via_device_plugin: bool,
    /// Measured last-good-heartbeat → detection latency, on the
    /// monitor's clock. `None` for board-scan detections (no wire
    /// timestamps to measure from).
    pub latency_s: Option<f64>,
    pub at: Instant,
}

// ------------------------------------------------------------------
// Wire-plane detection: leased heartbeats
// ------------------------------------------------------------------

/// Lease/stall thresholds for the wire monitor, all derived from the
/// worker push interval.
#[derive(Debug, Clone, Copy)]
pub struct LeaseConfig {
    /// Worker heartbeat push interval.
    pub interval: Duration,
    /// Missed intervals before a silent peer is declared lost.
    pub lease_misses: u32,
    /// A frozen step tag older than this is a stall *candidate*.
    pub stall_after: Duration,
    /// Steps behind the DP-group median before a stall candidate is
    /// reported. >= 2 tolerates the one-step skew a synchronous DP
    /// group can legitimately show around the gradient barrier.
    pub stall_margin: i64,
}

impl Default for LeaseConfig {
    fn default() -> Self {
        LeaseConfig {
            interval: Duration::from_millis(50),
            lease_misses: 3,
            stall_after: Duration::from_millis(500),
            stall_margin: 2,
        }
    }
}

impl LeaseConfig {
    /// The liveness lease: beats older than this mean the worker (or
    /// its node, or its network path) is gone.
    pub fn lease(&self) -> Duration {
        self.interval * self.lease_misses.max(1)
    }
}

/// One admitted worker's lease state.
#[derive(Debug, Clone, Copy)]
struct Lease {
    incarnation: u64,
    last_beat: Instant,
    /// Raw step tag of the last beat (may be -1 mid-optimizer).
    tag: i64,
    /// Last non-negative tag — the comparable notion of progress.
    progress: i64,
    /// When `tag` last changed (any change resets the stall clock).
    tag_since: Instant,
    /// Sticky device-plugin report (-1 = none).
    device_code: i64,
}

/// Controller-side monitor over wire heartbeats.
///
/// Membership is explicit: [`LeaseMonitor::admit`] opens a lease (with
/// a fresh grace period) for `(rank, incarnation)` and
/// [`LeaseMonitor::evict`] closes it; beats for unknown ranks or stale
/// incarnations are ignored, so a zombie predecessor can never refresh
/// its replacement's lease and a stopped rank's parting beats are
/// inert. Bookkeeping mirrors [`HeartbeatMonitor`]: `reported` marks
/// are keyed by `(rank, incarnation)` and pruned on re-admission.
pub struct LeaseMonitor {
    cfg: LeaseConfig,
    leases: BTreeMap<usize, Lease>,
    reported: BTreeSet<(usize, u64)>,
}

impl LeaseMonitor {
    pub fn new(cfg: LeaseConfig) -> Self {
        LeaseMonitor { cfg, leases: BTreeMap::new(), reported: BTreeSet::new() }
    }

    pub fn config(&self) -> &LeaseConfig {
        &self.cfg
    }

    /// Open (or reopen) a lease for `(rank, incarnation)` with a grace
    /// period starting at `now` — the worker has until the lease runs
    /// out to land its first beat.
    pub fn admit(&mut self, rank: usize, incarnation: u64, now: Instant) {
        self.prune_reported(rank);
        self.leases.insert(
            rank,
            Lease {
                incarnation,
                last_beat: now,
                tag: i64::MIN,
                progress: i64::MIN,
                tag_since: now,
                device_code: -1,
            },
        );
    }

    /// Close a rank's lease (clean stop / teardown).
    pub fn evict(&mut self, rank: usize) {
        self.leases.remove(&rank);
        self.prune_reported(rank);
    }

    fn prune_reported(&mut self, rank: usize) {
        let stale: Vec<(usize, u64)> = self
            .reported
            .range((rank, 0)..=(rank, u64::MAX))
            .copied()
            .collect();
        for key in stale {
            self.reported.remove(&key);
        }
    }

    /// Feed one beat. Beats for unadmitted ranks and stale
    /// incarnations are dropped; a beat for a *newer* incarnation than
    /// admitted (shouldn't happen, but the wire is the wire) resets
    /// the lease.
    pub fn observe(
        &mut self,
        rank: usize,
        incarnation: u64,
        step_tag: i64,
        device_code: i64,
        at: Instant,
    ) {
        let Some(l) = self.leases.get_mut(&rank) else {
            return;
        };
        if incarnation < l.incarnation {
            return;
        }
        if incarnation > l.incarnation {
            l.incarnation = incarnation;
            l.last_beat = at;
            l.tag = step_tag;
            l.progress = step_tag.max(-1);
            l.tag_since = at;
            l.device_code = device_code;
            return;
        }
        if at < l.last_beat {
            // stale replay (store snapshots are re-drained every
            // scan, and re-admission must not be backdated by a
            // pre-grace record): teaches nothing new
            return;
        }
        l.last_beat = at;
        if step_tag != l.tag {
            l.tag = step_tag;
            l.tag_since = at;
        }
        if step_tag >= 0 {
            l.progress = l.progress.max(step_tag);
        }
        if device_code >= 0 {
            // sticky: a device report survives later (raced) beats
            l.device_code = device_code;
        }
    }

    /// Feed one store-recorded beat (the usual path: the controller
    /// drains `TcpStoreServer::beats` every scan).
    pub fn observe_beat(&mut self, b: &BeatRecord) {
        self.observe(b.rank as usize, b.incarnation, b.step_tag, b.device_code, b.at);
    }

    /// Incarnation currently leased for `rank`.
    pub fn incarnation_of(&self, rank: usize) -> Option<u64> {
        self.leases.get(&rank).map(|l| l.incarnation)
    }

    /// Seconds since `rank`'s last good beat — the measured component
    /// of `detection_s` even when another path won the detection race.
    pub fn since_last_beat(&self, rank: usize, now: Instant) -> Option<f64> {
        self.leases
            .get(&rank)
            .map(|l| now.saturating_duration_since(l.last_beat).as_secs_f64())
    }

    /// Upper median of the unreported ranks' progress tags — the
    /// group's notion of "where training is".
    fn median_progress(&self) -> Option<i64> {
        let mut tags = Vec::with_capacity(self.leases.len());
        for (&rank, l) in &self.leases {
            if l.progress >= 0 && !self.reported.contains(&(rank, l.incarnation)) {
                tags.push(l.progress);
            }
        }
        if tags.is_empty() {
            return None;
        }
        tags.sort_unstable();
        Some(tags[tags.len() / 2])
    }

    /// One scan over the lease table: returns any *new* failures.
    /// Classification precedence per rank: device plugin (a hardware
    /// report must win even when the lease expired in the same
    /// interval — the misclassification race), then lease expiry, then
    /// step-tag stall.
    pub fn scan(&mut self, now: Instant) -> Vec<Detection> {
        let lease = self.cfg.lease();
        let median = self.median_progress();
        let mut out = Vec::new();
        let mut newly_reported = Vec::new();
        for (&rank, l) in &self.leases {
            if self.reported.contains(&(rank, l.incarnation)) {
                continue;
            }
            let silent_for = now.saturating_duration_since(l.last_beat);
            if l.device_code >= 0 {
                out.push(Detection {
                    rank,
                    kind: kind_from_code(l.device_code).unwrap_or(FailureKind::HardwareOther),
                    path: DetectionPath::DevicePlugin,
                    via_device_plugin: true,
                    latency_s: Some(silent_for.as_secs_f64()),
                    at: now,
                });
                newly_reported.push((rank, l.incarnation));
                continue;
            }
            if silent_for > lease {
                // Process lost with no hardware report: classified as
                // a software failure by the monitoring process.
                out.push(Detection {
                    rank,
                    kind: FailureKind::Segfault,
                    path: DetectionPath::LeaseExpiry,
                    via_device_plugin: false,
                    latency_s: Some(silent_for.as_secs_f64()),
                    at: now,
                });
                newly_reported.push((rank, l.incarnation));
                continue;
            }
            if let Some(m) = median {
                let frozen_for = now.saturating_duration_since(l.tag_since);
                if l.progress >= 0
                    && frozen_for > self.cfg.stall_after
                    && m - l.progress >= self.cfg.stall_margin
                {
                    // Alive but not making progress while the group
                    // moves on: silent hang / hard straggler.
                    out.push(Detection {
                        rank,
                        kind: FailureKind::Timeout,
                        path: DetectionPath::StepStall,
                        via_device_plugin: false,
                        latency_s: Some(frozen_for.as_secs_f64()),
                        at: now,
                    });
                    newly_reported.push((rank, l.incarnation));
                }
            }
        }
        self.reported.extend(newly_reported);
        out
    }
}

// ------------------------------------------------------------------
// In-process fallback: board scans
// ------------------------------------------------------------------

/// Scans all workers' monitor boards every heartbeat interval.
///
/// Bookkeeping is keyed by `(rank, incarnation)`: every `watch` of a
/// rank opens a new incarnation, so a replacement worker registered on
/// a previously-failed rank is monitored afresh — its predecessor's
/// "already reported" mark can never suppress it. Lookups are map/set
/// based (the old linear `Vec` scans made every heartbeat O(dp²) under
/// watch/unwatch churn), and `unwatch` prunes the rank's reported
/// marks so long-lived controllers do not accumulate dead keys.
pub struct HeartbeatMonitor {
    /// rank -> (incarnation, board)
    boards: BTreeMap<usize, (u64, Arc<MonitorBoard>)>,
    /// (rank, incarnation) pairs already reported (do not re-report).
    reported: BTreeSet<(usize, u64)>,
    next_incarnation: u64,
}

impl HeartbeatMonitor {
    pub fn new() -> Self {
        HeartbeatMonitor {
            boards: BTreeMap::new(),
            reported: BTreeSet::new(),
            next_incarnation: 0,
        }
    }

    pub fn watch(&mut self, rank: usize, board: Arc<MonitorBoard>) {
        self.next_incarnation += 1;
        self.prune_reported(rank);
        self.boards.insert(rank, (self.next_incarnation, board));
    }

    pub fn unwatch(&mut self, rank: usize) {
        self.boards.remove(&rank);
        self.prune_reported(rank);
    }

    fn prune_reported(&mut self, rank: usize) {
        let stale: Vec<(usize, u64)> = self
            .reported
            .range((rank, 0)..=(rank, u64::MAX))
            .copied()
            .collect();
        for key in stale {
            self.reported.remove(&key);
        }
    }

    /// Current step tag of a rank (the heartbeat payload).
    pub fn tag_of(&self, rank: usize) -> Option<i64> {
        self.boards
            .get(&rank)
            .map(|(_, b)| b.step_tag.load(Ordering::SeqCst))
    }

    /// Current incarnation of a rank's monitored worker.
    pub fn incarnation_of(&self, rank: usize) -> Option<u64> {
        self.boards.get(&rank).map(|(inc, _)| *inc)
    }

    /// One scan: returns any *new* failures.
    pub fn scan(&mut self) -> Vec<Detection> {
        let now = Instant::now();
        let mut out = Vec::new();
        let mut newly_reported = Vec::new();
        for (&rank, (inc, board)) in &self.boards {
            if self.reported.contains(&(rank, *inc)) {
                continue;
            }
            let code = board.device_error.load(Ordering::SeqCst);
            if code >= 0 {
                out.push(Detection {
                    rank,
                    kind: kind_from_code(code).unwrap_or(FailureKind::HardwareOther),
                    path: DetectionPath::DevicePlugin,
                    via_device_plugin: true,
                    latency_s: None,
                    at: now,
                });
                newly_reported.push((rank, *inc));
                continue;
            }
            if !board.alive.load(Ordering::SeqCst) {
                // Process lost with no hardware report: classified as a
                // software failure by the monitoring process.
                out.push(Detection {
                    rank,
                    kind: FailureKind::Segfault,
                    path: DetectionPath::Liveness,
                    via_device_plugin: false,
                    latency_s: None,
                    at: now,
                });
                newly_reported.push((rank, *inc));
            }
        }
        self.reported.extend(newly_reported);
        out
    }

    /// Ranks currently alive (and not reported failed).
    pub fn alive_ranks(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for (&rank, (inc, board)) in &self.boards {
            if !self.reported.contains(&(rank, *inc))
                && board.alive.load(Ordering::SeqCst)
            {
                out.push(rank);
            }
        }
        out
    }
}

impl Default for HeartbeatMonitor {
    fn default() -> Self {
        Self::new()
    }
}

// ------------------------------------------------------------------
// Detection-latency sweep (the `bench detect` CLI / bench target)
// ------------------------------------------------------------------

/// Configuration for the detection-latency scale sweep.
#[derive(Debug, Clone)]
pub struct DetectionSweepConfig {
    /// Simulated fleet sizes: the monitor's lease table runs at full
    /// scale (its O(alive) scan is part of what is measured).
    pub scales: Vec<usize>,
    /// Measured kill→detect episodes per scale (+1 discarded warmup).
    pub samples: u32,
    /// Ranks driven as *live* wire agents (real heartbeat emitter
    /// threads over real sockets), victim included. Every worker runs
    /// the identical O(1)-per-beat protocol, so a fixed sample bounds
    /// thread/socket count while the lease table scans at full scale —
    /// the same scale model as the rendezvous sweep (DESIGN.md §8).
    pub live_agents: usize,
    /// Heartbeat push interval.
    pub interval: Duration,
    /// Missed intervals before lease expiry.
    pub lease_misses: u32,
    /// Push the live sample's beats through one *node agent* (a single
    /// `Batch` frame per interval for all sampled ranks, DESIGN.md
    /// §11) instead of one emitter connection per rank. Off by
    /// default so the committed baseline measures the per-process
    /// emitter path.
    pub node_agent: bool,
}

impl Default for DetectionSweepConfig {
    fn default() -> Self {
        DetectionSweepConfig {
            scales: vec![64, 256, 1024, 4096],
            samples: 5,
            live_agents: 16,
            interval: Duration::from_millis(20),
            lease_misses: 5,
            node_agent: false,
        }
    }
}

/// Run the detection-latency scale sweep: per scale, a victim worker
/// dies (its emitter goes silent) and the wall clock from its last
/// good heartbeat to the `LeaseMonitor` detection is measured over
/// real sockets. Column 0 (`p50 ms`) is what CI's bench gate compares
/// against the committed baseline; flatness across scales is the
/// paper's "within seconds, independent of cluster size" claim —
/// heartbeats are O(1) per worker and the scan is O(alive).
pub fn detection_sweep(cfg: &DetectionSweepConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new(
        "detection_latency: leased heartbeats over the live TCP plane, scale sweep",
        &["p50 ms", "mean ms", "max ms", "scan p50 us", "live agents"],
    );
    for &n in &cfg.scales {
        if n < 2 {
            bail!("sweep scale must be >= 2 ranks (got {n})");
        }
        let lease_cfg = LeaseConfig {
            interval: cfg.interval,
            lease_misses: cfg.lease_misses,
            // liveness only: stalls are exercised by tests + the chaos
            // driver, not this latency sweep
            stall_after: Duration::from_secs(3600),
            stall_margin: 2,
        };
        let server = TcpStoreServer::start()?;
        let eps = server.endpoints();
        let mut mon = LeaseMonitor::new(lease_cfg);
        let t_admit = Instant::now();
        for r in 0..n {
            mon.admit(r, 1, t_admit);
        }

        // live wire agents: an evenly-strided sample; the victim is
        // one of them so its silence is a real absence of packets
        let live = cfg.live_agents.clamp(2, n);
        let stride = n / live;
        let sample: Vec<usize> = (0..live).map(|i| i * stride).collect();
        let victim = sample[1];
        let virtuals: Vec<usize> = (0..n).filter(|r| !sample.contains(r)).collect();

        let mut emitters = Vec::new();
        let mut boards: BTreeMap<usize, Arc<MonitorBoard>> = BTreeMap::new();
        for &r in &sample {
            boards.insert(r, MonitorBoard::new());
        }
        if cfg.node_agent {
            // coalesced mode: the whole sample's beats ride one Batch
            // frame per interval through a single node agent
            let members: Vec<NodeRank> = sample
                .iter()
                .map(|&r| NodeRank { rank: r, incarnation: 1, board: boards[&r].clone() })
                .collect();
            emitters.push(spawn_node_heartbeat(
                members,
                NodeAgentCfg { store: eps.clone(), interval: cfg.interval },
            ));
        } else {
            for &r in &sample {
                emitters.push(spawn_heartbeat(
                    r,
                    boards[&r].clone(),
                    HeartbeatCfg {
                        store: eps.clone(),
                        interval: cfg.interval,
                        incarnation: 1,
                    },
                ));
            }
        }

        let mut h = Histogram::new();
        let mut scan_h = Histogram::new();
        let mut incarnation = 1u64;
        for i in 0..=cfg.samples {
            // settle: the victim's emitter must have a beat on record
            std::thread::sleep(cfg.interval);
            for b in server.beats() {
                mon.observe_beat(&b);
            }
            let _ = mon.scan(Instant::now()); // drain any stragglers
            // fresh grace for the victim so the episode starts clean
            mon.admit(victim, incarnation, Instant::now());

            let t0 = Instant::now();
            boards[&victim].alive.store(false, Ordering::SeqCst);
            let deadline = t0 + Duration::from_secs(30);
            let latency_s = loop {
                if Instant::now() > deadline {
                    bail!("detection timed out at n={n}");
                }
                let now = Instant::now();
                // virtual ranks' beats keep flowing (full-scale lease
                // table churn — the O(alive) cost under test)
                for &r in &virtuals {
                    mon.observe(r, 1, 0, -1, now);
                }
                for b in server.beats() {
                    mon.observe_beat(&b);
                }
                let t_scan = Instant::now();
                let ds = mon.scan(Instant::now());
                scan_h.record(t_scan.elapsed().as_secs_f64());
                if let Some(d) = ds.iter().find(|d| d.rank == victim) {
                    break d.latency_s.unwrap_or_else(|| t0.elapsed().as_secs_f64());
                }
                std::thread::sleep(Duration::from_millis(2));
            };
            if i > 0 {
                // episode 0 is warmup (server threads, allocator)
                h.record(latency_s);
            }
            if i == cfg.samples {
                break; // last episode: no revive, teardown follows
            }
            // revive the victim under a new incarnation
            incarnation += 1;
            let b = MonitorBoard::new();
            emitters.push(spawn_heartbeat(
                victim,
                b.clone(),
                HeartbeatCfg { store: eps.clone(), interval: cfg.interval, incarnation },
            ));
            boards.insert(victim, b);
            mon.admit(victim, incarnation, Instant::now());
        }
        for b in boards.values() {
            b.alive.store(false, Ordering::SeqCst);
        }
        drop(server);
        for e in emitters {
            let _ = e.join();
        }
        report.row(
            format!("n={n}"),
            vec![
                h.p50() * 1e3,
                h.mean() * 1e3,
                h.max() * 1e3,
                scan_h.p50() * 1e6,
                live as f64,
            ],
        );
    }
    report.note(format!(
        "{} samples/scale (+1 warmup); lease = {} x {:?}; latency measured \
         last-good-heartbeat -> detection over real sockets; lease table at \
         full scale, {} live emitters ({})",
        cfg.samples,
        cfg.lease_misses,
        cfg.interval,
        cfg.live_agents,
        if cfg.node_agent {
            "coalesced through one node agent"
        } else {
            "one connection per rank"
        }
    ));
    report.note(
        "scale-independence: beats are O(1)/worker, the scan O(alive) — p50 \
         stays within 2x from the smallest to the largest scale",
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn board() -> Arc<MonitorBoard> {
        MonitorBoard::new()
    }

    #[test]
    fn healthy_boards_report_nothing() {
        let mut mon = HeartbeatMonitor::new();
        mon.watch(0, board());
        mon.watch(1, board());
        assert!(mon.scan().is_empty());
        assert_eq!(mon.alive_ranks(), vec![0, 1]);
    }

    #[test]
    fn dead_process_detected_as_software() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(3, b.clone());
        b.alive.store(false, Ordering::SeqCst);
        let d = mon.scan();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rank, 3);
        assert!(!d[0].via_device_plugin);
        assert_eq!(d[0].path, DetectionPath::Liveness);
        assert_eq!(d[0].latency_s, None);
        // reported once only
        assert!(mon.scan().is_empty());
        assert!(mon.alive_ranks().is_empty());
    }

    #[test]
    fn device_plugin_reports_hardware_kind() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(1, b.clone());
        // simulate the plugin flagging a network error (still "alive")
        let code = FailureKind::all()
            .iter()
            .position(|k| *k == FailureKind::Network)
            .unwrap() as i64;
        b.device_error.store(code, Ordering::SeqCst);
        let d = mon.scan();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind, FailureKind::Network);
        assert!(d[0].via_device_plugin);
        assert_eq!(d[0].path, DetectionPath::DevicePlugin);
    }

    #[test]
    fn tag_of_reads_heartbeat_payload() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(0, b.clone());
        b.step_tag.store(17, Ordering::SeqCst);
        assert_eq!(mon.tag_of(0), Some(17));
        assert_eq!(mon.tag_of(9), None);
    }

    #[test]
    fn watch_unwatch_churn_always_remonitors_replacements() {
        // Regression: `reported` marks used to outlive a rank's worker,
        // so a replacement on a previously-failed rank could be ignored.
        let mut mon = HeartbeatMonitor::new();
        for cycle in 0..5 {
            let b = board();
            mon.watch(7, b.clone());
            assert_eq!(mon.alive_ranks(), vec![7], "cycle {cycle}");
            b.alive.store(false, Ordering::SeqCst);
            assert_eq!(mon.scan().len(), 1, "cycle {cycle}: death missed");
            assert!(mon.scan().is_empty(), "cycle {cycle}: double report");
            mon.unwatch(7);
            assert!(mon.scan().is_empty());
        }
    }

    #[test]
    fn each_watch_opens_a_new_incarnation() {
        let mut mon = HeartbeatMonitor::new();
        mon.watch(0, board());
        let first = mon.incarnation_of(0).unwrap();
        mon.watch(0, board());
        assert!(mon.incarnation_of(0).unwrap() > first);
        assert_eq!(mon.incarnation_of(9), None);
    }

    #[test]
    fn rewatch_clears_reported_state() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(0, b.clone());
        b.alive.store(false, Ordering::SeqCst);
        assert_eq!(mon.scan().len(), 1);
        // replacement worker re-registers the same rank
        mon.watch(0, board());
        assert!(mon.scan().is_empty());
        assert_eq!(mon.alive_ranks(), vec![0]);
    }

    // ---------------- LeaseMonitor ----------------

    fn lease_cfg() -> LeaseConfig {
        LeaseConfig {
            interval: Duration::from_millis(10),
            lease_misses: 3,
            stall_after: Duration::from_millis(50),
            stall_margin: 2,
        }
    }

    fn net_code() -> i64 {
        FailureKind::all()
            .iter()
            .position(|k| *k == FailureKind::Network)
            .unwrap() as i64
    }

    /// Build a monitor with `n` admitted ranks all beating at `t0`.
    fn fleet(n: usize, t0: Instant) -> LeaseMonitor {
        let mut mon = LeaseMonitor::new(lease_cfg());
        for r in 0..n {
            mon.admit(r, 1, t0);
            mon.observe(r, 1, 0, -1, t0);
        }
        mon
    }

    #[test]
    fn fresh_leases_report_nothing() {
        let t0 = Instant::now();
        let mut mon = fleet(4, t0);
        assert!(mon.scan(t0 + Duration::from_millis(5)).is_empty());
    }

    #[test]
    fn lease_expiry_detects_silent_worker_with_measured_latency() {
        let t0 = Instant::now();
        let mut mon = fleet(4, t0);
        // ranks 0,1,2 keep beating; rank 3 goes silent
        let later = t0 + Duration::from_millis(40);
        for r in 0..3 {
            mon.observe(r, 1, 1, -1, later);
        }
        let now = t0 + Duration::from_millis(45);
        let d = mon.scan(now);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rank, 3);
        assert_eq!(d[0].path, DetectionPath::LeaseExpiry);
        assert_eq!(d[0].kind, FailureKind::Segfault);
        let lat = d[0].latency_s.expect("wire detections carry latency");
        assert!(lat >= 0.030 && lat < 0.2, "measured latency {lat}");
        // reported once only
        assert!(mon.scan(now + Duration::from_millis(50)).is_empty());
    }

    #[test]
    fn device_code_beats_lease_expiry_in_the_same_interval() {
        // Misclassification race: the device plugin's hardware report
        // lands in the same interval as the process death. The scan
        // sees both an expired lease *and* a device code — the
        // hardware kind must win, never a generic Segfault.
        let t0 = Instant::now();
        let mut mon = fleet(2, t0);
        // final-gasp beat carrying the device code, then silence
        mon.observe(1, 1, 3, net_code(), t0 + Duration::from_millis(2));
        let now = t0 + Duration::from_millis(200); // lease long expired
        let d = mon.scan(now);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rank, 1);
        assert_eq!(d[0].kind, FailureKind::Network, "hardware kind must win");
        assert_eq!(d[0].path, DetectionPath::DevicePlugin);
        assert!(d[0].via_device_plugin);
    }

    #[test]
    fn stall_behind_median_is_a_silent_hang() {
        // Rank 1 freezes at tag 5 while the group advances: alive (its
        // beats keep arriving) but not progressing — the failure class
        // a liveness flag cannot see.
        let t0 = Instant::now();
        let mut mon = LeaseMonitor::new(lease_cfg());
        for r in 0..4 {
            mon.admit(r, 1, t0);
            mon.observe(r, 1, 5, -1, t0);
        }
        // beats keep flowing; survivors' tags advance, rank 1 frozen
        for tick in 1..=8i64 {
            let at = t0 + Duration::from_millis(10 * tick as u64);
            for r in [0usize, 2, 3] {
                mon.observe(r, 1, 5 + tick, -1, at);
            }
            mon.observe(1, 1, 5, -1, at);
        }
        let now = t0 + Duration::from_millis(85);
        let d = mon.scan(now);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rank, 1);
        assert_eq!(d[0].path, DetectionPath::StepStall);
        assert_eq!(d[0].kind, FailureKind::Timeout);
        let lat = d[0].latency_s.expect("stall latency measured");
        assert!(lat >= 0.050, "frozen-for latency {lat}");
    }

    #[test]
    fn straggler_resuming_before_threshold_is_not_reported() {
        // Misclassification race 2: a slow worker that resumes before
        // the stall threshold must not be evicted.
        let t0 = Instant::now();
        let mut mon = LeaseMonitor::new(lease_cfg());
        for r in 0..4 {
            mon.admit(r, 1, t0);
            mon.observe(r, 1, 5, -1, t0);
        }
        // group advances; rank 1 lags 3 steps behind but resumes at
        // t=40ms, inside the 50ms stall window
        for tick in 1..=4i64 {
            let at = t0 + Duration::from_millis(10 * tick as u64);
            for r in [0usize, 2, 3] {
                mon.observe(r, 1, 5 + tick, -1, at);
            }
            mon.observe(1, 1, 5, -1, at);
        }
        mon.observe(1, 1, 6, -1, t0 + Duration::from_millis(40));
        // scan *after* the stall window would have fired for tag 5
        let d = mon.scan(t0 + Duration::from_millis(60));
        assert!(d.is_empty(), "resumed straggler misreported: {d:?}");
    }

    #[test]
    fn lockstep_freeze_does_not_false_positive() {
        // When a peer dies, every survivor blocks in the collective at
        // the *same* tag: nobody is behind the median, so stall
        // detection stays quiet (the lease/liveness path owns that
        // failure).
        let t0 = Instant::now();
        let mut mon = LeaseMonitor::new(lease_cfg());
        for r in 0..4 {
            mon.admit(r, 1, t0);
            mon.observe(r, 1, 9, -1, t0);
        }
        for tick in 1..=10u64 {
            let at = t0 + Duration::from_millis(10 * tick);
            for r in 0..4 {
                mon.observe(r, 1, 9, -1, at); // all frozen together
            }
        }
        assert!(mon.scan(t0 + Duration::from_millis(105)).is_empty());
    }

    #[test]
    fn zombie_incarnation_cannot_refresh_replacement_lease() {
        let t0 = Instant::now();
        let mut mon = LeaseMonitor::new(lease_cfg());
        mon.admit(0, 2, t0); // replacement, incarnation 2
        mon.observe(0, 2, 4, -1, t0);
        // zombie predecessor's beat must be inert
        mon.observe(0, 1, 99, -1, t0 + Duration::from_millis(100));
        let d = mon.scan(t0 + Duration::from_millis(100));
        assert_eq!(d.len(), 1, "replacement lease must expire: {d:?}");
        assert_eq!(d[0].path, DetectionPath::LeaseExpiry);
    }

    #[test]
    fn readmission_clears_reported_marks() {
        let t0 = Instant::now();
        let mut mon = fleet(2, t0);
        let d = mon.scan(t0 + Duration::from_millis(100));
        assert_eq!(d.len(), 2, "both leases expired");
        mon.admit(0, 2, t0 + Duration::from_millis(100));
        mon.observe(0, 2, 0, -1, t0 + Duration::from_millis(100));
        assert!(mon.scan(t0 + Duration::from_millis(105)).is_empty());
        assert_eq!(mon.incarnation_of(0), Some(2));
        mon.evict(1);
        assert_eq!(mon.incarnation_of(1), None);
    }

    #[test]
    fn optimizer_tag_does_not_break_stall_math() {
        // tag -1 (optimizer phase) must neither poison the median nor
        // hide a hang: progress tracks the last non-negative tag.
        let t0 = Instant::now();
        let mut mon = LeaseMonitor::new(lease_cfg());
        for r in 0..4 {
            mon.admit(r, 1, t0);
            mon.observe(r, 1, 5, -1, t0);
        }
        // rank 1 freezes inside the optimizer (tag -1) at t=10ms
        mon.observe(1, 1, -1, -1, t0 + Duration::from_millis(10));
        for tick in 2..=9i64 {
            let at = t0 + Duration::from_millis(10 * tick as u64);
            for r in [0usize, 2, 3] {
                mon.observe(r, 1, 4 + tick, -1, at);
            }
            mon.observe(1, 1, -1, -1, at);
        }
        let d = mon.scan(t0 + Duration::from_millis(95));
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rank, 1);
        assert_eq!(d[0].path, DetectionPath::StepStall);
    }

    #[test]
    fn detection_sweep_smoke() {
        // tiny end-to-end sweep over real sockets
        let cfg = DetectionSweepConfig {
            scales: vec![8],
            samples: 1,
            live_agents: 4,
            interval: Duration::from_millis(10),
            lease_misses: 3,
            node_agent: false,
        };
        let report = detection_sweep(&cfg).unwrap();
        let row = report.row_values("n=8").expect("row");
        assert!(row[0] > 0.0, "p50 must be measured: {row:?}");
        assert!(row[0] < 10_000.0, "p50 implausible: {row:?}");
    }

    #[test]
    fn detection_sweep_smoke_node_agent() {
        // same sweep with the sample's beats coalesced through one
        // node agent: detection semantics (lease expiry of a victim
        // whose beats stop) must be mode-independent
        let cfg = DetectionSweepConfig {
            scales: vec![8],
            samples: 1,
            live_agents: 4,
            interval: Duration::from_millis(10),
            lease_misses: 3,
            node_agent: true,
        };
        let report = detection_sweep(&cfg).unwrap();
        let row = report.row_values("n=8").expect("row");
        assert!(row[0] > 0.0, "p50 must be measured: {row:?}");
        assert!(row[0] < 10_000.0, "p50 implausible: {row:?}");
    }
}
