//! Active real-time failure detection (paper §III-C).
//!
//! Two detection paths feed the controller:
//! * **monitoring process** — per-worker liveness (`alive` flag on the
//!   [`MonitorBoard`]): a dead training process is noticed within one
//!   heartbeat scan;
//! * **device plugin** — per-node hardware status (`device_error`):
//!   hardware failures are reported with their [`FailureKind`]
//!   immediately, before liveness is even lost.
//!
//! This replaces the passive baseline where peers discover a failure
//! only when a collective hangs into its (default 1800 s) timeout.

use crate::cluster::failure::FailureKind;
use crate::training::worker::{kind_from_code, MonitorBoard};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// One detected failure.
#[derive(Debug, Clone)]
pub struct Detection {
    pub rank: usize,
    pub kind: FailureKind,
    /// Which path noticed it first.
    pub via_device_plugin: bool,
    pub at: Instant,
}

/// Scans all workers' monitor boards every heartbeat interval.
///
/// Bookkeeping is keyed by `(rank, incarnation)`: every `watch` of a
/// rank opens a new incarnation, so a replacement worker registered on
/// a previously-failed rank is monitored afresh — its predecessor's
/// "already reported" mark can never suppress it. Lookups are map/set
/// based (the old linear `Vec` scans made every heartbeat O(dp²) under
/// watch/unwatch churn), and `unwatch` prunes the rank's reported
/// marks so long-lived controllers do not accumulate dead keys.
pub struct HeartbeatMonitor {
    /// rank -> (incarnation, board)
    boards: BTreeMap<usize, (u64, Arc<MonitorBoard>)>,
    /// (rank, incarnation) pairs already reported (do not re-report).
    reported: BTreeSet<(usize, u64)>,
    next_incarnation: u64,
}

impl HeartbeatMonitor {
    pub fn new() -> Self {
        HeartbeatMonitor {
            boards: BTreeMap::new(),
            reported: BTreeSet::new(),
            next_incarnation: 0,
        }
    }

    pub fn watch(&mut self, rank: usize, board: Arc<MonitorBoard>) {
        self.next_incarnation += 1;
        self.prune_reported(rank);
        self.boards.insert(rank, (self.next_incarnation, board));
    }

    pub fn unwatch(&mut self, rank: usize) {
        self.boards.remove(&rank);
        self.prune_reported(rank);
    }

    fn prune_reported(&mut self, rank: usize) {
        let stale: Vec<(usize, u64)> = self
            .reported
            .range((rank, 0)..=(rank, u64::MAX))
            .copied()
            .collect();
        for key in stale {
            self.reported.remove(&key);
        }
    }

    /// Current step tag of a rank (the heartbeat payload).
    pub fn tag_of(&self, rank: usize) -> Option<i64> {
        self.boards
            .get(&rank)
            .map(|(_, b)| b.step_tag.load(Ordering::SeqCst))
    }

    /// Current incarnation of a rank's monitored worker.
    pub fn incarnation_of(&self, rank: usize) -> Option<u64> {
        self.boards.get(&rank).map(|(inc, _)| *inc)
    }

    /// One scan: returns any *new* failures.
    pub fn scan(&mut self) -> Vec<Detection> {
        let now = Instant::now();
        let mut out = Vec::new();
        let mut newly_reported = Vec::new();
        for (&rank, (inc, board)) in &self.boards {
            if self.reported.contains(&(rank, *inc)) {
                continue;
            }
            let code = board.device_error.load(Ordering::SeqCst);
            if code >= 0 {
                out.push(Detection {
                    rank,
                    kind: kind_from_code(code).unwrap_or(FailureKind::HardwareOther),
                    via_device_plugin: true,
                    at: now,
                });
                newly_reported.push((rank, *inc));
                continue;
            }
            if !board.alive.load(Ordering::SeqCst) {
                // Process lost with no hardware report: classified as a
                // software failure by the monitoring process.
                out.push(Detection {
                    rank,
                    kind: FailureKind::Segfault,
                    via_device_plugin: false,
                    at: now,
                });
                newly_reported.push((rank, *inc));
            }
        }
        self.reported.extend(newly_reported);
        out
    }

    /// Ranks currently alive (and not reported failed).
    pub fn alive_ranks(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for (&rank, (inc, board)) in &self.boards {
            if !self.reported.contains(&(rank, *inc))
                && board.alive.load(Ordering::SeqCst)
            {
                out.push(rank);
            }
        }
        out
    }
}

impl Default for HeartbeatMonitor {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn board() -> Arc<MonitorBoard> {
        MonitorBoard::new()
    }

    #[test]
    fn healthy_boards_report_nothing() {
        let mut mon = HeartbeatMonitor::new();
        mon.watch(0, board());
        mon.watch(1, board());
        assert!(mon.scan().is_empty());
        assert_eq!(mon.alive_ranks(), vec![0, 1]);
    }

    #[test]
    fn dead_process_detected_as_software() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(3, b.clone());
        b.alive.store(false, Ordering::SeqCst);
        let d = mon.scan();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rank, 3);
        assert!(!d[0].via_device_plugin);
        // reported once only
        assert!(mon.scan().is_empty());
        assert!(mon.alive_ranks().is_empty());
    }

    #[test]
    fn device_plugin_reports_hardware_kind() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(1, b.clone());
        // simulate the plugin flagging a network error (still "alive")
        let code = FailureKind::all()
            .iter()
            .position(|k| *k == FailureKind::Network)
            .unwrap() as i64;
        b.device_error.store(code, Ordering::SeqCst);
        let d = mon.scan();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind, FailureKind::Network);
        assert!(d[0].via_device_plugin);
    }

    #[test]
    fn tag_of_reads_heartbeat_payload() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(0, b.clone());
        b.step_tag.store(17, Ordering::SeqCst);
        assert_eq!(mon.tag_of(0), Some(17));
        assert_eq!(mon.tag_of(9), None);
    }

    #[test]
    fn watch_unwatch_churn_always_remonitors_replacements() {
        // Regression: `reported` marks used to outlive a rank's worker,
        // so a replacement on a previously-failed rank could be ignored.
        let mut mon = HeartbeatMonitor::new();
        for cycle in 0..5 {
            let b = board();
            mon.watch(7, b.clone());
            assert_eq!(mon.alive_ranks(), vec![7], "cycle {cycle}");
            b.alive.store(false, Ordering::SeqCst);
            assert_eq!(mon.scan().len(), 1, "cycle {cycle}: death missed");
            assert!(mon.scan().is_empty(), "cycle {cycle}: double report");
            mon.unwatch(7);
            assert!(mon.scan().is_empty());
        }
    }

    #[test]
    fn each_watch_opens_a_new_incarnation() {
        let mut mon = HeartbeatMonitor::new();
        mon.watch(0, board());
        let first = mon.incarnation_of(0).unwrap();
        mon.watch(0, board());
        assert!(mon.incarnation_of(0).unwrap() > first);
        assert_eq!(mon.incarnation_of(9), None);
    }

    #[test]
    fn rewatch_clears_reported_state() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(0, b.clone());
        b.alive.store(false, Ordering::SeqCst);
        assert_eq!(mon.scan().len(), 1);
        // replacement worker re-registers the same rank
        mon.watch(0, board());
        assert!(mon.scan().is_empty());
        assert_eq!(mon.alive_ranks(), vec![0]);
    }
}
