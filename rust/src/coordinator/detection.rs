//! Active real-time failure detection (paper §III-C).
//!
//! Two detection paths feed the controller:
//! * **monitoring process** — per-worker liveness (`alive` flag on the
//!   [`MonitorBoard`]): a dead training process is noticed within one
//!   heartbeat scan;
//! * **device plugin** — per-node hardware status (`device_error`):
//!   hardware failures are reported with their [`FailureKind`]
//!   immediately, before liveness is even lost.
//!
//! This replaces the passive baseline where peers discover a failure
//! only when a collective hangs into its (default 1800 s) timeout.

use crate::cluster::failure::FailureKind;
use crate::training::worker::{kind_from_code, MonitorBoard};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// One detected failure.
#[derive(Debug, Clone)]
pub struct Detection {
    pub rank: usize,
    pub kind: FailureKind,
    /// Which path noticed it first.
    pub via_device_plugin: bool,
    pub at: Instant,
}

/// Scans all workers' monitor boards every heartbeat interval.
pub struct HeartbeatMonitor {
    boards: Vec<(usize, Arc<MonitorBoard>)>,
    /// Ranks already reported (do not re-report).
    reported: Vec<usize>,
}

impl HeartbeatMonitor {
    pub fn new() -> Self {
        HeartbeatMonitor { boards: Vec::new(), reported: Vec::new() }
    }

    pub fn watch(&mut self, rank: usize, board: Arc<MonitorBoard>) {
        self.boards.retain(|(r, _)| *r != rank);
        self.reported.retain(|r| *r != rank);
        self.boards.push((rank, board));
    }

    pub fn unwatch(&mut self, rank: usize) {
        self.boards.retain(|(r, _)| *r != rank);
        self.reported.retain(|r| *r != rank);
    }

    /// Current step tag of a rank (the heartbeat payload).
    pub fn tag_of(&self, rank: usize) -> Option<i64> {
        self.boards
            .iter()
            .find(|(r, _)| *r == rank)
            .map(|(_, b)| b.step_tag.load(Ordering::SeqCst))
    }

    /// One scan: returns any *new* failures.
    pub fn scan(&mut self) -> Vec<Detection> {
        let now = Instant::now();
        let mut out = Vec::new();
        for (rank, board) in &self.boards {
            if self.reported.contains(rank) {
                continue;
            }
            let code = board.device_error.load(Ordering::SeqCst);
            if code >= 0 {
                out.push(Detection {
                    rank: *rank,
                    kind: kind_from_code(code).unwrap_or(FailureKind::HardwareOther),
                    via_device_plugin: true,
                    at: now,
                });
                self.reported.push(*rank);
                continue;
            }
            if !board.alive.load(Ordering::SeqCst) {
                // Process lost with no hardware report: classified as a
                // software failure by the monitoring process.
                out.push(Detection {
                    rank: *rank,
                    kind: FailureKind::Segfault,
                    via_device_plugin: false,
                    at: now,
                });
                self.reported.push(*rank);
            }
        }
        out
    }

    /// Ranks currently alive (and not reported failed).
    pub fn alive_ranks(&self) -> Vec<usize> {
        self.boards
            .iter()
            .filter(|(r, b)| {
                !self.reported.contains(r) && b.alive.load(Ordering::SeqCst)
            })
            .map(|(r, _)| *r)
            .collect()
    }
}

impl Default for HeartbeatMonitor {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn board() -> Arc<MonitorBoard> {
        MonitorBoard::new()
    }

    #[test]
    fn healthy_boards_report_nothing() {
        let mut mon = HeartbeatMonitor::new();
        mon.watch(0, board());
        mon.watch(1, board());
        assert!(mon.scan().is_empty());
        assert_eq!(mon.alive_ranks(), vec![0, 1]);
    }

    #[test]
    fn dead_process_detected_as_software() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(3, b.clone());
        b.alive.store(false, Ordering::SeqCst);
        let d = mon.scan();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rank, 3);
        assert!(!d[0].via_device_plugin);
        // reported once only
        assert!(mon.scan().is_empty());
        assert!(mon.alive_ranks().is_empty());
    }

    #[test]
    fn device_plugin_reports_hardware_kind() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(1, b.clone());
        // simulate the plugin flagging a network error (still "alive")
        let code = FailureKind::all()
            .iter()
            .position(|k| *k == FailureKind::Network)
            .unwrap() as i64;
        b.device_error.store(code, Ordering::SeqCst);
        let d = mon.scan();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind, FailureKind::Network);
        assert!(d[0].via_device_plugin);
    }

    #[test]
    fn tag_of_reads_heartbeat_payload() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(0, b.clone());
        b.step_tag.store(17, Ordering::SeqCst);
        assert_eq!(mon.tag_of(0), Some(17));
        assert_eq!(mon.tag_of(9), None);
    }

    #[test]
    fn rewatch_clears_reported_state() {
        let mut mon = HeartbeatMonitor::new();
        let b = board();
        mon.watch(0, b.clone());
        b.alive.store(false, Ordering::SeqCst);
        assert_eq!(mon.scan().len(), 1);
        // replacement worker re-registers the same rank
        mon.watch(0, board());
        assert!(mon.scan().is_empty());
        assert_eq!(mon.alive_ranks(), vec![0]);
    }
}
