//! Controller-side records of recovery episodes and run outcomes.

use crate::cluster::failure::FailureKind;
use crate::config::{RecoveryMode, ShardId};
use crate::util::Json;

/// One shard's streaming restore within a recovery episode: which
/// surviving replica served which target, how many bytes moved, and
/// how long the transfer took (DESIGN.md §9).
#[derive(Debug, Clone, Copy)]
pub struct ShardRestoreStat {
    pub shard: ShardId,
    pub source: usize,
    pub target: usize,
    pub bytes: u64,
    pub wall_s: f64,
}

impl ShardRestoreStat {
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("pp", self.shard.pp)
            .set("tp", self.shard.tp)
            .set("zero", self.shard.zero)
            .set("source", self.source)
            .set("target", self.target)
            .set("bytes", self.bytes)
            .set("wall_s", self.wall_s);
        o
    }
}

/// One failure + recovery episode, timed the way the paper's Tab. III
/// reports it.
#[derive(Debug, Clone)]
pub struct RecoveryRecord {
    pub mode: RecoveryMode,
    pub failed_ranks: Vec<usize>,
    pub kind: FailureKind,
    pub via_device_plugin: bool,
    /// Step the failure interrupted.
    pub failed_at_step: u64,
    /// Step training resumed from (i or i+1 for Flash; checkpoint step
    /// for vanilla).
    pub resume_step: u64,
    /// Completed optimizer steps discarded by the rollback (0 or more;
    /// Flash guarantees 0 — only the in-flight step is redone).
    pub lost_steps: u64,
    /// Failure occurrence -> controller aware.
    pub detection_s: f64,
    /// True when `detection_s` was *measured* on the live heartbeat
    /// plane (wall clock from last good heartbeat to detection,
    /// DESIGN.md §10); false when it fell back to the in-process
    /// boards' ground-truth death stamps.
    pub detection_measured: bool,
    /// Controller aware -> all workers training again.
    pub restart_s: f64,
    /// Portion of restart spent in replica/checkpoint state transfer.
    pub restore_s: f64,
    /// Portion of restart spent rebuilding communication groups over
    /// the live TCP plane (0 when the rebuild plane is disabled, and
    /// for vanilla recoveries, which re-establish from scratch).
    pub rebuild_s: f64,
    pub total_s: f64,
    /// Per-shard streaming transfers of this episode (empty for
    /// vanilla recoveries and checkpoint fallbacks).
    pub shard_restores: Vec<ShardRestoreStat>,
}

impl RecoveryRecord {
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("mode", self.mode.name())
            .set(
                "failed_ranks",
                Json::Array(self.failed_ranks.iter().map(|r| Json::from(*r)).collect()),
            )
            .set("kind", self.kind.name())
            .set("via_device_plugin", self.via_device_plugin)
            .set("failed_at_step", self.failed_at_step)
            .set("resume_step", self.resume_step)
            .set("lost_steps", self.lost_steps)
            .set("detection_s", self.detection_s)
            .set("detection_measured", self.detection_measured)
            .set("restart_s", self.restart_s)
            .set("restore_s", self.restore_s)
            .set("rebuild_s", self.rebuild_s)
            .set("total_s", self.total_s)
            .set(
                "shard_restores",
                Json::Array(self.shard_restores.iter().map(|s| s.to_json()).collect()),
            );
        o
    }
}

/// Outcome of one training run under the controller.
#[derive(Debug, Default)]
pub struct RunReport {
    /// (step, mean loss across DP ranks reporting that step).
    pub losses: Vec<(u64, f32)>,
    pub recoveries: Vec<RecoveryRecord>,
    pub final_step: u64,
    pub wall_s: f64,
    pub checkpoints_taken: usize,
    /// Total k0 stall time across all checkpoints.
    pub checkpoint_stall_s: f64,
    /// Max |param| divergence across DP ranks at the end (0 == bitwise
    /// consistent replicas).
    pub final_param_divergence: f32,
}

impl RunReport {
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("final_step", self.final_step)
            .set("wall_s", self.wall_s)
            .set("checkpoints_taken", self.checkpoints_taken)
            .set("checkpoint_stall_s", self.checkpoint_stall_s)
            .set("final_param_divergence", self.final_param_divergence as f64)
            .set(
                "recoveries",
                Json::Array(self.recoveries.iter().map(|r| r.to_json()).collect()),
            )
            .set(
                "losses",
                Json::Array(
                    self.losses
                        .iter()
                        .map(|(s, l)| {
                            let mut e = Json::object();
                            e.set("step", *s).set("loss", *l as f64);
                            e
                        })
                        .collect(),
                ),
            );
        o
    }

    /// Loss at or nearest-after `step` (test helper for continuity
    /// checks around recoveries).
    pub fn loss_at(&self, step: u64) -> Option<f32> {
        self.losses
            .iter()
            .find(|(s, _)| *s >= step)
            .map(|(_, l)| *l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_serializes() {
        let r = RecoveryRecord {
            mode: RecoveryMode::Flash,
            failed_ranks: vec![1],
            kind: FailureKind::Network,
            via_device_plugin: true,
            failed_at_step: 10,
            resume_step: 10,
            lost_steps: 0,
            detection_s: 0.2,
            detection_measured: true,
            restart_s: 1.1,
            restore_s: 0.3,
            rebuild_s: 0.1,
            total_s: 1.3,
            shard_restores: vec![ShardRestoreStat {
                shard: ShardId { pp: 0, tp: 0, zero: 1 },
                source: 3,
                target: 1,
                bytes: 4096,
                wall_s: 0.05,
            }],
        };
        let j = r.to_json();
        assert_eq!(j.get("mode").as_str(), Some("flash"));
        assert_eq!(j.get("lost_steps").as_i64(), Some(0));
        assert_eq!(j.get("detection_measured").as_bool(), Some(true));
        assert_eq!(j.get("rebuild_s").as_f64(), Some(0.1));
        let sr = j.get("shard_restores").idx(0);
        assert_eq!(sr.get("source").as_usize(), Some(3));
        assert_eq!(sr.get("bytes").as_i64(), Some(4096));
    }

    #[test]
    fn report_loss_lookup() {
        let mut rep = RunReport::default();
        rep.losses = vec![(1, 5.0), (2, 4.5), (4, 4.0)];
        assert_eq!(rep.loss_at(2), Some(4.5));
        assert_eq!(rep.loss_at(3), Some(4.0));
        assert_eq!(rep.loss_at(9), None);
    }
}
