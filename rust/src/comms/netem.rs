//! In-process network impairment (netem) for the live plane
//! (DESIGN.md §15): per-link delay, jitter, loss, bandwidth caps, and
//! asymmetric partitions injected *under* the pluggable [`Link`] layer
//! with zero external crates and zero kernel privileges.
//!
//! Two injection points cover both sides of every wire protocol:
//!
//! * [`NetemDialer`] wraps the links a client dials in an
//!   [`ImpairedLink`] — store sessions, heartbeat emitters, endpoint
//!   discovery, and state-stream fetches all pay the configured
//!   impairment without any protocol change.
//! * [`NetemProxy`] fronts a listener (the reactor's accept path) with
//!   an in-process TCP forwarder whose pump threads shape each
//!   direction — the server's epoll core never knows it is behind a
//!   degraded link.
//!
//! Impairments are *timing-only*: bytes are never reordered, torn, or
//! altered, so wire format and op accounting stay bit-identical and
//! every §8/§10/§13 assertion runs unchanged over an impaired path.
//! Loss is modelled as per-MTU-chunk retransmission delay (geometric
//! RTO backoff, like TCP over a lossy path), bandwidth as a
//! serialization clock, and partitions as swallowed or stalled traffic
//! that heals when the runtime-mutable [`NetemMap`] rule changes.

use super::link::{Dialer, DirectDialer, Link};
use crate::util::Rng;
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Ethernet-ish MTU: the unit of simulated loss.
const MTU: usize = 1500;
/// Floor/ceiling for the simulated retransmission timeout.
const RTO_FLOOR: Duration = Duration::from_millis(5);
const RTO_CEIL: Duration = Duration::from_millis(500);
/// Upper bound on one shaping charge, so a huge transfer over a lossy
/// link degrades instead of freezing the plane. Public because it is
/// also the deterministic worst-case arrival lag impaired campaigns
/// scale their lease budgets from: one request/response pair can trail
/// its predecessor by at most two charges (egress + ingress).
pub const MAX_CHARGE: Duration = Duration::from_secs(2);
/// Poll period while a partitioned direction stalls.
const PARTITION_POLL: Duration = Duration::from_millis(1);
/// Safety cap for a partition stall when the caller set no read
/// deadline — a campaign that never heals surfaces as a timeout, not
/// a hang.
const PARTITION_CAP: Duration = Duration::from_secs(30);

/// Which direction(s) of a link a partition severs, from the dialing
/// (client) side's point of view.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Partition {
    #[default]
    None,
    /// Client -> server traffic is lost; replies that were already in
    /// flight still arrive.
    Egress,
    /// Server -> client traffic stalls; requests still arrive.
    Ingress,
    /// Full bidirectional partition.
    Both,
}

impl Partition {
    pub fn name(&self) -> &'static str {
        match self {
            Partition::None => "none",
            Partition::Egress => "egress",
            Partition::Ingress => "ingress",
            Partition::Both => "both",
        }
    }

    pub fn parse(s: &str) -> Option<Partition> {
        match s {
            "none" => Some(Partition::None),
            "egress" => Some(Partition::Egress),
            "ingress" => Some(Partition::Ingress),
            "both" => Some(Partition::Both),
            _ => None,
        }
    }

    fn blocks_egress(&self) -> bool {
        matches!(self, Partition::Egress | Partition::Both)
    }

    fn severed(&self) -> bool {
        !matches!(self, Partition::None)
    }
}

/// Per-link impairment parameters. `delay_ms` is the one-way latency
/// charged in *each* direction, so a link's RTT is `2 * delay_ms`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkPolicy {
    pub delay_ms: f64,
    /// Uniform jitter amplitude: each latency charge draws from
    /// `delay_ms ± jitter_ms` (clamped at zero).
    pub jitter_ms: f64,
    /// Per-MTU-chunk loss probability in [0, 1], charged as
    /// geometric-backoff retransmission delay.
    pub loss: f64,
    /// Serialization bandwidth cap, kilobits per second.
    pub rate_kbps: Option<f64>,
    pub partition: Partition,
}

impl Default for LinkPolicy {
    fn default() -> Self {
        LinkPolicy {
            delay_ms: 0.0,
            jitter_ms: 0.0,
            loss: 0.0,
            rate_kbps: None,
            partition: Partition::None,
        }
    }
}

impl LinkPolicy {
    /// A symmetric fixed-latency link (`ms` one way per direction).
    pub fn delay(ms: f64) -> Self {
        LinkPolicy { delay_ms: ms, ..Default::default() }
    }

    /// A lossy link with no added base latency.
    pub fn lossy(loss: f64) -> Self {
        LinkPolicy { loss, ..Default::default() }
    }

    /// A severed link.
    pub fn partitioned(p: Partition) -> Self {
        LinkPolicy { partition: p, ..Default::default() }
    }

    /// A cross-region WAN profile: latency + jitter + light loss.
    pub fn wan(delay_ms: f64, jitter_ms: f64, loss: f64) -> Self {
        LinkPolicy { delay_ms, jitter_ms, loss, ..Default::default() }
    }

    pub fn is_noop(&self) -> bool {
        self.delay_ms <= 0.0
            && self.jitter_ms <= 0.0
            && self.loss <= 0.0
            && self.rate_kbps.is_none()
            && self.partition == Partition::None
    }

    /// Round-trip time implied by the base delay.
    pub fn rtt(&self) -> Duration {
        Duration::from_secs_f64((self.delay_ms * 2.0 / 1000.0).max(0.0))
    }

    /// Reject nonsensical parameters (negative delays, loss outside
    /// [0, 1], non-positive rate caps).
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.loss) {
            return Err(format!("netem loss {} outside [0, 1]", self.loss));
        }
        if self.delay_ms < 0.0 || self.jitter_ms < 0.0 {
            return Err("netem delay/jitter must be >= 0".to_string());
        }
        if !self.delay_ms.is_finite() || !self.jitter_ms.is_finite() {
            return Err("netem delay/jitter must be finite".to_string());
        }
        if let Some(r) = self.rate_kbps {
            if r <= 0.0 || !r.is_finite() {
                return Err(format!("netem rate_kbps {r} must be > 0"));
            }
        }
        Ok(())
    }
}

/// Runtime-mutable per-destination impairment rules: campaigns mutate
/// the map mid-run (e.g. to heal a partition) and every link dialed
/// through it — including ones already established — observes the new
/// policy on its next operation.
#[derive(Debug, Default)]
pub struct NetemMap {
    rules: Mutex<Rules>,
    seed: AtomicU64,
}

#[derive(Debug, Default)]
struct Rules {
    default: LinkPolicy,
    per_addr: HashMap<SocketAddr, LinkPolicy>,
    /// Most-specific tier: rules keyed by (source label, destination).
    /// A labeled dial (`Dialer::dial_from`) matches here first, so one
    /// traffic class — e.g. the replication shipper's `"repl"` links —
    /// can be shaped independently of everything else hitting the same
    /// destination address.
    per_pair: HashMap<(String, SocketAddr), LinkPolicy>,
}

impl NetemMap {
    pub fn new(default: LinkPolicy) -> Arc<NetemMap> {
        Arc::new(NetemMap {
            rules: Mutex::new(Rules {
                default,
                per_addr: HashMap::new(),
                per_pair: HashMap::new(),
            }),
            seed: AtomicU64::new(0x6e65_7465),
        })
    }

    pub fn set_default(&self, p: LinkPolicy) {
        self.rules.lock().unwrap().default = p;
    }

    /// Install (or replace) the rule for one destination.
    pub fn set(&self, addr: SocketAddr, p: LinkPolicy) {
        self.rules.lock().unwrap().per_addr.insert(addr, p);
    }

    /// Install (or replace) the rule for one (source label, dst) pair.
    /// Pair rules are the most specific tier: a link dialed with that
    /// label (`Dialer::dial_from`) matches them before any per-address
    /// or default rule, while unlabeled traffic to the same address is
    /// untouched.
    pub fn set_pair(&self, src: &str, addr: SocketAddr, p: LinkPolicy) {
        self.rules
            .lock()
            .unwrap()
            .per_pair
            .insert((src.to_string(), addr), p);
    }

    pub fn policy_for(&self, addr: SocketAddr) -> LinkPolicy {
        self.policy_for_pair(None, addr)
    }

    /// Three-tier lookup: (src, dst) pair rule, then per-destination
    /// rule, then the map default.
    pub fn policy_for_pair(&self, src: Option<&str>, addr: SocketAddr) -> LinkPolicy {
        let rules = self.rules.lock().unwrap();
        if let Some(s) = src {
            if let Some(p) = rules.per_pair.get(&(s.to_string(), addr)) {
                return *p;
            }
        }
        rules.per_addr.get(&addr).copied().unwrap_or(rules.default)
    }

    /// Clear every partition (all other impairments stay): the
    /// campaign's "partition heals" event.
    pub fn heal_partitions(&self) {
        let mut rules = self.rules.lock().unwrap();
        rules.default.partition = Partition::None;
        for p in rules.per_addr.values_mut() {
            p.partition = Partition::None;
        }
        for p in rules.per_pair.values_mut() {
            p.partition = Partition::None;
        }
    }

    fn next_seed(&self) -> u64 {
        self.seed.fetch_add(0x9E37_79B9, Ordering::Relaxed)
    }
}

/// One direction's shaping state: a deterministic RNG for jitter/loss
/// draws plus the serialization clock for the bandwidth cap.
#[derive(Debug)]
struct Shaper {
    rng: Rng,
    next_free: Instant,
}

impl Shaper {
    fn new(seed: u64) -> Shaper {
        Shaper { rng: Rng::new(seed), next_free: Instant::now() }
    }

    /// Compute and sleep the delay a transfer of `n` bytes pays in
    /// this direction. Latency (+jitter) is charged only when
    /// `new_burst` — once per request/response leg, not per syscall —
    /// while loss and serialization are charged per byte chunk.
    fn charge(&mut self, p: &LinkPolicy, n: usize, new_burst: bool) {
        if p.is_noop() || n == 0 {
            return;
        }
        let mut pay = Duration::ZERO;
        if new_burst && p.delay_ms > 0.0 {
            let lo = (p.delay_ms - p.jitter_ms).max(0.0);
            let hi = p.delay_ms + p.jitter_ms;
            let ms =
                if hi > lo { self.rng.range_f64(lo, hi) } else { p.delay_ms };
            pay += Duration::from_secs_f64(ms / 1000.0);
        }
        if p.loss > 0.0 {
            let rto_base = p.rtt().max(RTO_FLOOR * 2) / 2;
            for _ in 0..n.div_ceil(MTU) {
                let mut rto = rto_base.max(RTO_FLOOR);
                while self.rng.bool(p.loss) && pay < MAX_CHARGE {
                    pay += rto;
                    rto = (rto * 2).min(RTO_CEIL);
                }
            }
        }
        let now = Instant::now();
        if let Some(kbps) = p.rate_kbps {
            let wire_s = (n as f64 * 8.0) / (kbps * 1000.0);
            let base = if self.next_free > now { self.next_free } else { now };
            self.next_free = base + Duration::from_secs_f64(wire_s);
            pay += self.next_free - now;
        }
        let pay = pay.min(MAX_CHARGE);
        if pay > Duration::ZERO {
            std::thread::sleep(pay);
        }
    }
}

/// A [`Link`] whose traffic pays the [`NetemMap`] policy for its peer:
/// writes charge the egress direction, reads the ingress direction,
/// and partitions swallow writes / stall reads until the map heals.
pub struct ImpairedLink {
    inner: Box<dyn Link>,
    map: Arc<NetemMap>,
    peer: SocketAddr,
    /// Source label the link was dialed under (`Dialer::dial_from`),
    /// consulted first in the policy lookup so per-pair rules apply.
    src: Option<String>,
    egress: Shaper,
    ingress: Shaper,
    /// Set on every write, cleared by the first read after it: that
    /// read is the reply leg of an RPC and pays the ingress latency.
    awaiting_reply: bool,
    read_deadline: Mutex<Option<Duration>>,
}

impl ImpairedLink {
    pub fn new(inner: Box<dyn Link>, map: Arc<NetemMap>, peer: SocketAddr) -> ImpairedLink {
        Self::labeled(inner, map, peer, None)
    }

    /// An impaired link carrying a source label: its every policy
    /// lookup tries the `(src, peer)` pair rule before falling back to
    /// the per-address and default tiers.
    pub fn labeled(
        inner: Box<dyn Link>,
        map: Arc<NetemMap>,
        peer: SocketAddr,
        src: Option<String>,
    ) -> ImpairedLink {
        let seed = map.next_seed();
        ImpairedLink {
            inner,
            map,
            peer,
            src,
            egress: Shaper::new(seed),
            ingress: Shaper::new(seed ^ 0x5DEE_CE66),
            // the first read of a dialed link (e.g. a state-stream
            // fetch) crosses the wire once and pays latency
            awaiting_reply: true,
            read_deadline: Mutex::new(None),
        }
    }

    fn policy(&self) -> LinkPolicy {
        self.map.policy_for_pair(self.src.as_deref(), self.peer)
    }

    /// Stall while the link is severed; `Ok(())` when the partition
    /// heals, a `TimedOut` error when the read deadline (or the
    /// global safety cap) expires first.
    fn stall_while_severed(&self) -> io::Result<()> {
        let cap =
            self.read_deadline.lock().unwrap().unwrap_or(PARTITION_CAP);
        let deadline = Instant::now() + cap.min(PARTITION_CAP);
        while self.policy().partition.severed() {
            if Instant::now() > deadline {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "netem: link partitioned past the read deadline",
                ));
            }
            std::thread::sleep(PARTITION_POLL);
        }
        Ok(())
    }
}

impl Read for ImpairedLink {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.policy().partition.severed() {
            // either direction severed starves an RPC reply
            self.stall_while_severed()?;
        }
        let n = self.inner.read(buf)?;
        let p = self.policy();
        let burst = self.awaiting_reply;
        self.awaiting_reply = false;
        self.ingress.charge(&p, n, burst);
        Ok(n)
    }
}

impl Write for ImpairedLink {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let p = self.policy();
        if p.partition.blocks_egress() {
            // the frame vanishes on the wire; `comms::wire` always
            // writes whole frames in one call, so nothing tears
            self.awaiting_reply = true;
            return Ok(buf.len());
        }
        self.egress.charge(&p, buf.len(), !self.awaiting_reply);
        self.awaiting_reply = true;
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

impl Link for ImpairedLink {
    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        *self.read_deadline.lock().unwrap() = d;
        self.inner.set_read_timeout(d)
    }

    fn set_nodelay(&self, on: bool) -> io::Result<()> {
        self.inner.set_nodelay(on)
    }

    fn peer_addr(&self) -> io::Result<SocketAddr> {
        self.inner.peer_addr()
    }
}

/// A [`Dialer`] that wraps every dialed link in an [`ImpairedLink`]
/// governed by a shared, runtime-mutable [`NetemMap`]. Connection
/// setup itself pays one RTT, and dialing across a full or egress
/// partition fails like a dropped SYN (timeout).
pub struct NetemDialer {
    inner: Arc<dyn Dialer>,
    map: Arc<NetemMap>,
}

impl NetemDialer {
    pub fn new(map: Arc<NetemMap>) -> NetemDialer {
        NetemDialer { inner: Arc::new(DirectDialer), map }
    }

    /// Impair an arbitrary inner dialer (e.g. to stack policies).
    pub fn over(inner: Arc<dyn Dialer>, map: Arc<NetemMap>) -> NetemDialer {
        NetemDialer { inner, map }
    }

    pub fn map(&self) -> Arc<NetemMap> {
        self.map.clone()
    }

    fn dial_labeled(
        &self,
        src: Option<&str>,
        addr: SocketAddr,
        timeout: Duration,
    ) -> io::Result<Box<dyn Link>> {
        let p = self.map.policy_for_pair(src, addr);
        if p.partition.severed() {
            // SYN or SYN-ACK is lost: burn the caller's patience like
            // a real connect timeout would, bounded for campaigns
            std::thread::sleep(timeout.min(Duration::from_millis(50)));
            return Err(io::Error::new(
                io::ErrorKind::TimedOut,
                "netem: destination partitioned",
            ));
        }
        let rtt = p.rtt();
        if rtt >= timeout {
            std::thread::sleep(timeout);
            return Err(io::Error::new(
                io::ErrorKind::TimedOut,
                "netem: connect timeout below the link RTT",
            ));
        }
        std::thread::sleep(rtt);
        let inner = self.inner.dial(addr, timeout - rtt)?;
        Ok(Box::new(ImpairedLink::labeled(
            inner,
            self.map.clone(),
            addr,
            src.map(String::from),
        )))
    }
}

impl Dialer for NetemDialer {
    fn dial(&self, addr: SocketAddr, timeout: Duration) -> io::Result<Box<dyn Link>> {
        self.dial_labeled(None, addr, timeout)
    }

    /// Labeled dialing keeps the source tag on the resulting link, so
    /// per-pair rules installed later (mid-campaign) still catch it.
    fn dial_from(
        &self,
        src: &str,
        addr: SocketAddr,
        timeout: Duration,
    ) -> io::Result<Box<dyn Link>> {
        self.dial_labeled(Some(src), addr, timeout)
    }

    fn name(&self) -> &'static str {
        "netem"
    }
}

/// An in-process impairment proxy fronting one upstream listener: the
/// server side of the netem story. Accepted connections are piped to
/// the upstream address by two pump threads, each shaping its
/// direction with the (runtime-mutable) policy — so the reactor's
/// epoll accept path is exercised behind a degraded link without a
/// single line of reactor change. During a partition the affected
/// pump *stalls* (bytes are delayed, never dropped mid-stream), which
/// keeps arbitrary multi-write protocols intact across a heal.
pub struct NetemProxy {
    addr: SocketAddr,
    policy: Arc<Mutex<LinkPolicy>>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl NetemProxy {
    pub fn start(upstream: SocketAddr, policy: LinkPolicy) -> io::Result<NetemProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let policy = Arc::new(Mutex::new(policy));
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let t = {
            let (policy, stop, conns) = (policy.clone(), stop.clone(), conns.clone());
            std::thread::Builder::new()
                .name("netem-proxy".into())
                .spawn(move || {
                    let mut seed = 0x70_726f_7879u64;
                    while !stop.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((client, _)) => {
                                seed = seed.wrapping_add(0x9E37_79B9);
                                if let Err(e) = Self::splice(
                                    client, upstream, &policy, &stop, &conns, seed,
                                ) {
                                    crate::telemetry::log::warn("netem", || {
                                        format!("proxy splice failed: {e}")
                                    });
                                }
                            }
                            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(1));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn netem proxy thread")
        };
        Ok(NetemProxy {
            addr,
            policy,
            stop,
            conns,
            accept_thread: Some(t),
        })
    }

    /// Address clients dial instead of the upstream's.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Swap the impairment live; in-flight connections pick the new
    /// policy up on their next chunk.
    pub fn set_policy(&self, p: LinkPolicy) {
        *self.policy.lock().unwrap() = p;
    }

    fn splice(
        client: TcpStream,
        upstream: SocketAddr,
        policy: &Arc<Mutex<LinkPolicy>>,
        stop: &Arc<AtomicBool>,
        conns: &Arc<Mutex<Vec<TcpStream>>>,
        seed: u64,
    ) -> io::Result<()> {
        // connection setup over the impaired link pays one RTT
        let rtt = policy.lock().unwrap().rtt();
        if rtt > Duration::ZERO {
            std::thread::sleep(rtt);
        }
        let server = TcpStream::connect_timeout(&upstream, Duration::from_secs(10))?;
        client.set_nodelay(true).ok();
        server.set_nodelay(true).ok();
        {
            let mut held = conns.lock().unwrap();
            held.push(client.try_clone()?);
            held.push(server.try_clone()?);
        }
        // client -> server shapes the egress direction, server ->
        // client the ingress one; each pump checks the live policy
        // per chunk so partitions heal mid-connection
        Self::pump(client.try_clone()?, server.try_clone()?, policy.clone(), stop.clone(), seed, true);
        Self::pump(server, client, policy.clone(), stop.clone(), seed ^ 0xFF, false);
        Ok(())
    }

    fn pump(
        mut from: TcpStream,
        mut to: TcpStream,
        policy: Arc<Mutex<LinkPolicy>>,
        stop: Arc<AtomicBool>,
        seed: u64,
        egress: bool,
    ) {
        std::thread::Builder::new()
            .name(if egress { "netem-egress" } else { "netem-ingress" }.into())
            .spawn(move || {
                from.set_read_timeout(Some(Duration::from_millis(50))).ok();
                let mut shaper = Shaper::new(seed);
                let mut buf = vec![0u8; 16 * 1024];
                let mut last_forward = Instant::now() - Duration::from_secs(1);
                loop {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let n = match from.read(&mut buf) {
                        Ok(0) => break,
                        Ok(n) => n,
                        Err(e)
                            if e.kind() == io::ErrorKind::WouldBlock
                                || e.kind() == io::ErrorKind::TimedOut =>
                        {
                            continue
                        }
                        Err(_) => break,
                    };
                    // a severed direction stalls: the bytes wait (in
                    // order) for the heal, exactly like a partitioned
                    // path where TCP keeps retransmitting
                    loop {
                        let p = *policy.lock().unwrap();
                        let cut = if egress {
                            p.partition.blocks_egress()
                        } else {
                            matches!(p.partition, Partition::Ingress | Partition::Both)
                        };
                        if !cut || stop.load(Ordering::Relaxed) {
                            break;
                        }
                        std::thread::sleep(PARTITION_POLL);
                    }
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let p = *policy.lock().unwrap();
                    // new burst = the pipe was idle long enough that
                    // this chunk starts a fresh request/response leg
                    let new_burst =
                        last_forward.elapsed() > Duration::from_millis(1).max(p.rtt() / 4);
                    shaper.charge(&p, n, new_burst);
                    if to.write_all(&buf[..n]).is_err() {
                        break;
                    }
                    last_forward = Instant::now();
                }
                from.shutdown(Shutdown::Both).ok();
                to.shutdown(Shutdown::Both).ok();
            })
            .expect("spawn netem pump thread");
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for c in self.conns.lock().unwrap().drain(..) {
            c.shutdown(Shutdown::Both).ok();
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for NetemProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpListener;

    fn echo_server() -> (SocketAddr, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            // one connection per test server
            if let Ok((mut s, _)) = listener.accept() {
                let mut buf = vec![0u8; 64 * 1024];
                loop {
                    match s.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            if s.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
            }
        });
        (addr, t)
    }

    #[test]
    fn noop_policy_is_bit_transparent() {
        let (addr, server) = echo_server();
        let map = NetemMap::new(LinkPolicy::default());
        let mut link =
            NetemDialer::new(map).dial(addr, Duration::from_secs(5)).unwrap();
        let payload: Vec<u8> =
            (0..20_000u32).map(|i| (i.wrapping_mul(2654435761) >> 13) as u8).collect();
        link.write_all(&payload).unwrap();
        let mut back = vec![0u8; payload.len()];
        link.read_exact(&mut back).unwrap();
        assert_eq!(back, payload, "impaired path must never alter bytes");
        drop(link);
        server.join().unwrap();
    }

    #[test]
    fn delay_policy_charges_rtt_per_roundtrip() {
        let (addr, server) = echo_server();
        let map = NetemMap::new(LinkPolicy::delay(15.0));
        let t0 = Instant::now();
        let mut link =
            NetemDialer::new(map).dial(addr, Duration::from_secs(5)).unwrap();
        let connect_elapsed = t0.elapsed();
        assert!(
            connect_elapsed >= Duration::from_millis(30),
            "connect must pay one RTT, took {connect_elapsed:?}"
        );
        let t1 = Instant::now();
        link.write_all(b"ping").unwrap();
        let mut back = [0u8; 4];
        link.read_exact(&mut back).unwrap();
        let rt = t1.elapsed();
        assert!(rt >= Duration::from_millis(30), "roundtrip {rt:?} below RTT");
        assert!(rt < Duration::from_secs(2), "roundtrip {rt:?} implausibly slow");
        drop(link);
        server.join().unwrap();
    }

    #[test]
    fn bandwidth_cap_serializes_large_writes() {
        let (addr, server) = echo_server();
        let policy = LinkPolicy {
            rate_kbps: Some(640.0), // 8 KiB ≈ 102 ms on the wire
            ..Default::default()
        };
        let map = NetemMap::new(policy);
        let mut link =
            NetemDialer::new(map).dial(addr, Duration::from_secs(5)).unwrap();
        let payload = vec![7u8; 8 * 1024];
        let t0 = Instant::now();
        link.write_all(&payload).unwrap();
        let sent = t0.elapsed();
        assert!(
            sent >= Duration::from_millis(80),
            "8KiB at 640kbps must take ~100ms, took {sent:?}"
        );
        let mut back = vec![0u8; payload.len()];
        link.read_exact(&mut back).unwrap();
        assert_eq!(back, payload);
        drop(link);
        server.join().unwrap();
    }

    #[test]
    fn lossy_link_pays_bounded_retransmit_penalty() {
        let (addr, server) = echo_server();
        let map = NetemMap::new(LinkPolicy::lossy(0.3));
        let mut link =
            NetemDialer::new(map).dial(addr, Duration::from_secs(5)).unwrap();
        let t0 = Instant::now();
        for _ in 0..20 {
            link.write_all(b"beat").unwrap();
            let mut back = [0u8; 4];
            link.read_exact(&mut back).unwrap();
        }
        let elapsed = t0.elapsed();
        // 40 chunk draws at 30% loss: some retransmits are all but
        // certain, but the penalty must stay bounded
        assert!(elapsed < Duration::from_secs(10), "loss penalty unbounded: {elapsed:?}");
        drop(link);
        server.join().unwrap();
    }

    #[test]
    fn partition_heals_through_the_live_map() {
        let (addr, server) = echo_server();
        let map = NetemMap::new(LinkPolicy::default());
        let dialer = NetemDialer::new(map.clone());
        let mut link = dialer.dial(addr, Duration::from_secs(5)).unwrap();
        link.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // sever, then heal from another thread mid-read
        map.set(addr, LinkPolicy::partitioned(Partition::Both));
        let healer_map = map.clone();
        let healer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            healer_map.heal_partitions();
        });
        let t0 = Instant::now();
        link.write_all(b"ping").unwrap(); // swallowed by the partition
        let mut back = [0u8; 4];
        // the swallowed frame never echoes; resend after the stall
        // clears and the reply must arrive intact
        let err = {
            link.set_read_timeout(Some(Duration::from_millis(120))).unwrap();
            link.read_exact(&mut back)
        };
        assert!(err.is_err(), "a fully swallowed frame cannot echo");
        healer.join().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(60), "read must stall until heal");
        link.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        link.write_all(b"ping").unwrap();
        link.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"ping", "healed link must carry frames intact");
        drop(link);
        server.join().unwrap();
    }

    #[test]
    fn dial_into_a_partition_times_out() {
        let (addr, server) = echo_server();
        let map = NetemMap::new(LinkPolicy::partitioned(Partition::Both));
        let err = NetemDialer::new(map.clone())
            .dial(addr, Duration::from_secs(1))
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
        map.heal_partitions();
        let link = NetemDialer::new(map).dial(addr, Duration::from_secs(1)).unwrap();
        drop(link);
        server.join().unwrap();
    }

    #[test]
    fn proxy_is_transparent_and_shapes_latency() {
        let (addr, server) = echo_server();
        let mut proxy = NetemProxy::start(addr, LinkPolicy::default()).unwrap();
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let payload: Vec<u8> = (0..50_000u32).map(|i| (i % 251) as u8).collect();
        s.write_all(&payload).unwrap();
        let mut back = vec![0u8; payload.len()];
        s.read_exact(&mut back).unwrap();
        assert_eq!(back, payload, "proxied bytes must be identical");

        // flip latency on live and measure a shaped roundtrip
        proxy.set_policy(LinkPolicy::delay(10.0));
        std::thread::sleep(Duration::from_millis(20)); // drain idle window
        let t0 = Instant::now();
        s.write_all(b"ping").unwrap();
        let mut b = [0u8; 4];
        s.read_exact(&mut b).unwrap();
        let rt = t0.elapsed();
        assert!(rt >= Duration::from_millis(18), "proxied RTT {rt:?} below 2x delay");
        drop(s);
        proxy.shutdown();
        server.join().unwrap();
    }

    /// Like [`echo_server`] but serves up to `conns` connections, each
    /// on its own thread — pair tests drive labeled and unlabeled links
    /// to the *same* destination concurrently.
    fn echo_server_multi(conns: usize) -> (SocketAddr, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut handles = Vec::new();
            for _ in 0..conns {
                let Ok((mut s, _)) = listener.accept() else { break };
                handles.push(std::thread::spawn(move || {
                    let mut buf = vec![0u8; 64 * 1024];
                    loop {
                        match s.read(&mut buf) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => {
                                if s.write_all(&buf[..n]).is_err() {
                                    break;
                                }
                            }
                        }
                    }
                }));
            }
            for h in handles {
                h.join().ok();
            }
        });
        (addr, t)
    }

    #[test]
    fn pair_rule_shapes_only_the_labeled_traffic_class() {
        let (addr, server) = echo_server_multi(2);
        let map = NetemMap::new(LinkPolicy::default());
        let dialer = NetemDialer::new(map.clone());
        // sever only the replication pair to this destination
        map.set_pair("repl", addr, LinkPolicy::partitioned(Partition::Both));
        let err = dialer
            .dial_from("repl", addr, Duration::from_secs(1))
            .unwrap_err();
        assert_eq!(
            err.kind(),
            io::ErrorKind::TimedOut,
            "labeled dial must hit the pair partition"
        );
        // unlabeled client traffic to the same address is untouched
        let mut client = dialer.dial(addr, Duration::from_secs(5)).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        client.write_all(b"ping").unwrap();
        let mut back = [0u8; 4];
        client.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"ping");
        // heal: the labeled class reconnects and carries frames intact
        map.heal_partitions();
        let mut repl =
            dialer.dial_from("repl", addr, Duration::from_secs(5)).unwrap();
        repl.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        repl.write_all(b"ship").unwrap();
        let mut b = [0u8; 4];
        repl.read_exact(&mut b).unwrap();
        assert_eq!(&b, b"ship");
        drop(client);
        drop(repl);
        server.join().unwrap();
    }

    #[test]
    fn pair_rule_is_live_and_most_specific() {
        let (addr, server) = echo_server_multi(2);
        let map = NetemMap::new(LinkPolicy::default());
        let dialer = NetemDialer::new(map.clone());
        let mut repl =
            dialer.dial_from("repl", addr, Duration::from_secs(5)).unwrap();
        let mut client = dialer.dial(addr, Duration::from_secs(5)).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // a pair rule installed *after* establishment catches the
        // already-dialed labeled link...
        map.set_pair("repl", addr, LinkPolicy::partitioned(Partition::Both));
        repl.set_read_timeout(Some(Duration::from_millis(150))).unwrap();
        repl.write_all(b"lost").unwrap(); // swallowed by the pair cut
        let mut b = [0u8; 4];
        assert!(repl.read_exact(&mut b).is_err(), "pair-severed link cannot echo");
        // ...while the unlabeled link to the same destination flows
        client.write_all(b"ping").unwrap();
        client.read_exact(&mut b).unwrap();
        assert_eq!(&b, b"ping");
        // per-pair outranks per-addr: a healthy pair rule punches
        // through an address-wide partition
        map.set_pair("repl", addr, LinkPolicy::default());
        map.set(addr, LinkPolicy::partitioned(Partition::Both));
        repl.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        repl.write_all(b"pong").unwrap();
        repl.read_exact(&mut b).unwrap();
        assert_eq!(&b, b"pong", "healthy pair rule must outrank the address cut");
        client.set_read_timeout(Some(Duration::from_millis(150))).unwrap();
        client.write_all(b"gone").unwrap(); // swallowed by the address cut
        assert!(
            client.read_exact(&mut b).is_err(),
            "address-wide cut must still sever unlabeled traffic"
        );
        drop(repl);
        drop(client);
        server.join().unwrap();
    }

    #[test]
    fn policy_validation_rejects_nonsense() {
        assert!(LinkPolicy::lossy(1.5).validate().is_err());
        assert!(LinkPolicy { delay_ms: -1.0, ..Default::default() }.validate().is_err());
        assert!(
            LinkPolicy { rate_kbps: Some(0.0), ..Default::default() }.validate().is_err()
        );
        assert!(LinkPolicy::wan(25.0, 5.0, 0.01).validate().is_ok());
    }
}
