//! Store data-plane throughput sweep (DESIGN.md §11) — the
//! `store-bench` CLI / `benches/store_throughput.rs` target.
//!
//! Measures the redesigned store (lock stripes, per-key waiter
//! parking, `Arc<[u8]>` values, pooled workers) under a mixed-opcode
//! workload at 64 → 8192 *simulated clients*, in two client modes:
//!
//! * **batched** — each connection pipelines its simulated clients'
//!   ops as `Batch` frames (the §8 survivor re-key / node-agent
//!   coalescing pattern): ops per round-trip is the whole point of
//!   the data plane;
//! * **serial** — the same ops, one per round-trip: the old client
//!   model, kept as the in-tree baseline the acceptance criterion
//!   compares against.
//!
//! A third *replicated* column re-runs the batched cell against a
//! quorum-replicated plane (primary + `replicas` log-shipping
//! followers, DESIGN.md §13): every mutating op is acked only after
//! quorum append, and the acceptance criterion bounds the replicated
//! per-op p50 at ≤ 1.5x the un-replicated batched p50.
//!
//! Scale model (same as the rendezvous and detection sweeps): the
//! simulated-client count drives keys, counters, heartbeat ranks, and
//! total op volume at full scale, while real sockets are bounded by
//! `connections` driver threads — exactly the coalescing a per-node
//! agent performs for its local ranks. Column 0 (`p50 us/op`, batched)
//! is what CI's bench gate compares against the committed baseline;
//! the bench target additionally asserts batched throughput ≥ 2x
//! serial at 4096 clients and flat-at-scale per-op p50.

use super::replication::ReplicaSet;
use super::tcp_store::{TcpStoreClient, TcpStoreServer};
use super::wire::{Request, Response};
use crate::metrics::bench::BenchReport;
use crate::metrics::Histogram;
use crate::telemetry::{trace, TraceCtx};
use anyhow::{anyhow, bail, ensure, Result};
use std::net::SocketAddr;
use std::time::Instant;

/// Ops per `Batch` frame in batched mode — large enough to amortise
/// the round-trip, small enough to keep frames in the tens of KB.
const BATCH_OPS: usize = 128;

/// Mixed ops one simulated client issues per repeat: set, read back,
/// wait-hit (the parked-wait fast path), a contended counter add, one
/// heartbeat, and a second read.
const MIX_OPS: usize = 6;

/// Configuration for the store throughput sweep.
#[derive(Debug, Clone)]
pub struct StoreSweepConfig {
    /// Simulated client counts (keys/counters/ranks at full scale).
    pub clients: Vec<usize>,
    /// Real TCP connections (== driver threads) the simulated clients
    /// are multiplexed over.
    pub connections: usize,
    /// Repeats of the 6-op mix per simulated client per round.
    pub repeats: usize,
    /// Measured rounds per (scale, mode); one extra warmup round is
    /// discarded.
    pub rounds: u32,
    /// Log-shipping replicas behind the replicated column's primary
    /// (0 degenerates to an un-replicated plane).
    pub replicas: usize,
}

impl Default for StoreSweepConfig {
    fn default() -> Self {
        StoreSweepConfig {
            clients: vec![64, 1024, 4096, 8192],
            connections: 64,
            repeats: 2,
            rounds: 5,
            replicas: 1,
        }
    }
}

/// The 6-op mix for simulated client `id` in round `round`.
fn mix(id: usize, round: u32, out: &mut Vec<Request>) {
    let key = format!("bench/k{id}");
    let value = format!("payload-{id}-{round}-0123456789abcdef").into_bytes();
    out.push(Request::Set { key: key.clone(), value });
    out.push(Request::Get { key: key.clone() });
    // wait on a key this same pipeline just published: exercises the
    // wait path's fast hit (and, in serial mode, a real Wait RTT)
    out.push(Request::Wait { key: key.clone() });
    out.push(Request::Add { key: format!("bench/ctr{}", id % 32), delta: 1 });
    out.push(Request::Heartbeat {
        rank: id as u64,
        incarnation: 1,
        step_tag: round as i64,
        device_code: -1,
    });
    out.push(Request::Get { key });
}

/// What one driver thread reports for one round.
struct DriverOut {
    /// Per-op latency samples (one per frame: frame RTT / ops in it).
    samples: Vec<f64>,
    ops: u64,
    busy_s: f64,
}

fn check_resps(n_sent: usize, resps: &[Response]) -> Result<()> {
    if resps.len() != n_sent {
        bail!("batch executed {} of {n_sent} ops", resps.len());
    }
    Ok(())
}

/// Run one round for one driver thread owning `ids`.
fn drive_round(
    addr: SocketAddr,
    ids: &[usize],
    round: u32,
    repeats: usize,
    batched: bool,
    trace: Option<TraceCtx>,
) -> Result<DriverOut> {
    let mut client = TcpStoreClient::connect(addr)?;
    client.set_trace_ctx(trace);
    let mut reqs: Vec<Request> = Vec::with_capacity(ids.len() * MIX_OPS * repeats);
    for rep in 0..repeats {
        for &id in ids {
            mix(id, round * repeats.max(1) as u32 + rep as u32, &mut reqs);
        }
    }
    let total_ops = reqs.len() as u64;
    let mut samples = Vec::new();
    let t0 = Instant::now();
    if batched {
        let mut iter = reqs.into_iter().peekable();
        while iter.peek().is_some() {
            let chunk: Vec<Request> = iter.by_ref().take(BATCH_OPS).collect();
            let n = chunk.len();
            let t = Instant::now();
            let resps = client.batch(chunk)?;
            samples.push(t.elapsed().as_secs_f64() / n as f64);
            check_resps(n, &resps)?;
        }
    } else {
        for req in reqs {
            let t = Instant::now();
            let _ = client.roundtrip(req)?;
            samples.push(t.elapsed().as_secs_f64());
        }
    }
    Ok(DriverOut { samples, ops: total_ops, busy_s: t0.elapsed().as_secs_f64() })
}

/// Run every round of one (scale, mode) cell on a fresh plain server;
/// returns (per-op histogram, ops/s over the measured rounds).
fn run_cell(
    cfg: &StoreSweepConfig,
    clients: usize,
    batched: bool,
    trace: Option<TraceCtx>,
) -> Result<(Histogram, f64)> {
    let server = TcpStoreServer::start()?;
    run_cell_on(server.addr(), cfg, clients, batched, trace)
}

/// Run every round of one batched cell against a quorum-replicated
/// plane: mutating ops ack only after the primary has shipped them to
/// its `cfg.replicas` followers (DESIGN.md §13).
fn run_replicated_cell(
    cfg: &StoreSweepConfig,
    clients: usize,
) -> Result<(Histogram, f64)> {
    let set = ReplicaSet::start(cfg.replicas)?;
    run_cell_on(set.addr(), cfg, clients, true, None)
}

/// The driver loop of one (scale, mode) cell against an already
/// running store at `addr`.
fn run_cell_on(
    addr: SocketAddr,
    cfg: &StoreSweepConfig,
    clients: usize,
    batched: bool,
    trace: Option<TraceCtx>,
) -> Result<(Histogram, f64)> {
    let conns = cfg.connections.clamp(1, clients);
    // evenly partition simulated client ids over the connections
    let id_sets: Vec<Vec<usize>> = (0..conns)
        .map(|c| (0..clients).filter(|id| id % conns == c).collect())
        .collect();

    let mut hist = Histogram::new();
    let mut measured_ops = 0u64;
    let mut measured_busy = 0.0f64;
    for round in 0..=cfg.rounds {
        let mut handles = Vec::with_capacity(conns);
        for ids in &id_sets {
            let ids = ids.clone();
            let repeats = cfg.repeats.max(1);
            handles.push(std::thread::spawn(move || {
                drive_round(addr, &ids, round, repeats, batched, trace)
            }));
        }
        let mut round_busy = 0.0f64;
        let mut round_ops = 0u64;
        let mut outs = Vec::with_capacity(conns);
        for h in handles {
            outs.push(h.join().expect("driver thread panicked")?);
        }
        if round == 0 {
            continue; // warmup: server pool + allocator settle
        }
        for out in outs {
            round_busy = round_busy.max(out.busy_s);
            round_ops += out.ops;
            for s in out.samples {
                hist.record(s);
            }
        }
        measured_ops += round_ops;
        // rounds are synchronized by join, so the per-round critical
        // path (slowest driver) is what wall-clock throughput pays
        measured_busy += round_busy;
    }
    let ops_per_s = if measured_busy > 0.0 {
        measured_ops as f64 / measured_busy
    } else {
        0.0
    };
    Ok((hist, ops_per_s))
}

/// Run the store throughput sweep. Column 0 (`p50 us/op`, batched
/// mode) is the value CI's bench gate compares against the committed
/// baseline in `ci/BENCH_store_throughput.baseline.json`.
pub fn store_sweep(cfg: &StoreSweepConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new(
        "store_throughput: striped+parked+batched data plane, mixed workload",
        &[
            "p50 us/op",
            "ops/s",
            "serial us/op",
            "serial ops/s",
            "speedup x",
            "conns",
            "repl p50 us/op",
        ],
    );
    for &n in &cfg.clients {
        if n == 0 {
            bail!("sweep needs at least one simulated client");
        }
        let (batched_h, batched_ops) = run_cell(cfg, n, true, None)?;
        let (serial_h, serial_ops) = run_cell(cfg, n, false, None)?;
        let (repl_h, _) = run_replicated_cell(cfg, n)?;
        let speedup = if serial_ops > 0.0 { batched_ops / serial_ops } else { 0.0 };
        report.row(
            format!("n={n}"),
            vec![
                batched_h.p50() * 1e6,
                batched_ops,
                serial_h.p50() * 1e6,
                serial_ops,
                speedup,
                cfg.connections.min(n) as f64,
                repl_h.p50() * 1e6,
            ],
        );
    }
    report.note(format!(
        "{} rounds/cell (+1 warmup), {} x 6-op mix per simulated client \
         (set/get/wait-hit/add/heartbeat/get), {} connections; batched mode \
         pipelines {} ops per frame, serial mode pays one RTT per op; the \
         repl column re-runs the batched cell with {} quorum replica(s) \
         behind the primary",
        cfg.rounds, cfg.repeats, cfg.connections, BATCH_OPS, cfg.replicas
    ));
    report.note(
        "flat-at-scale: per-op p50 stays within 2x from the smallest to the \
         largest client count (striped locks + per-key parking, no global \
         serialization); batched >= 2x serial ops/s at 4096 clients; \
         quorum-replicated p50 <= 1.5x un-replicated batched p50",
    );
    Ok(report)
}

/// The sweep's acceptance properties (ISSUE 5 + ISSUE 7), shared by
/// the bench target and `bench store --assert` (which bench-gate
/// runs): batched ≥ 2x serial ops/s at 4096 clients (or the largest
/// swept scale); batched per-op p50 flat — ≤ 2x from the smallest to
/// the largest scale; and quorum-replicated per-op p50 ≤ 1.5x the
/// un-replicated batched p50 per scale. All with a 5us noise floor
/// for loaded runners.
pub fn check_report(cfg: &StoreSweepConfig, report: &BenchReport) -> Result<()> {
    let (Some(&min_scale), Some(&max_scale)) =
        (cfg.clients.iter().min(), cfg.clients.iter().max())
    else {
        return Ok(());
    };
    let row = |n: usize| {
        report
            .row_values(&format!("n={n}"))
            .ok_or_else(|| anyhow!("missing sweep row n={n}"))
    };
    let compare_at = if cfg.clients.contains(&4096) { 4096 } else { max_scale };
    let speedup = row(compare_at)?[4];
    ensure!(
        speedup >= 2.0,
        "batched plane must be >= 2x serial ops/s at {compare_at} clients \
         (got {speedup:.2}x)"
    );
    let (lo, hi) = (row(min_scale)?[0], row(max_scale)?[0]);
    ensure!(
        hi <= 2.0 * lo + 5.0,
        "store per-op p50 not scale-independent: {hi:.2}us @ {max_scale} vs \
         {lo:.2}us @ {min_scale}"
    );
    for &n in &cfg.clients {
        let r = row(n)?;
        let (plain, repl) = (r[0], r[6]);
        ensure!(
            repl <= 1.5 * plain + 5.0,
            "quorum replication too expensive at n={n}: repl p50 {repl:.2}us \
             vs {:.2}us allowed (1.5x un-replicated {plain:.2}us + 5us floor)",
            1.5 * plain + 5.0
        );
    }
    Ok(())
}

/// Flight-recorder overhead on the batched hot path (DESIGN.md §12):
/// run one batched cell with the recorder off, then again with it on
/// and every frame stamped with a live trace context (16 extra wire
/// bytes per frame + one recorded event per frame server-side), and
/// return `(off_p50, on_p50)` per-op latencies in seconds. The bench
/// target asserts on ≤ 1.05x off plus a small noise floor.
///
/// Toggles (and finally disables) the process-global recorder, so
/// call it only from a single-threaded bench/CLI context — never
/// concurrently with code that records traces.
pub fn telemetry_overhead(cfg: &StoreSweepConfig, clients: usize) -> Result<(f64, f64)> {
    trace::set_recording(false);
    let (off, _) = run_cell(cfg, clients, true, None)?;
    trace::set_recording(true);
    let on = {
        let root = trace::root("store-bench", "bench");
        let (on, _) = run_cell(cfg, clients, true, root.ctx())?;
        on
    };
    trace::set_recording(false);
    trace::clear();
    Ok((off.p50(), on.p50()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_smoke_reports_all_modes() {
        let cfg = StoreSweepConfig {
            clients: vec![16],
            connections: 4,
            repeats: 1,
            rounds: 2,
            replicas: 1,
        };
        let report = store_sweep(&cfg).unwrap();
        let row = report.row_values("n=16").expect("row");
        assert!(row[0] > 0.0, "batched p50 must be measured: {row:?}");
        assert!(row[1] > 0.0, "batched ops/s must be measured: {row:?}");
        assert!(row[2] > 0.0, "serial p50 must be measured: {row:?}");
        assert!(row[3] > 0.0, "serial ops/s must be measured: {row:?}");
        assert_eq!(row[5], 4.0);
        assert!(row[6] > 0.0, "replicated p50 must be measured: {row:?}");
    }

    #[test]
    fn mix_is_deterministic_and_balanced() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        mix(7, 3, &mut a);
        mix(7, 3, &mut b);
        assert_eq!(a, b);
        assert_eq!(a.len(), MIX_OPS);
    }
}
