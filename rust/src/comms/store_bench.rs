//! Store data-plane throughput sweep (DESIGN.md §11, §14) — the
//! `flashrecovery bench store` CLI / `benches/store_throughput.rs`
//! target.
//!
//! Measures the store under a mixed-opcode workload at 64 → 65,536
//! *simulated clients*, across serving cores and client modes:
//!
//! * **reactor batched** (column 0, the CI gate column) — each
//!   connection pipelines its simulated clients' ops as `Batch`
//!   frames against the readiness-driven event-loop core;
//! * **threads batched** — the same cells against the PR 5 worker
//!   pool: the reactor/threads comparison column;
//! * **serial** — one op per round-trip: the old client model, kept
//!   as the in-tree baseline the speedup criterion compares against;
//! * **replicated** — the batched cell re-run against a
//!   quorum-replicated plane (primary + `replicas` log-shipping
//!   followers, DESIGN.md §13);
//! * **netem** — the batched cell re-run through an in-process netem
//!   proxy adding a mild symmetric delay (DESIGN.md §15): the
//!   degraded-link column, isolating what the wire costs the plane.
//!
//! Serial, replicated, and netem cells are capped at 8,192 simulated
//! clients (their columns report 0 above that, and the report notes
//! the cap): one-RTT-per-op at 65k clients measures the harness, not
//! the store.
//!
//! Scale model (same as the rendezvous and detection sweeps): the
//! simulated-client count drives keys, counters, heartbeat ranks, and
//! total op volume at full scale, while real sockets are bounded by
//! `connections` driver threads — exactly the coalescing a per-node
//! agent performs for its local ranks. Two resource columns feed the
//! §14 acceptance gates: `peak threads` (the serving core's thread
//! high-water mark off the server's own metrics — 1 for the reactor)
//! and `rss mb` (VmRSS after the gated cell, Linux; 0 elsewhere).

use super::netem::{LinkPolicy, NetemProxy};
use super::replication::ReplicaSet;
use super::tcp_store::{StoreCore, TcpStoreClient, TcpStoreServer};
use super::wire::{Request, Response};
use crate::metrics::bench::BenchReport;
use crate::metrics::Histogram;
use crate::telemetry::{trace, TraceCtx};
use anyhow::{anyhow, bail, ensure, Result};
use std::net::SocketAddr;
use std::time::Instant;

/// Ops per `Batch` frame in batched mode — large enough to amortise
/// the round-trip, small enough to keep frames in the tens of KB.
const BATCH_OPS: usize = 128;

/// Mixed ops one simulated client issues per repeat: set, read back,
/// wait-hit (the parked-wait fast path), a contended counter add, one
/// heartbeat, and a second read.
const MIX_OPS: usize = 6;

/// Serial and replicated cells stop at this scale (columns report 0
/// above it): one round-trip per op at 65k simulated clients would
/// dominate the sweep's wall clock while measuring nothing new about
/// the store — the serial baseline's verdict is settled by 8k.
const SERIAL_SCALE_CAP: usize = 8192;

/// Per-direction delay (ms) of the degraded-link column's in-process
/// `NetemProxy` (DESIGN.md §15): a mild metro link — enough to
/// separate the column from loopback noise without dominating the
/// sweep's wall clock. Every batched frame pays at least one
/// `2 × NETEM_DELAY_MS` round trip through the proxy.
const NETEM_DELAY_MS: f64 = 2.0;

/// Configuration for the store throughput sweep.
#[derive(Debug, Clone)]
pub struct StoreSweepConfig {
    /// Simulated client counts (keys/counters/ranks at full scale).
    pub clients: Vec<usize>,
    /// Real TCP connections (== driver threads) the simulated clients
    /// are multiplexed over.
    pub connections: usize,
    /// Repeats of the 6-op mix per simulated client per round.
    pub repeats: usize,
    /// Measured rounds per (scale, mode); one extra warmup round is
    /// discarded.
    pub rounds: u32,
    /// Log-shipping replicas behind the replicated column's primary
    /// (0 degenerates to an un-replicated plane).
    pub replicas: usize,
}

impl Default for StoreSweepConfig {
    fn default() -> Self {
        StoreSweepConfig {
            clients: vec![64, 1024, 4096, 8192, 65536],
            connections: 64,
            repeats: 2,
            rounds: 5,
            replicas: 1,
        }
    }
}

/// The 6-op mix for simulated client `id` in round `round`.
fn mix(id: usize, round: u32, out: &mut Vec<Request>) {
    let key = format!("bench/k{id}");
    let value = format!("payload-{id}-{round}-0123456789abcdef").into_bytes();
    out.push(Request::Set { key: key.clone(), value });
    out.push(Request::Get { key: key.clone() });
    // wait on a key this same pipeline just published: exercises the
    // wait path's fast hit (and, in serial mode, a real Wait RTT)
    out.push(Request::Wait { key: key.clone() });
    out.push(Request::Add { key: format!("bench/ctr{}", id % 32), delta: 1 });
    out.push(Request::Heartbeat {
        rank: id as u64,
        incarnation: 1,
        step_tag: round as i64,
        device_code: -1,
    });
    out.push(Request::Get { key });
}

/// What one driver thread reports for one round.
struct DriverOut {
    /// Per-op latency samples (one per frame: frame RTT / ops in it).
    samples: Vec<f64>,
    ops: u64,
    busy_s: f64,
}

fn check_resps(n_sent: usize, resps: &[Response]) -> Result<()> {
    if resps.len() != n_sent {
        bail!("batch executed {} of {n_sent} ops", resps.len());
    }
    Ok(())
}

/// Run one round for one driver thread owning `ids`.
fn drive_round(
    addr: SocketAddr,
    ids: &[usize],
    round: u32,
    repeats: usize,
    batched: bool,
    trace: Option<TraceCtx>,
) -> Result<DriverOut> {
    let mut client = TcpStoreClient::connect(addr)?;
    client.set_trace_ctx(trace);
    let mut reqs: Vec<Request> = Vec::with_capacity(ids.len() * MIX_OPS * repeats);
    for rep in 0..repeats {
        for &id in ids {
            mix(id, round * repeats.max(1) as u32 + rep as u32, &mut reqs);
        }
    }
    let total_ops = reqs.len() as u64;
    let mut samples = Vec::new();
    let t0 = Instant::now();
    if batched {
        let mut iter = reqs.into_iter().peekable();
        while iter.peek().is_some() {
            let chunk: Vec<Request> = iter.by_ref().take(BATCH_OPS).collect();
            let n = chunk.len();
            let t = Instant::now();
            let resps = client.batch(chunk)?;
            samples.push(t.elapsed().as_secs_f64() / n as f64);
            check_resps(n, &resps)?;
        }
    } else {
        for req in reqs {
            let t = Instant::now();
            let _ = client.roundtrip(req)?;
            samples.push(t.elapsed().as_secs_f64());
        }
    }
    Ok(DriverOut { samples, ops: total_ops, busy_s: t0.elapsed().as_secs_f64() })
}

/// Resident set size in MB (Linux VmRSS; 0 elsewhere) — process-wide,
/// so it bounds server + driver harness together, which is exactly
/// what a CI runner's memory budget sees.
fn rss_mb() -> f64 {
    #[cfg(target_os = "linux")]
    {
        if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
            for line in status.lines() {
                if let Some(rest) = line.strip_prefix("VmRSS:") {
                    let kb: f64 = rest
                        .trim()
                        .trim_end_matches("kB")
                        .trim()
                        .parse()
                        .unwrap_or(0.0);
                    return kb / 1024.0;
                }
            }
        }
    }
    0.0
}

/// Run every round of one (scale, mode) cell on a fresh server with
/// an explicit serving core; returns (per-op histogram, ops/s over
/// the measured rounds, the core's peak serving-thread count).
fn run_cell(
    cfg: &StoreSweepConfig,
    clients: usize,
    batched: bool,
    core: StoreCore,
    trace: Option<TraceCtx>,
) -> Result<(Histogram, f64, f64)> {
    let server = TcpStoreServer::start_with("127.0.0.1:0".parse()?, core)?;
    let (hist, ops) = run_cell_on(server.addr(), cfg, clients, batched, trace)?;
    let peak = server.metrics_snapshot().gauge("store.core_threads") as f64;
    Ok((hist, ops, peak))
}

/// Run every round of one batched cell against a quorum-replicated
/// plane: mutating ops ack only after the primary has shipped them to
/// its `cfg.replicas` followers (DESIGN.md §13).
fn run_replicated_cell(
    cfg: &StoreSweepConfig,
    clients: usize,
) -> Result<(Histogram, f64)> {
    let set = ReplicaSet::start(cfg.replicas)?;
    run_cell_on(set.addr(), cfg, clients, true, None)
}

/// Run one batched cell with every connection routed through an
/// in-process [`NetemProxy`] imposing `NETEM_DELAY_MS` per direction:
/// the §15 degraded-link column. Same store, same workload — the
/// column isolates what the wire costs the batched data plane.
fn run_netem_cell(cfg: &StoreSweepConfig, clients: usize) -> Result<(Histogram, f64)> {
    let server = TcpStoreServer::start_with("127.0.0.1:0".parse()?, StoreCore::Reactor)?;
    let mut proxy = NetemProxy::start(server.addr(), LinkPolicy::delay(NETEM_DELAY_MS))?;
    let out = run_cell_on(proxy.addr(), cfg, clients, true, None);
    proxy.shutdown();
    out
}

/// The driver loop of one (scale, mode) cell against an already
/// running store at `addr`.
fn run_cell_on(
    addr: SocketAddr,
    cfg: &StoreSweepConfig,
    clients: usize,
    batched: bool,
    trace: Option<TraceCtx>,
) -> Result<(Histogram, f64)> {
    let conns = cfg.connections.clamp(1, clients);
    // evenly partition simulated client ids over the connections
    let id_sets: Vec<Vec<usize>> = (0..conns)
        .map(|c| (0..clients).filter(|id| id % conns == c).collect())
        .collect();

    let mut hist = Histogram::new();
    let mut measured_ops = 0u64;
    let mut measured_busy = 0.0f64;
    for round in 0..=cfg.rounds {
        let mut handles = Vec::with_capacity(conns);
        for ids in &id_sets {
            let ids = ids.clone();
            let repeats = cfg.repeats.max(1);
            handles.push(std::thread::spawn(move || {
                drive_round(addr, &ids, round, repeats, batched, trace)
            }));
        }
        let mut round_busy = 0.0f64;
        let mut round_ops = 0u64;
        let mut outs = Vec::with_capacity(conns);
        for h in handles {
            outs.push(h.join().expect("driver thread panicked")?);
        }
        if round == 0 {
            continue; // warmup: server core + allocator settle
        }
        for out in outs {
            round_busy = round_busy.max(out.busy_s);
            round_ops += out.ops;
            for s in out.samples {
                hist.record(s);
            }
        }
        measured_ops += round_ops;
        // rounds are synchronized by join, so the per-round critical
        // path (slowest driver) is what wall-clock throughput pays
        measured_busy += round_busy;
    }
    let ops_per_s = if measured_busy > 0.0 {
        measured_ops as f64 / measured_busy
    } else {
        0.0
    };
    Ok((hist, ops_per_s))
}

/// Run the store throughput sweep. Column 0 (`p50 us/op`, reactor
/// batched mode) is the value CI's bench gate compares against the
/// committed baseline in `ci/BENCH_store_throughput.baseline.json`.
pub fn store_sweep(cfg: &StoreSweepConfig) -> Result<BenchReport> {
    let mut report = BenchReport::new(
        "store_throughput: event-loop reactor vs worker pool, mixed workload",
        &[
            "p50 us/op",
            "ops/s",
            "threads p50",
            "serial us/op",
            "serial ops/s",
            "speedup x",
            "conns",
            "repl p50",
            "peak threads",
            "rss mb",
            "netem p50",
        ],
    );
    for &n in &cfg.clients {
        if n == 0 {
            bail!("sweep needs at least one simulated client");
        }
        let (batched_h, batched_ops, peak) =
            run_cell(cfg, n, true, StoreCore::Reactor, None)?;
        let rss = rss_mb();
        let (threads_h, _, _) = run_cell(cfg, n, true, StoreCore::Threads, None)?;
        let (serial_p50, serial_ops, repl_p50, speedup, netem_p50) =
            if n <= SERIAL_SCALE_CAP {
            let (serial_h, serial_ops, _) =
                run_cell(cfg, n, false, StoreCore::Reactor, None)?;
            let (repl_h, _) = run_replicated_cell(cfg, n)?;
            let (netem_h, _) = run_netem_cell(cfg, n)?;
            let speedup =
                if serial_ops > 0.0 { batched_ops / serial_ops } else { 0.0 };
            (
                serial_h.p50() * 1e6,
                serial_ops,
                repl_h.p50() * 1e6,
                speedup,
                netem_h.p50() * 1e6,
            )
        } else {
            (0.0, 0.0, 0.0, 0.0, 0.0)
        };
        report.row(
            format!("n={n}"),
            vec![
                batched_h.p50() * 1e6,
                batched_ops,
                threads_h.p50() * 1e6,
                serial_p50,
                serial_ops,
                speedup,
                cfg.connections.min(n) as f64,
                repl_p50,
                peak,
                rss,
                netem_p50,
            ],
        );
    }
    report.note(format!(
        "{} rounds/cell (+1 warmup), {} x 6-op mix per simulated client \
         (set/get/wait-hit/add/heartbeat/get), {} connections; batched mode \
         pipelines {} ops per frame against the reactor (col 0, the CI gate) \
         and the worker pool (threads p50); serial mode pays one RTT per op; \
         the repl column re-runs the batched cell with {} quorum replica(s) \
         behind the primary",
        cfg.rounds, cfg.repeats, cfg.connections, BATCH_OPS, cfg.replicas
    ));
    report.note(format!(
        "serial, replicated, and netem cells are capped at {SERIAL_SCALE_CAP} \
         simulated clients (0 above): one RTT per op at 65k measures the \
         harness, not the store — their columns are baselines, not gates, \
         beyond that scale"
    ));
    report.note(format!(
        "netem p50 re-runs the batched reactor cell through an in-process \
         netem proxy adding {NETEM_DELAY_MS}ms per direction (DESIGN.md §15): \
         the degraded-link column — every frame pays at least one \
         {:.0}ms round trip through the proxy",
        2.0 * NETEM_DELAY_MS
    ));
    report.note(
        "gates: per-op p50 at the largest scale <= 1.5x the 4096-client p50 \
         (flat at 65k); batched >= 2x serial ops/s at 4096 clients; \
         quorum-replicated p50 <= 1.5x un-replicated batched p50; reactor \
         peak serving threads <= 8 (one event loop, not thread-per-client); \
         RSS at the largest scale <= 2x the 4096-client RSS + 256MB",
    );
    Ok(report)
}

/// The sweep's acceptance properties (ISSUE 5 + ISSUE 7 + the §14
/// reactor gates), shared by the bench target and `bench store
/// --assert` (which bench-gate runs):
///
/// * batched ≥ 2x serial ops/s at 4096 clients (or the largest swept
///   scale at or under the serial cap);
/// * flat at scale, twice: the legacy 2x bound from the smallest to
///   the largest *serial-capped* scale, and the §14 bound — p50 at
///   the largest scale ≤ 1.5x the 4096-client p50;
/// * quorum-replicated p50 ≤ 1.5x un-replicated batched p50 per
///   measured scale;
/// * the reactor's peak serving threads stay ≤ 8 at every scale
///   (Linux; elsewhere the reactor request degrades to the pool);
/// * RSS at the largest scale ≤ 2x the 4096-client row's + 256MB;
/// * the §15 degraded-link cell actually pays the proxy's wire (per-op
///   p50 ≥ 90% of one proxy RTT amortised over a full frame) and stays
///   within a bounded envelope of the un-impaired cell — the wire, not
///   queueing collapse, must be the difference.
///
/// All latency bounds carry a 5us noise floor for loaded runners.
pub fn check_report(cfg: &StoreSweepConfig, report: &BenchReport) -> Result<()> {
    let (Some(&min_scale), Some(&max_scale)) =
        (cfg.clients.iter().min(), cfg.clients.iter().max())
    else {
        return Ok(());
    };
    let row = |n: usize| {
        report
            .row_values(&format!("n={n}"))
            .ok_or_else(|| anyhow!("missing sweep row n={n}"))
    };
    // the largest scale whose serial/replicated cells were measured
    let capped_max = cfg
        .clients
        .iter()
        .copied()
        .filter(|&n| n <= SERIAL_SCALE_CAP)
        .max()
        .unwrap_or(min_scale);
    let compare_at = if cfg.clients.contains(&4096) { 4096 } else { capped_max };
    let speedup = row(compare_at)?[5];
    ensure!(
        speedup >= 2.0,
        "batched plane must be >= 2x serial ops/s at {compare_at} clients \
         (got {speedup:.2}x)"
    );
    let (lo, hi) = (row(min_scale)?[0], row(capped_max)?[0]);
    ensure!(
        hi <= 2.0 * lo + 5.0,
        "store per-op p50 not scale-independent: {hi:.2}us @ {capped_max} vs \
         {lo:.2}us @ {min_scale}"
    );
    // §14 flat-at-65k gate: the largest scale against the 4096 anchor
    let (anchor, top) = (row(compare_at)?[0], row(max_scale)?[0]);
    ensure!(
        top <= 1.5 * anchor + 5.0,
        "per-op p50 must stay flat at the largest scale: {top:.2}us @ \
         {max_scale} vs {anchor:.2}us @ {compare_at} (> 1.5x + 5us floor)"
    );
    for &n in &cfg.clients {
        let r = row(n)?;
        let (plain, repl) = (r[0], r[7]);
        if repl > 0.0 {
            ensure!(
                repl <= 1.5 * plain + 5.0,
                "quorum replication too expensive at n={n}: repl p50 \
                 {repl:.2}us vs {:.2}us allowed (1.5x un-replicated \
                 {plain:.2}us + 5us floor)",
                1.5 * plain + 5.0
            );
        }
        // §14 thread gate: one event loop serves every client — the
        // reactor cell's serving-thread high-water mark must not
        // scale with clients (off-Linux the reactor request degrades
        // to the pool, so the gate only binds where epoll exists)
        if cfg!(target_os = "linux") {
            let peak = r[8];
            ensure!(
                peak <= 8.0,
                "reactor peak serving threads must be O(1), got {peak} at \
                 n={n}"
            );
        }
        // §15 degraded-link gate: one proxy RTT amortised over a full
        // frame is the deterministic per-op floor (frames never carry
        // more than BATCH_OPS ops, and each one sleeps through the
        // proxy both ways); the ceiling leaves room for frames split
        // into several charged bursts but catches queueing collapse.
        let netem = r[10];
        if netem > 0.0 {
            let rtt_us = 2.0 * NETEM_DELAY_MS * 1e3;
            let floor = rtt_us / BATCH_OPS as f64;
            ensure!(
                netem >= 0.9 * floor,
                "netem cell at n={n} did not pay the wire: {netem:.2}us/op \
                 vs a {floor:.2}us/op proxy-RTT floor"
            );
            ensure!(
                netem <= 1.5 * plain + 100.0 * floor + 5.0,
                "netem cell at n={n} looks like queueing collapse, not a \
                 slow wire: {netem:.2}us/op vs {:.2}us allowed",
                1.5 * plain + 100.0 * floor + 5.0
            );
        }
    }
    // §14 memory gate: bounded RSS at the top scale (Linux-measured;
    // rows report 0 where /proc is unavailable)
    let (rss_anchor, rss_top) = (row(compare_at)?[9], row(max_scale)?[9]);
    if rss_anchor > 0.0 && rss_top > 0.0 {
        ensure!(
            rss_top <= 2.0 * rss_anchor + 256.0,
            "RSS must stay bounded at the largest scale: {rss_top:.0}MB @ \
             {max_scale} vs {rss_anchor:.0}MB @ {compare_at} (> 2x + 256MB)"
        );
    }
    Ok(())
}

/// Flight-recorder overhead on the batched hot path (DESIGN.md §12):
/// run one batched cell with the recorder off, then again with it on
/// and every frame stamped with a live trace context (16 extra wire
/// bytes per frame + one recorded event per frame server-side), and
/// return `(off_p50, on_p50)` per-op latencies in seconds. The bench
/// target asserts on ≤ 1.05x off plus a small noise floor.
///
/// Toggles (and finally disables) the process-global recorder, so
/// call it only from a single-threaded bench/CLI context — never
/// concurrently with code that records traces.
pub fn telemetry_overhead(cfg: &StoreSweepConfig, clients: usize) -> Result<(f64, f64)> {
    trace::set_recording(false);
    let (off, _, _) = run_cell(cfg, clients, true, StoreCore::default_core(), None)?;
    trace::set_recording(true);
    let on = {
        let root = trace::root("store-bench", "bench");
        let (on, _, _) =
            run_cell(cfg, clients, true, StoreCore::default_core(), root.ctx())?;
        on
    };
    trace::set_recording(false);
    trace::clear();
    Ok((off.p50(), on.p50()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_smoke_reports_all_modes() {
        let cfg = StoreSweepConfig {
            clients: vec![16],
            connections: 4,
            repeats: 1,
            rounds: 2,
            replicas: 1,
        };
        let report = store_sweep(&cfg).unwrap();
        let row = report.row_values("n=16").expect("row");
        assert!(row[0] > 0.0, "reactor batched p50 must be measured: {row:?}");
        assert!(row[1] > 0.0, "batched ops/s must be measured: {row:?}");
        assert!(row[2] > 0.0, "threads-core p50 must be measured: {row:?}");
        assert!(row[3] > 0.0, "serial p50 must be measured: {row:?}");
        assert!(row[4] > 0.0, "serial ops/s must be measured: {row:?}");
        assert_eq!(row[6], 4.0);
        assert!(row[7] > 0.0, "replicated p50 must be measured: {row:?}");
        assert!(row[8] >= 1.0, "peak serving threads must be sampled: {row:?}");
        // 24 ops per frame in this config, and every frame sleeps
        // through the netem proxy both ways: >= 4ms/24 ≈ 166us/op
        assert!(
            row[10] > 100.0,
            "netem p50 must pay the proxy's wire: {row:?}"
        );
        #[cfg(target_os = "linux")]
        {
            assert!(
                row[8] <= 8.0,
                "reactor cell must not be thread-per-client: {row:?}"
            );
            assert!(row[9] > 0.0, "RSS must be sampled on Linux: {row:?}");
        }
    }

    #[test]
    fn mix_is_deterministic_and_balanced() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        mix(7, 3, &mut a);
        mix(7, 3, &mut b);
        assert_eq!(a, b);
        assert_eq!(a.len(), MIX_OPS);
    }
}
