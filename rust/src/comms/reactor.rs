//! Readiness-driven store core (DESIGN.md §14): one event-loop thread
//! serves every connection through epoll, replacing thread-per-blocked
//! -client with per-connection frame state machines. Blocked `Wait` /
//! `WaitEpoch` / `ClaimRestore` ops are parked *entries* in the same
//! per-key slots the threaded core parks threads in — a `Set` enqueues
//! exactly its key's entry ids onto a wakeup queue the loop drains by
//! resuming the suspended frame. Replication commit waits park the
//! same way (the shipper pings an eventfd instead of a condvar).
//!
//! Equivalence contract with the threaded core (`tcp_store::handle`):
//! identical wire format, one frame in flight per connection, the same
//! `wait_poll` fence→value→stop decision order, the same per-op
//! metrics accounting, and the same replication/dedup log layout. The
//! op-budget, failover and dedup tests run against either core
//! unchanged.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::replication::{Replicator, ROLE_REPLICA};
use super::tcp_store::{
    apply_mutating, apply_op, bump_applied, encode_resp_body,
    handle_install_state, handle_replicate, lock, loggable, promote_shared,
    repl_status_response,
    replica_serves, restore_key, run_thread_core, wait_poll, Shared,
    WakeEvent,
};
use super::wire::{Request, Response, MAX_FRAME_BYTES};
use crate::telemetry::trace;
use crate::util::epoll::{
    Epoll, EpollEvent, WakeFd, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT,
    EPOLLRDHUP,
};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;
/// Matches the threaded core's `Replicator::wait_committed` deadline.
const COMMIT_DEADLINE: Duration = Duration::from_secs(10);

/// Event-loop entry point — the body of the store's serve thread.
/// Falls back to the threaded core's accept loop if epoll/eventfd
/// setup fails (it already owns the thread, so the fallback is free).
pub(super) fn run(listener: TcpListener, shared: Arc<Shared>, stop: Arc<AtomicBool>) {
    let (epoll, waker) = match (Epoll::new(), WakeFd::new()) {
        (Ok(e), Ok(w)) => (e, w),
        _ => return run_thread_core(listener, shared, stop),
    };
    let waker = Arc::new(waker);
    if epoll.add(listener.as_raw_fd(), EPOLLIN, TOKEN_LISTENER).is_err()
        || epoll.add(waker.raw_fd(), EPOLLIN, TOKEN_WAKER).is_err()
    {
        return run_thread_core(listener, shared, stop);
    }
    let hook: Arc<dyn Fn() + Send + Sync> = {
        let w = waker.clone();
        Arc::new(move || w.wake())
    };
    *lock(&shared.reactor_waker) = Some(hook.clone());
    shared.core_threads.set(1); // the event loop is the whole core

    let mut r = Reactor {
        shared,
        stop,
        epoll,
        waker,
        wake_hook: hook,
        listener,
        conns: HashMap::new(),
        pending: HashMap::new(),
        commit_waits: Vec::new(),
        runnable: Vec::new(),
        next_token: FIRST_CONN_TOKEN,
        scratch: vec![0u8; 64 * 1024],
    };
    r.event_loop();
    r.shutdown_drain();
}

/// What a connection is doing between readiness events.
enum ConnState {
    /// Reading request bytes (or flushing a response).
    Idle,
    /// A blocking op parked this connection's frame on a key slot.
    Parked,
    /// The frame executed; its response is held until the replication
    /// watermark covers the ops it logged.
    AwaitCommit,
}

struct Conn {
    stream: TcpStream,
    /// Buffered inbound bytes (possibly several pipelined frames).
    buf: Vec<u8>,
    /// The encoded response being flushed; empty = nothing in flight.
    wbuf: Vec<u8>,
    wpos: usize,
    state: ConnState,
    interest: u32,
}

/// Which envelope the in-flight frame arrived under — decides how the
/// collected responses fold up and whether/how they are logged.
enum Wrapper {
    Single,
    Batch,
    DedupSingle { id: u64 },
    DedupBatch { id: u64 },
}

/// A suspended frame: everything `handle_inner` kept on its stack,
/// lifted into a heap entry so the frame survives parking.
struct Pending {
    conn: u64,
    wrapper: Wrapper,
    /// Ops not yet executed (tail of a batch; the single op itself).
    rest: VecDeque<Request>,
    /// Responses collected so far.
    out: Vec<Response>,
    /// Loggable ops accumulated under a dedup envelope — appended to
    /// the replication log in one frame with the `DedupDone` marker.
    entries: Vec<Request>,
    /// Highest log index this frame shipped (0 = nothing logged).
    highest: u64,
    /// Replication snapshot taken once per frame, like `handle`.
    repl: Option<Arc<Replicator>>,
    /// A blocking sub-op was released by the shutdown broadcast —
    /// suppress dedup caching/logging, exactly like the threaded core.
    released: bool,
    /// The key/epoch the frame is parked on (valid while `Parked`).
    wait_key: String,
    wait_epoch: u64,
}

/// A response withheld until its log index commits (or the replica set
/// degrades / the deadline passes — `wait_committed`'s exits).
struct CommitWait {
    conn: u64,
    repl: Arc<Replicator>,
    index: u64,
    deadline: Instant,
    resp: Response,
}

struct Reactor {
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    epoll: Epoll,
    waker: Arc<WakeFd>,
    wake_hook: Arc<dyn Fn() + Send + Sync>,
    listener: TcpListener,
    conns: HashMap<u64, Conn>,
    /// Suspended frames, keyed by connection token (one frame in
    /// flight per connection, so the token doubles as the entry id
    /// stored in `WaitSlot::entries`).
    pending: HashMap<u64, Pending>,
    commit_waits: Vec<CommitWait>,
    /// Connections with a freshly flushed response whose buffered
    /// pipelined frames should be processed this drain round.
    runnable: Vec<u64>,
    next_token: u64,
    scratch: Vec<u8>,
}

impl Reactor {
    fn event_loop(&mut self) {
        let mut events = vec![EpollEvent::default(); 1024];
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return;
            }
            let n = match self.epoll.wait(&mut events, 100) {
                Ok(n) => n,
                Err(_) => return,
            };
            let ready: Vec<(u64, u32)> =
                events.iter().take(n).map(|e| (e.token(), e.events())).collect();
            for (token, bits) in ready {
                match token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.waker.drain(),
                    _ => self.conn_event(token, bits),
                }
            }
            self.drain_ready();
        }
    }

    /// Fan out queued publish wakes, release due commit waits, and run
    /// buffered pipelined frames — repeating until a fixpoint (each
    /// iteration consumes buffered work, so this terminates).
    fn drain_ready(&mut self) {
        loop {
            let wakes = std::mem::take(&mut *lock(&self.shared.pending_wakes));
            for ev in wakes {
                match ev {
                    WakeEvent::Key(k) => self.wake_key(&k),
                    WakeEvent::All => self.wake_all_entries(),
                }
            }
            self.release_due_commits();
            let run = std::mem::take(&mut self.runnable);
            for token in run {
                self.process_buffered(token);
            }
            if self.runnable.is_empty()
                && lock(&self.shared.pending_wakes).is_empty()
            {
                return;
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nodelay(true).ok();
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    let interest = EPOLLIN | EPOLLRDHUP;
                    if self.epoll.add(stream.as_raw_fd(), interest, token).is_err() {
                        continue;
                    }
                    self.shared.registrations.add(1);
                    self.conns.insert(
                        token,
                        Conn {
                            stream,
                            buf: Vec::new(),
                            wbuf: Vec::new(),
                            wpos: 0,
                            state: ConnState::Idle,
                            interest,
                        },
                    );
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn conn_event(&mut self, token: u64, bits: u32) {
        if bits & (EPOLLERR | EPOLLHUP) != 0 {
            return self.close_conn(token);
        }
        let Some(c) = self.conns.get(&token) else { return };
        if !c.wbuf.is_empty() {
            // mid-flush: only writability (or peer death) matters
            if bits & EPOLLOUT != 0 {
                self.flush_conn(token);
            } else if bits & EPOLLRDHUP != 0 {
                self.close_conn(token);
            }
            return;
        }
        match c.state {
            // parked frames hold only EPOLLRDHUP interest: any event
            // here is the peer dying, which must unpark-and-discard
            ConnState::Parked | ConnState::AwaitCommit => self.close_conn(token),
            ConnState::Idle => {
                if bits & (EPOLLIN | EPOLLRDHUP) != 0 {
                    self.read_drain(token);
                }
            }
        }
    }

    /// Pull everything the socket has (level-triggered — draining now
    /// saves redundant wakeups), then process complete frames.
    fn read_drain(&mut self, token: u64) {
        loop {
            let Some(c) = self.conns.get_mut(&token) else { return };
            match c.stream.read(&mut self.scratch) {
                Ok(0) => return self.close_conn(token),
                Ok(n) => c.buf.extend_from_slice(&self.scratch[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return self.close_conn(token),
            }
        }
        self.process_buffered(token);
    }

    /// Execute buffered frames one at a time, stopping when the
    /// connection parks, starts flushing, closes, or runs out of
    /// complete frames — the reactor's version of "one frame in
    /// flight per connection".
    fn process_buffered(&mut self, token: u64) {
        loop {
            enum Parse {
                Stop,
                TooLarge,
                Frame(Vec<u8>),
            }
            let parsed = {
                let Some(c) = self.conns.get_mut(&token) else { return };
                if !matches!(c.state, ConnState::Idle) || !c.wbuf.is_empty() {
                    return;
                }
                if c.buf.len() < 4 {
                    Parse::Stop
                } else {
                    let len = u32::from_le_bytes(c.buf[..4].try_into().unwrap())
                        as usize;
                    if len > MAX_FRAME_BYTES {
                        Parse::TooLarge
                    } else if c.buf.len() < 4 + len {
                        Parse::Stop
                    } else {
                        let body = c.buf[4..4 + len].to_vec();
                        c.buf.drain(..4 + len);
                        Parse::Frame(body)
                    }
                }
            };
            match parsed {
                Parse::Stop => return,
                Parse::TooLarge => return self.close_conn(token),
                Parse::Frame(body) => self.handle_frame(token, &body),
            }
        }
    }

    /// Decode and begin one frame — mirrors `tcp_store::handle`: one
    /// frames tick, one trace event, one replication snapshot.
    fn handle_frame(&mut self, token: u64, body: &[u8]) {
        self.shared.frames.inc();
        let Ok((req, ctx)) = Request::decode_traced(body) else {
            return self.close_conn(token);
        };
        if let Some(ctx) = ctx {
            trace::event_in(ctx, req.op_name(), "store", String::new());
        }
        let repl = lock(&self.shared.repl).clone();
        self.begin(token, repl, req);
    }

    /// Top-level dispatch — the reactor's `handle_inner` head: role
    /// check, then the arms that answer immediately (replication
    /// protocol, cached dedup replays); everything else becomes a
    /// `Pending` frame run through `run_ops`.
    fn begin(&mut self, token: u64, repl: Option<Arc<Replicator>>, req: Request) {
        let sh = self.shared.clone();
        if sh.role.load(Ordering::SeqCst) == ROLE_REPLICA && !replica_serves(&req) {
            sh.requests.inc();
            return self.complete(token, repl, Response::NotPrimary, 0);
        }
        let (wrapper, ops) = match req {
            Request::Replicate { start_index, ops } => {
                sh.requests.inc();
                let resp = handle_replicate(&sh, &self.stop, start_index, ops);
                return self.complete(token, repl, resp, 0);
            }
            Request::ReplStatus => {
                sh.requests.inc();
                return self.complete(token, repl, repl_status_response(&sh), 0);
            }
            Request::InstallState { high_water, ops } => {
                sh.requests.inc();
                let resp = handle_install_state(&sh, &self.stop, high_water, ops);
                return self.complete(token, repl, resp, 0);
            }
            Request::Promote { peers } => {
                sh.requests.inc();
                let addrs: Vec<SocketAddr> =
                    peers.iter().filter_map(|p| p.parse().ok()).collect();
                promote_shared(&sh, &addrs);
                return self.complete(token, repl, Response::Ok, 0);
            }
            Request::Dedup { id, op } => {
                if let Some(cached) = lock(&sh.dedup).get(id) {
                    // replayed id: cached answer, no requests tick —
                    // identical to `handle_dedup`'s replay path
                    let resp =
                        Response::decode(&cached).unwrap_or(Response::NotFound);
                    return self.complete(token, repl, resp, 0);
                }
                match *op {
                    Request::Batch(items) => {
                        (Wrapper::DedupBatch { id }, VecDeque::from(items))
                    }
                    single => {
                        (Wrapper::DedupSingle { id }, VecDeque::from(vec![single]))
                    }
                }
            }
            Request::Batch(items) => (Wrapper::Batch, VecDeque::from(items)),
            single => (Wrapper::Single, VecDeque::from(vec![single])),
        };
        let cap = ops.len();
        self.run_ops(Pending {
            conn: token,
            wrapper,
            rest: ops,
            out: Vec::with_capacity(cap),
            entries: Vec::new(),
            highest: 0,
            repl,
            released: false,
            wait_key: String::new(),
            wait_epoch: 0,
        });
    }

    /// Execute the frame's remaining ops until it parks, fences, is
    /// shutdown-released, or completes — the loop `handle_inner` runs
    /// on a thread's stack, resumable at any op boundary.
    fn run_ops(&mut self, mut p: Pending) {
        let sh = self.shared.clone();
        while let Some(op) = p.rest.pop_front() {
            // per-item role re-check for plain batches only — mirrors
            // handle_inner's recursion (dedup bodies apply directly)
            if matches!(p.wrapper, Wrapper::Batch)
                && sh.role.load(Ordering::SeqCst) == ROLE_REPLICA
                && !replica_serves(&op)
            {
                sh.requests.inc();
                p.out.push(Response::NotPrimary);
                continue;
            }
            sh.requests.inc();
            if op.is_blocking() {
                let (key, epoch) = blocking_target(&op);
                let polled = {
                    let g = lock(sh.stripe_for(&key));
                    wait_poll(&sh, &self.stop, &g, &key, epoch)
                };
                match polled {
                    Some(resp) => {
                        // resolved without parking: no wakeups tick,
                        // same as a thread that never waited
                        if self.push_result(&mut p, true, resp) {
                            break;
                        }
                    }
                    None => return self.park(p, key, epoch),
                }
            } else {
                let resp = self.exec_nonblocking(&mut p, op);
                if self.push_result(&mut p, false, resp) {
                    break;
                }
            }
        }
        self.finish(p);
    }

    /// Execute one non-blocking op under the frame's wrapper. Dedup
    /// bodies apply directly and accumulate loggable entries for one
    /// atomic append at finish (same log layout as `handle_dedup`);
    /// plain ops take the same dispatch arms as `handle_inner`.
    fn exec_nonblocking(&mut self, p: &mut Pending, op: Request) -> Response {
        let sh = self.shared.clone();
        match p.wrapper {
            Wrapper::DedupSingle { .. } | Wrapper::DedupBatch { .. } => {
                let resp = apply_op(&sh, &self.stop, op.clone());
                if loggable(&op, &resp) {
                    p.entries.push(op);
                }
                resp
            }
            Wrapper::Single | Wrapper::Batch => match op {
                Request::ReplStatus => repl_status_response(&sh),
                Request::Promote { peers } => {
                    let addrs: Vec<SocketAddr> =
                        peers.iter().filter_map(|s| s.parse().ok()).collect();
                    promote_shared(&sh, &addrs);
                    Response::Ok
                }
                Request::Replicate { start_index, ops } => {
                    handle_replicate(&sh, &self.stop, start_index, ops)
                }
                op if op.is_mutating() => apply_mutating(
                    &sh,
                    &self.stop,
                    p.repl.as_deref(),
                    &mut p.highest,
                    op,
                ),
                op => apply_op(&sh, &self.stop, op),
            },
        }
    }

    /// Record one op's response; returns true when the frame must stop
    /// early (fence, or a blocking op released by shutdown).
    fn push_result(&mut self, p: &mut Pending, blocking: bool, resp: Response) -> bool {
        let fenced = matches!(resp, Response::EpochFenced { .. });
        let released = blocking
            && resp == Response::NotFound
            && self.stop.load(Ordering::Relaxed);
        p.out.push(resp);
        if released {
            p.released = true;
            return true;
        }
        fenced
    }

    /// Suspend the frame: its id joins the key's slot (beside any
    /// parked threads), interest narrows to peer-death, and the state
    /// machine waits for a `WakeEvent` to resume it.
    fn park(&mut self, mut p: Pending, key: String, epoch: u64) {
        let token = p.conn;
        {
            let mut g = lock(self.shared.stripe_for(&key));
            g.parked.entry(key.clone()).or_default().entries.push(token);
        }
        self.shared.parked.add(1);
        p.wait_key = key;
        p.wait_epoch = epoch;
        if let Some(c) = self.conns.get_mut(&token) {
            c.state = ConnState::Parked;
        }
        self.set_interest(token, EPOLLRDHUP);
        self.pending.insert(token, p);
    }

    /// Resume a parked frame off the wakeup queue: re-poll under the
    /// stripe lock (the value may have been consumed or the wake may
    /// be spurious — same re-check a notified thread performs) and
    /// either re-park or continue the frame.
    fn resume(&mut self, token: u64) {
        let Some(mut p) = self.pending.remove(&token) else { return };
        self.shared.parked.sub(1);
        let polled = {
            let g = lock(self.shared.stripe_for(&p.wait_key));
            wait_poll(&self.shared, &self.stop, &g, &p.wait_key, p.wait_epoch)
        };
        match polled {
            None => {
                // spurious wake: back onto the slot
                {
                    let mut g = lock(self.shared.stripe_for(&p.wait_key));
                    g.parked
                        .entry(p.wait_key.clone())
                        .or_default()
                        .entries
                        .push(token);
                }
                self.shared.parked.add(1);
                self.pending.insert(token, p);
            }
            Some(resp) => {
                // parked-then-published: the deterministic wakeup
                if matches!(resp, Response::Value(_)) {
                    self.shared.wakeups.inc();
                }
                if self.push_result(&mut p, true, resp) {
                    self.finish(p);
                } else {
                    self.run_ops(p);
                }
            }
        }
    }

    /// Fold the collected responses per the wrapper and (for fresh
    /// dedup ids that weren't shutdown-released) cache + log the
    /// response with its ops in one atomic append — byte-identical to
    /// `handle_dedup`'s layout.
    fn finish(&mut self, mut p: Pending) {
        let resp = match p.wrapper {
            Wrapper::Single => p.out.pop().unwrap_or(Response::NotFound),
            Wrapper::Batch => Response::Multi(std::mem::take(&mut p.out)),
            Wrapper::DedupSingle { id } => {
                let resp = p.out.pop().unwrap_or(Response::NotFound);
                if p.released {
                    resp // uncached: the client replays fresh
                } else {
                    self.seal_dedup(&mut p, id, &resp);
                    resp
                }
            }
            Wrapper::DedupBatch { id } => {
                let resp = Response::Multi(std::mem::take(&mut p.out));
                if p.released {
                    resp // executed prefix dies with this primary
                } else {
                    self.seal_dedup(&mut p, id, &resp);
                    resp
                }
            }
        };
        self.complete(p.conn, p.repl.clone(), resp, p.highest);
    }

    /// Install the dedup cache entry and ship `[ops.., DedupDone]` as
    /// one contiguous log append.
    fn seal_dedup(&mut self, p: &mut Pending, id: u64, resp: &Response) {
        let body = encode_resp_body(resp);
        lock(&self.shared.dedup).insert(id, body.clone());
        p.entries.push(Request::DedupDone { id, resp: body });
        if let Some(r) = &p.repl {
            if let Some(idx) = r.append(std::mem::take(&mut p.entries)) {
                bump_applied(&self.shared, &mut p.highest, idx);
            }
        }
    }

    /// Ship the response — or, when the frame logged replicated ops
    /// not yet committed, park it as a commit wait (the reactor's
    /// `wait_committed`: released by watermark advance, degradation,
    /// shutdown, or the 10s deadline).
    fn complete(
        &mut self,
        token: u64,
        repl: Option<Arc<Replicator>>,
        resp: Response,
        highest: u64,
    ) {
        if let Some(c) = self.conns.get_mut(&token) {
            c.state = ConnState::Idle;
        } else {
            return;
        }
        if highest > 0 && !self.stop.load(Ordering::Relaxed) {
            if let Some(r) = repl {
                if r.watermark() < highest && !r.is_degraded() {
                    r.set_commit_waker(self.wake_hook.clone());
                    if let Some(c) = self.conns.get_mut(&token) {
                        c.state = ConnState::AwaitCommit;
                    }
                    self.set_interest(token, EPOLLRDHUP);
                    self.commit_waits.push(CommitWait {
                        conn: token,
                        repl: r,
                        index: highest,
                        deadline: Instant::now() + COMMIT_DEADLINE,
                        resp,
                    });
                    return;
                }
            }
        }
        self.send(token, resp);
    }

    fn release_due_commits(&mut self) {
        if self.commit_waits.is_empty() {
            return;
        }
        let stop = self.stop.load(Ordering::Relaxed);
        let now = Instant::now();
        let mut ready = Vec::new();
        let mut i = 0;
        while i < self.commit_waits.len() {
            let w = &self.commit_waits[i];
            if stop
                || now >= w.deadline
                || w.repl.is_degraded()
                || w.repl.watermark() >= w.index
            {
                ready.push(self.commit_waits.swap_remove(i));
            } else {
                i += 1;
            }
        }
        for w in ready {
            if let Some(c) = self.conns.get_mut(&w.conn) {
                c.state = ConnState::Idle;
            }
            self.send(w.conn, w.resp);
        }
    }

    /// Drain entries parked on one key's slot (a `Set` published it).
    fn wake_key(&mut self, key: &str) {
        let ids = {
            let mut g = lock(self.shared.stripe_for(key));
            match g.parked.get_mut(key) {
                Some(slot) => {
                    let ids = std::mem::take(&mut slot.entries);
                    if slot.waiters == 0 {
                        g.parked.remove(key);
                    }
                    ids
                }
                None => return,
            }
        };
        for token in ids {
            self.resume(token);
        }
    }

    /// Drain every parked entry (epoch advance / shutdown broadcast).
    fn wake_all_entries(&mut self) {
        let sh = self.shared.clone();
        let mut ids = Vec::new();
        for stripe in &sh.stripes {
            let mut g = lock(stripe);
            for slot in g.parked.values_mut() {
                ids.append(&mut slot.entries);
            }
            g.parked.retain(|_, s| s.waiters > 0 || !s.entries.is_empty());
        }
        for token in ids {
            self.resume(token);
        }
    }

    /// Encode the response into the connection's write buffer and
    /// start flushing.
    fn send(&mut self, token: u64, resp: Response) {
        let Some(c) = self.conns.get_mut(&token) else { return };
        resp.encode_into(&mut c.wbuf);
        c.wpos = 0;
        self.flush_conn(token);
    }

    /// Push buffered response bytes until done or the socket backs up
    /// (then wait for EPOLLOUT — slow readers park the *connection*,
    /// never a thread).
    fn flush_conn(&mut self, token: u64) {
        loop {
            let Some(c) = self.conns.get_mut(&token) else { return };
            if c.wpos >= c.wbuf.len() {
                c.wbuf.clear();
                c.wpos = 0;
                self.set_interest(token, EPOLLIN | EPOLLRDHUP);
                // pipelined frames may already be buffered
                self.runnable.push(token);
                return;
            }
            match c.stream.write(&c.wbuf[c.wpos..]) {
                Ok(0) => return self.close_conn(token),
                Ok(n) => c.wpos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    return self.set_interest(token, EPOLLOUT | EPOLLRDHUP);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return self.close_conn(token),
            }
        }
    }

    fn set_interest(&mut self, token: u64, events: u32) {
        if let Some(c) = self.conns.get_mut(&token) {
            if c.interest != events {
                c.interest = events;
                let _ = self.epoll.modify(c.stream.as_raw_fd(), events, token);
            }
        }
    }

    /// Tear a connection down: deregister, and if a frame was parked
    /// on it, unhook the entry from its slot and drop the frame — the
    /// no-leak path the churn test exercises.
    fn close_conn(&mut self, token: u64) {
        let Some(c) = self.conns.remove(&token) else { return };
        let _ = self.epoll.delete(c.stream.as_raw_fd());
        self.shared.registrations.sub(1);
        match c.state {
            ConnState::Idle => {}
            ConnState::Parked => {
                if let Some(p) = self.pending.remove(&token) {
                    self.shared.parked.sub(1);
                    let mut g = lock(self.shared.stripe_for(&p.wait_key));
                    if let Some(slot) = g.parked.get_mut(&p.wait_key) {
                        slot.entries.retain(|t| *t != token);
                        if slot.waiters == 0 && slot.entries.is_empty() {
                            g.parked.remove(&p.wait_key);
                        }
                    }
                }
            }
            ConnState::AwaitCommit => {
                self.commit_waits.retain(|w| w.conn != token);
            }
        }
    }

    /// Stop-flag observed: release every parked frame with the same
    /// fence→value→stop resolution a dying threaded server applies,
    /// flush commit waits, then deliver outstanding bytes best-effort
    /// (blocking with a short timeout) so clients *receive* their
    /// shutdown release — the failover trigger `StoreSession` acts on.
    fn shutdown_drain(&mut self) {
        let parked: Vec<u64> = self.pending.keys().copied().collect();
        for token in parked {
            self.resume(token); // stop ⇒ wait_poll always resolves
        }
        self.release_due_commits(); // stop ⇒ releases everything
        for c in self.conns.values_mut() {
            if c.wpos < c.wbuf.len() {
                c.stream.set_nonblocking(false).ok();
                c.stream
                    .set_write_timeout(Some(Duration::from_millis(500)))
                    .ok();
                let _ = c.stream.write_all(&c.wbuf[c.wpos..]);
            }
        }
    }
}

/// The key a blocking op parks on and the epoch it is fenced at.
fn blocking_target(op: &Request) -> (String, u64) {
    match op {
        Request::Wait { key } => (key.clone(), u64::MAX),
        Request::WaitEpoch { key, epoch } => (key.clone(), *epoch),
        Request::ClaimRestore { epoch, tag } => (restore_key(*epoch, *tag), *epoch),
        _ => unreachable!("not a blocking op"),
    }
}
