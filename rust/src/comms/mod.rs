//! Communication substrate: wire protocol, TCP key-value store (the
//! TCPStore used during communication-group establishment), DP/TP/PP
//! communication-group derivation, and in-process synchronous
//! collectives for the DP training engine.

pub mod collective;
pub mod group;
pub mod tcp_store;
pub mod wire;

pub use collective::{Collective, CollectiveError};
pub use group::{CommGroup, GroupId, GroupKind, GroupSet, RekeyStats};
pub use tcp_store::{establish, FencedWait, TcpStoreClient, TcpStoreServer};
