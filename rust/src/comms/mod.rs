//! Communication substrate: wire protocol, TCP key-value store (the
//! TCPStore used during communication-group establishment), DP/TP/PP
//! communication-group derivation, in-process synchronous collectives
//! for the DP training engine, and the epoch-fenced state-stream
//! protocol that ships model-state shards between replicas during
//! checkpoint-free recovery (DESIGN.md §9), plus the replicated
//! coordination plane and its endpoint-set client API (DESIGN.md §13).

pub mod collective;
pub mod group;
pub mod link;
pub mod netem;
#[cfg(target_os = "linux")]
mod reactor;
pub mod replication;
pub mod state_stream;
pub mod store_bench;
pub mod tcp_store;
pub mod wire;

pub use collective::{Collective, CollectiveError};
pub use group::{CommGroup, GroupId, GroupKind, GroupSet, RekeyStats};
pub use link::{default_dialer, jittered, Dialer, DirectDialer, Link};
pub use netem::{
    ImpairedLink, LinkPolicy, NetemDialer, NetemMap, NetemProxy, Partition,
};
pub use replication::{
    repl_status, ReplStatusInfo, ReplicaSet, Replicator, StoreEndpoints,
    StoreRole, StoreSession, REPL_LINK_SRC,
};
pub use state_stream::{
    fetch_blob, fetch_from_addr, fetch_from_addr_via, fetch_snapshot, serve_blob,
    serve_snapshot, transfer_tag, EpochFence, Expect, RestoreError, RestoreResult,
    StreamConfig,
};
pub use tcp_store::{
    decode_beats, establish, establish_via, BeatRecord, FencedWait, StoreCore,
    TcpStoreClient, TcpStoreServer,
};
pub use wire::{Bytes, Request, Response};
