//! Communication substrate: wire protocol, TCP key-value store (the
//! TCPStore used during communication-group establishment), and
//! in-process synchronous collectives for the DP training engine.

pub mod collective;
pub mod tcp_store;
pub mod wire;

pub use collective::{Collective, CollectiveError};
pub use tcp_store::{establish, TcpStoreClient, TcpStoreServer};
