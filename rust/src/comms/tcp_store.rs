//! A real TCP key-value store — the PyTorch `TCPStore` analogue used
//! during communication-group establishment (paper §III-D).
//!
//! The server is thread-per-connection (adequate at single-host scale);
//! clients support `set`/`get`/`wait`/`add`/`count`. `wait` blocks
//! server-side on a condvar until the key is published — exactly how
//! rank 0 publishes the rendezvous info that other ranks wait on.
//!
//! [`establish`] measures store-establishment for `n` clients with a
//! configurable parallelism degree: `p = 1` is the serialized baseline
//! of Fig. 10, `p > 1` is FlashRecovery's parallelized strategy.

use super::wire::{read_frame, write_frame, Request, Response};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Lock a store mutex, recovering from poisoning: one panicking
/// handler thread must degrade to at worst a stale value for *its*
/// client, never cascade panics into every later request (the map is
/// plain data — there is no invariant a partial update could tear
/// that the wire protocol does not already tolerate).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One worker's latest heartbeat as the store recorded it. `at` is the
/// server's receive clock — lease math never trusts sender timestamps.
#[derive(Debug, Clone, Copy)]
pub struct BeatRecord {
    pub rank: u64,
    pub incarnation: u64,
    pub step_tag: i64,
    pub device_code: i64,
    pub at: Instant,
}

#[derive(Default)]
struct Shared {
    map: Mutex<HashMap<String, Vec<u8>>>,
    counters: Mutex<HashMap<String, i64>>,
    /// rank -> latest heartbeat (highest incarnation wins).
    beats: Mutex<HashMap<u64, BeatRecord>>,
    cv: Condvar,
    hellos: AtomicU64,
    /// Rendezvous epoch: fenced waiters registered at an older epoch
    /// are released with `EpochFenced` when this advances.
    epoch: AtomicU64,
    /// Total requests served (all opcodes) — lets tests assert that
    /// rebuild traffic is independent of cluster size.
    requests: AtomicU64,
}

/// The store server. Dropping it shuts the listener down.
pub struct TcpStoreServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TcpStoreServer {
    /// Bind on 127.0.0.1 with an OS-assigned port.
    pub fn start() -> Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0").context("bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared::default());
        let stop = Arc::new(AtomicBool::new(false));

        let accept_shared = shared.clone();
        let accept_stop = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            let mut workers: Vec<JoinHandle<()>> = Vec::new();
            while !accept_stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let sh = accept_shared.clone();
                        let st = accept_stop.clone();
                        workers.push(std::thread::spawn(move || {
                            let _ = serve_connection(stream, sh, st);
                        }));
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(_) => break,
                }
            }
            for w in workers {
                let _ = w.join();
            }
        });

        Ok(TcpStoreServer { addr, shared, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of Hello handshakes seen (establishment bookkeeping).
    pub fn hello_count(&self) -> u64 {
        self.shared.hellos.load(Ordering::Relaxed)
    }

    /// Number of keys currently stored.
    pub fn key_count(&self) -> usize {
        lock(&self.shared.map).len()
    }

    /// Number of live barrier/arrive counters (pruned with the map's
    /// per-epoch keys on epoch advance).
    pub fn counter_count(&self) -> usize {
        lock(&self.shared.counters).len()
    }

    /// Snapshot of every rank's latest heartbeat record — what the
    /// controller-side [`crate::coordinator::LeaseMonitor`] consumes
    /// each scan.
    pub fn beats(&self) -> Vec<BeatRecord> {
        lock(&self.shared.beats).values().copied().collect()
    }

    /// Current rendezvous epoch (advanced by `AdvanceEpoch`).
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::SeqCst)
    }

    /// Total requests served since start (all clients, all opcodes).
    pub fn request_count(&self) -> u64 {
        self.shared.requests.load(Ordering::Relaxed)
    }
}

impl Drop for TcpStoreServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake any `wait`ers so their handler threads can observe stop.
        self.shared.cv.notify_all();
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn serve_connection(
    mut stream: TcpStream,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .ok();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let body = match read_frame(&mut stream) {
            Ok(b) => b,
            Err(e) => {
                // timeout -> poll the stop flag; EOF/reset -> done
                if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                    if matches!(
                        ioe.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut
                    ) {
                        continue;
                    }
                }
                return Ok(());
            }
        };
        let req = Request::decode(&body)?;
        let resp = handle(&shared, &stop, req);
        write_frame(&mut stream, &resp.encode())?;
    }
}

fn handle(shared: &Shared, stop: &AtomicBool, req: Request) -> Response {
    shared.requests.fetch_add(1, Ordering::Relaxed);
    match req {
        Request::Hello { .. } => {
            shared.hellos.fetch_add(1, Ordering::Relaxed);
            Response::HelloAck
        }
        Request::Set { key, value } => {
            lock(&shared.map).insert(key, value);
            shared.cv.notify_all();
            Response::Ok
        }
        Request::Get { key } => match lock(&shared.map).get(&key) {
            Some(v) => Response::Value(v.clone()),
            None => Response::NotFound,
        },
        Request::Wait { key } => {
            let mut map = lock(&shared.map);
            loop {
                if let Some(v) = map.get(&key) {
                    return Response::Value(v.clone());
                }
                if stop.load(Ordering::Relaxed) {
                    return Response::NotFound;
                }
                let (guard, _timeout) = shared
                    .cv
                    .wait_timeout(map, Duration::from_millis(100))
                    .unwrap_or_else(PoisonError::into_inner);
                map = guard;
            }
        }
        Request::Add { key, delta } => {
            let mut counters = lock(&shared.counters);
            let v = counters.entry(key).or_insert(0);
            *v += delta;
            Response::Counter(*v)
        }
        Request::Count => Response::CountIs(lock(&shared.map).len() as u64),
        Request::WaitEpoch { key, epoch } => fenced_wait(shared, stop, &key, epoch),
        Request::AdvanceEpoch { to } => {
            let prev = shared.epoch.fetch_max(to, Ordering::SeqCst);
            let current = prev.max(to);
            prune_stale_epochs(shared, current);
            // Wake every blocked waiter so stale fenced waits observe
            // the new epoch and return `EpochFenced`.
            shared.cv.notify_all();
            Response::Counter(current as i64)
        }
        Request::AdvertiseRestore { epoch, tag, addr } => {
            let current = shared.epoch.load(Ordering::SeqCst);
            if current > epoch {
                // the restore this source belongs to is already stale
                return Response::EpochFenced { current };
            }
            lock(&shared.map).insert(restore_key(epoch, tag), addr.into_bytes());
            shared.cv.notify_all();
            Response::Ok
        }
        Request::ClaimRestore { epoch, tag } => {
            fenced_wait(shared, stop, &restore_key(epoch, tag), epoch)
        }
        Request::AbortEpoch { unless_key, tombstone_key, tombstone, to } => {
            // Atomic with `Set` and the fenced waits (all serialize on
            // the map mutex): either the release key landed first and
            // the abort is a no-op, or the epoch is fenced before any
            // waiter can observe the late release — never a mix.
            let mut map = lock(&shared.map);
            if map.contains_key(&unless_key) {
                Response::Counter(0)
            } else {
                map.insert(tombstone_key, tombstone);
                let prev = shared.epoch.fetch_max(to, Ordering::SeqCst);
                drop(map);
                prune_stale_epochs(shared, prev.max(to));
                shared.cv.notify_all();
                Response::Counter(1)
            }
        }
        Request::Heartbeat { rank, incarnation, step_tag, device_code } => {
            let mut beats = lock(&shared.beats);
            let rec = BeatRecord { rank, incarnation, step_tag, device_code, at: Instant::now() };
            match beats.get(&rank) {
                // a stale incarnation must never refresh its
                // replacement's lease
                Some(old) if old.incarnation > incarnation => {}
                _ => {
                    beats.insert(rank, rec);
                }
            }
            Response::Ok
        }
        Request::DelPrefix { prefix } => {
            let mut removed = 0i64;
            let mut map = lock(&shared.map);
            map.retain(|k, _| {
                let keep = !k.starts_with(&prefix);
                removed += i64::from(!keep);
                keep
            });
            drop(map);
            let mut counters = lock(&shared.counters);
            counters.retain(|k, _| {
                let keep = !k.starts_with(&prefix);
                removed += i64::from(!keep);
                keep
            });
            Response::Counter(removed)
        }
    }
}

/// Drop every per-epoch rendezvous/restore key (and arrive counter)
/// for epochs `<= current - 2`. Only epoch `e-1` is ever needed for
/// late resync (DESIGN.md §8), so epoch advance keeps the store's key
/// count bounded by two epochs' worth instead of leaking one key set
/// per recovery forever.
fn prune_stale_epochs(shared: &Shared, current: u64) {
    let keep_from = current.saturating_sub(1);
    let stale = |key: &str| -> bool {
        for prefix in ["rdzv/", "restore/"] {
            if let Some(rest) = key.strip_prefix(prefix) {
                if let Some((e, _)) = rest.split_once('/') {
                    if let Ok(e) = e.parse::<u64>() {
                        return e < keep_from;
                    }
                }
            }
        }
        false
    };
    lock(&shared.map).retain(|k, _| !stale(k));
    lock(&shared.counters).retain(|k, _| !stale(k));
}

/// Store key under which a restore source's endpoint is advertised.
fn restore_key(epoch: u64, tag: u64) -> String {
    format!("restore/{epoch}/{tag:016x}")
}

/// Block until `key` is published or the rendezvous epoch passes
/// `epoch` — the shared body of `WaitEpoch` and `ClaimRestore`.
fn fenced_wait(shared: &Shared, stop: &AtomicBool, key: &str, epoch: u64) -> Response {
    let mut map = lock(&shared.map);
    loop {
        let current = shared.epoch.load(Ordering::SeqCst);
        if current > epoch {
            return Response::EpochFenced { current };
        }
        if let Some(v) = map.get(key) {
            return Response::Value(v.clone());
        }
        if stop.load(Ordering::Relaxed) {
            return Response::NotFound;
        }
        let (guard, _timeout) = shared
            .cv
            .wait_timeout(map, Duration::from_millis(100))
            .unwrap_or_else(PoisonError::into_inner);
        map = guard;
    }
}

/// Outcome of an epoch-fenced wait: the published value, or notice
/// that the rendezvous epoch moved past the one waited on. The latter
/// is retryable — re-issue the wait at `current`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FencedWait {
    Value(Vec<u8>),
    Superseded { current: u64 },
}

/// Client connection to the store.
pub struct TcpStoreClient {
    stream: TcpStream,
    ops: u64,
}

impl TcpStoreClient {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect_timeout(&addr, Duration::from_secs(10))?;
        stream.set_nodelay(true).ok();
        Ok(TcpStoreClient { stream, ops: 0 })
    }

    /// Requests sent over this connection since connect — the quantity
    /// the rendezvous protocol keeps O(1) per surviving node.
    pub fn ops_sent(&self) -> u64 {
        self.ops
    }

    fn call(&mut self, req: Request) -> Result<Response> {
        self.ops += 1;
        write_frame(&mut self.stream, &req.encode())?;
        let body = read_frame(&mut self.stream)?;
        Response::decode(&body)
    }

    /// Handshake; returns once the server acknowledged.
    pub fn hello(&mut self, client_id: u64) -> Result<()> {
        match self.call(Request::Hello { client_id })? {
            Response::HelloAck => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn set(&mut self, key: &str, value: &[u8]) -> Result<()> {
        match self.call(Request::Set { key: key.into(), value: value.into() })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn get(&mut self, key: &str) -> Result<Option<Vec<u8>>> {
        match self.call(Request::Get { key: key.into() })? {
            Response::Value(v) => Ok(Some(v)),
            Response::NotFound => Ok(None),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Block until `key` is published.
    pub fn wait(&mut self, key: &str) -> Result<Vec<u8>> {
        // waits can exceed the default read path; use a long timeout
        self.stream.set_read_timeout(Some(Duration::from_secs(300)))?;
        match self.call(Request::Wait { key: key.into() })? {
            Response::Value(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Block until `key` is published or the store's rendezvous epoch
    /// advances past `epoch` (a rebuild superseded this wait). Unlike
    /// [`Self::wait`], a stale waiter is *released* with
    /// [`FencedWait::Superseded`] rather than left hanging.
    pub fn wait_epoch(&mut self, key: &str, epoch: u64) -> Result<FencedWait> {
        self.stream.set_read_timeout(Some(Duration::from_secs(300)))?;
        match self.call(Request::WaitEpoch { key: key.into(), epoch })? {
            Response::Value(v) => Ok(FencedWait::Value(v)),
            Response::EpochFenced { current } => {
                Ok(FencedWait::Superseded { current })
            }
            Response::NotFound => bail!("store shut down during fenced wait"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Advance the store's rendezvous epoch (monotonic max); returns
    /// the epoch after the advance. Releases all stale fenced waiters.
    pub fn advance_epoch(&mut self, to: u64) -> Result<u64> {
        match self.call(Request::AdvanceEpoch { to })? {
            Response::Counter(v) => Ok(v as u64),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Advertise this client's endpoint as the restore source for one
    /// state transfer (`tag` packs shard + source rank). Returns
    /// `None` on success, or `Some(current)` when the epoch has
    /// already moved past `epoch` (stale — replan the restore).
    pub fn advertise_restore(
        &mut self,
        epoch: u64,
        tag: u64,
        addr: &str,
    ) -> Result<Option<u64>> {
        let req = Request::AdvertiseRestore { epoch, tag, addr: addr.into() };
        match self.call(req)? {
            Response::Ok => Ok(None),
            Response::EpochFenced { current } => Ok(Some(current)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Claim the restore source advertised for `tag`: blocks until the
    /// advertisement lands or the epoch supersedes the claim (then
    /// released retryably, never left hanging).
    pub fn claim_restore(&mut self, epoch: u64, tag: u64) -> Result<FencedWait> {
        self.stream.set_read_timeout(Some(Duration::from_secs(300)))?;
        match self.call(Request::ClaimRestore { epoch, tag })? {
            Response::Value(v) => Ok(FencedWait::Value(v)),
            Response::EpochFenced { current } => {
                Ok(FencedWait::Superseded { current })
            }
            Response::NotFound => bail!("store shut down during restore claim"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Atomically abort an epoch unless its release key was already
    /// published (the supervised-barrier watchdog's weapon). Returns
    /// true when the abort happened, false when the barrier won.
    pub fn abort_epoch_unless(
        &mut self,
        unless_key: &str,
        tombstone_key: &str,
        tombstone: &[u8],
        to: u64,
    ) -> Result<bool> {
        let req = Request::AbortEpoch {
            unless_key: unless_key.into(),
            tombstone_key: tombstone_key.into(),
            tombstone: tombstone.to_vec(),
            to,
        };
        match self.call(req)? {
            Response::Counter(v) => Ok(v == 1),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Push one liveness beat for `(rank, incarnation)`. Fire-and-ack:
    /// one round trip, O(1) payload — the per-worker cost the
    /// detection-latency bench asserts is scale-independent.
    pub fn heartbeat(
        &mut self,
        rank: u64,
        incarnation: u64,
        step_tag: i64,
        device_code: i64,
    ) -> Result<()> {
        let req = Request::Heartbeat { rank, incarnation, step_tag, device_code };
        match self.call(req)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Delete every key (and counter) starting with `prefix`; returns
    /// how many entries were removed.
    pub fn del_prefix(&mut self, prefix: &str) -> Result<i64> {
        match self.call(Request::DelPrefix { prefix: prefix.into() })? {
            Response::Counter(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn add(&mut self, key: &str, delta: i64) -> Result<i64> {
        match self.call(Request::Add { key: key.into(), delta })? {
            Response::Counter(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn count(&mut self) -> Result<u64> {
        match self.call(Request::Count)? {
            Response::CountIs(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }
}

/// Establish `n` store clients with parallelism degree `p` and return
/// (elapsed, clients). Each establishment = TCP connect + Hello RTT,
/// matching the per-rank TCPStore cost the paper parallelizes.
pub fn establish(
    addr: SocketAddr,
    n: usize,
    p: usize,
) -> Result<(Duration, Vec<TcpStoreClient>)> {
    let p = p.clamp(1, n.max(1));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for worker in 0..p {
        let count = n / p + usize::from(worker < n % p);
        handles.push(std::thread::spawn(move || -> Result<Vec<TcpStoreClient>> {
            let mut out = Vec::with_capacity(count);
            for i in 0..count {
                let mut c = TcpStoreClient::connect(addr)?;
                c.hello((worker * 1_000_000 + i) as u64)?;
                out.push(c);
            }
            Ok(out)
        }));
    }
    let mut clients = Vec::with_capacity(n);
    for h in handles {
        clients.extend(h.join().expect("establish worker panicked")?);
    }
    Ok((t0.elapsed(), clients))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        assert_eq!(c.get("missing").unwrap(), None);
        c.set("k", b"hello").unwrap();
        assert_eq!(c.get("k").unwrap().as_deref(), Some(&b"hello"[..]));
        assert_eq!(c.count().unwrap(), 1);
    }

    #[test]
    fn wait_blocks_until_set() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.wait("late").unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.set("late", b"v").unwrap();
        assert_eq!(waiter.join().unwrap(), b"v");
    }

    #[test]
    fn add_is_atomic_across_clients() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let mut handles = Vec::new();
        for _ in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut c = TcpStoreClient::connect(addr).unwrap();
                for _ in 0..25 {
                    c.add("ctr", 1).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut c = TcpStoreClient::connect(addr).unwrap();
        assert_eq!(c.add("ctr", 0).unwrap(), 100);
    }

    #[test]
    fn establish_counts_hellos() {
        let server = TcpStoreServer::start().unwrap();
        let (_elapsed, clients) = establish(server.addr(), 16, 4).unwrap();
        assert_eq!(clients.len(), 16);
        assert_eq!(server.hello_count(), 16);
    }

    #[test]
    fn establish_serial_equals_parallel_results() {
        let server = TcpStoreServer::start().unwrap();
        let (_t1, c1) = establish(server.addr(), 10, 1).unwrap();
        let (_t2, c2) = establish(server.addr(), 10, 10).unwrap();
        assert_eq!(c1.len(), 10);
        assert_eq!(c2.len(), 10);
        assert_eq!(server.hello_count(), 20);
    }

    #[test]
    fn epoch_bump_releases_stale_fenced_waiters() {
        // A rebuild epoch bump must release waiters fenced at an older
        // epoch with a retryable outcome — not leave them hanging.
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let t0 = Instant::now();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.wait_epoch("rdzv/1/delta", 1).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut c = TcpStoreClient::connect(addr).unwrap();
        assert_eq!(c.advance_epoch(2).unwrap(), 2);
        let out = waiter.join().unwrap();
        assert_eq!(out, FencedWait::Superseded { current: 2 });
        assert!(t0.elapsed() < Duration::from_secs(10), "waiter hung");
        assert_eq!(server.epoch(), 2);
    }

    #[test]
    fn fenced_wait_delivers_value_at_current_epoch() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.advance_epoch(3).unwrap();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            // fenced at the *current* epoch: must behave like wait()
            c.wait_epoch("rdzv/3/delta", 3).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        c.set("rdzv/3/delta", b"subs").unwrap();
        assert_eq!(waiter.join().unwrap(), FencedWait::Value(b"subs".to_vec()));
    }

    #[test]
    fn advance_epoch_is_monotonic_max() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        assert_eq!(c.advance_epoch(5).unwrap(), 5);
        // going backwards is a no-op, not a rollback
        assert_eq!(c.advance_epoch(2).unwrap(), 5);
        assert_eq!(server.epoch(), 5);
    }

    #[test]
    fn client_counts_ops_sent() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        assert_eq!(c.ops_sent(), 0);
        c.hello(7).unwrap();
        c.set("k", b"v").unwrap();
        c.get("k").unwrap();
        assert_eq!(c.ops_sent(), 3);
        assert!(server.request_count() >= 3);
    }

    #[test]
    fn restore_claim_blocks_until_advertised() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let claimer = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.claim_restore(3, 0xABC).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.advance_epoch(3).unwrap();
        assert_eq!(c.advertise_restore(3, 0xABC, "10.0.0.1:9").unwrap(), None);
        assert_eq!(
            claimer.join().unwrap(),
            FencedWait::Value(b"10.0.0.1:9".to_vec())
        );
    }

    #[test]
    fn restore_claim_released_retryably_by_epoch_bump() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let claimer = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            // claims a source that will never advertise (it died)
            c.claim_restore(1, 0x42).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.advance_epoch(2).unwrap();
        assert_eq!(
            claimer.join().unwrap(),
            FencedWait::Superseded { current: 2 }
        );
    }

    #[test]
    fn abort_epoch_unless_is_atomic_with_release() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        // release key present -> abort refused, nothing changes
        c.set("ep1/go", b"go").unwrap();
        assert!(!c
            .abort_epoch_unless("ep1/go", "ep2/delta", b"!abort", 2)
            .unwrap());
        assert_eq!(server.epoch(), 0);
        assert_eq!(c.get("ep2/delta").unwrap(), None);
        // release key absent -> tombstone published + epoch fenced
        assert!(c
            .abort_epoch_unless("ep2/go", "ep3/delta", b"!abort", 3)
            .unwrap());
        assert_eq!(server.epoch(), 3);
        assert_eq!(
            c.get("ep3/delta").unwrap().as_deref(),
            Some(&b"!abort"[..])
        );
    }

    #[test]
    fn stale_advertisement_is_fenced() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.advance_epoch(7).unwrap();
        // advertising for an already-superseded epoch is rejected
        assert_eq!(
            c.advertise_restore(6, 0x1, "10.0.0.2:9").unwrap(),
            Some(7)
        );
        // the current epoch is accepted
        assert_eq!(c.advertise_restore(7, 0x1, "10.0.0.2:9").unwrap(), None);
    }

    #[test]
    fn server_shutdown_releases_fenced_waiters() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            // shutdown surfaces as an error, not a hang
            c.wait_epoch("never", 0)
        });
        std::thread::sleep(Duration::from_millis(50));
        drop(server);
        assert!(waiter.join().unwrap().is_err());
    }

    #[test]
    fn poisoned_map_still_answers_requests() {
        // Regression (DESIGN §10 hardening): a panicking handler
        // thread used to poison the map mutex and turn every later
        // `.lock().unwrap()` into a cascading panic — one bad client
        // killed the whole control plane. The guard is now recovered.
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.set("pre", b"survives").unwrap();

        let sh = server.shared.clone();
        let _ = std::thread::spawn(move || {
            let _guard = sh.map.lock().unwrap();
            panic!("poison the map mutex (expected panic)");
        })
        .join();
        assert!(server.shared.map.is_poisoned(), "setup: mutex must be poisoned");

        assert_eq!(c.get("pre").unwrap().as_deref(), Some(&b"survives"[..]));
        c.set("post", b"v").unwrap();
        assert_eq!(c.get("post").unwrap().as_deref(), Some(&b"v"[..]));
        assert_eq!(server.key_count(), 2);
        // fenced waits cross the same mutex + condvar
        c.advance_epoch(1).unwrap();
        assert_eq!(
            c.wait_epoch("absent", 0).unwrap(),
            FencedWait::Superseded { current: 1 }
        );
    }

    #[test]
    fn heartbeat_upserts_latest_beat_per_rank() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.heartbeat(3, 1, 7, -1).unwrap();
        c.heartbeat(3, 1, 8, -1).unwrap();
        c.heartbeat(9, 2, 0, 4).unwrap();
        let beats = server.beats();
        assert_eq!(beats.len(), 2);
        let b3 = beats.iter().find(|b| b.rank == 3).unwrap();
        assert_eq!((b3.incarnation, b3.step_tag, b3.device_code), (1, 8, -1));
        let b9 = beats.iter().find(|b| b.rank == 9).unwrap();
        assert_eq!((b9.incarnation, b9.step_tag, b9.device_code), (2, 0, 4));
    }

    #[test]
    fn stale_incarnation_beat_cannot_refresh_replacement_lease() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.heartbeat(5, 2, 10, -1).unwrap(); // replacement, incarnation 2
        c.heartbeat(5, 1, 99, -1).unwrap(); // zombie predecessor
        let beats = server.beats();
        let b = beats.iter().find(|b| b.rank == 5).unwrap();
        assert_eq!((b.incarnation, b.step_tag), (2, 10));
    }

    #[test]
    fn del_prefix_removes_keys_and_counters() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.set("rdzv/1/delta", b"d").unwrap();
        c.set("rdzv/1/table", b"t").unwrap();
        c.set("rdzv/2/delta", b"d").unwrap();
        c.add("rdzv/1/arrived", 1).unwrap();
        assert_eq!(c.del_prefix("rdzv/1/").unwrap(), 3);
        assert_eq!(c.get("rdzv/1/delta").unwrap(), None);
        assert_eq!(c.get("rdzv/2/delta").unwrap().as_deref(), Some(&b"d"[..]));
        assert_eq!(c.del_prefix("nothing/").unwrap(), 0);
    }

    #[test]
    fn epoch_advance_prunes_epochs_two_behind() {
        // DESIGN §8 known limitation, resolved: per-epoch keys used to
        // be retained forever. Advancing to epoch e drops every
        // rdzv/restore key of epochs <= e-2; e and e-1 (late resync)
        // stay.
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        for e in 1..=4u64 {
            c.set(&format!("rdzv/{e}/delta"), b"d").unwrap();
            c.set(&format!("rdzv/{e}/table"), b"t").unwrap();
            c.set(&format!("restore/{e}/00ff"), b"a").unwrap();
            c.add(&format!("rdzv/{e}/arrived"), 1).unwrap();
        }
        c.set("ranktable/v1", b"keep").unwrap();
        c.advance_epoch(4).unwrap();
        // epochs 1 and 2 pruned, 3 and 4 retained, non-epoch keys kept
        assert_eq!(c.get("rdzv/1/delta").unwrap(), None);
        assert_eq!(c.get("rdzv/2/table").unwrap(), None);
        assert_eq!(c.get("restore/2/00ff").unwrap(), None);
        assert!(c.get("rdzv/3/delta").unwrap().is_some());
        assert!(c.get("rdzv/4/table").unwrap().is_some());
        assert!(c.get("ranktable/v1").unwrap().is_some());
        assert_eq!(server.key_count(), 1 + 2 * 3);
        assert_eq!(server.counter_count(), 2);
    }

    #[test]
    fn server_shutdown_releases_waiters() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            // will get NotFound when the server shuts down
            let _ = c.wait("never");
        });
        std::thread::sleep(Duration::from_millis(50));
        drop(server);
        waiter.join().unwrap();
    }
}
