//! A real TCP key-value store — the PyTorch `TCPStore` analogue used
//! during communication-group establishment (paper §III-D) and, since
//! §8–§10, the single funnel for rendezvous, restore discovery, and
//! leased heartbeats.
//!
//! Data plane (DESIGN.md §11): state is sharded into [`STRIPES`] lock
//! stripes keyed by key hash (beats by rank), so unrelated keys never
//! contend; blocked `wait`s park on **per-key slots**, so a `Set`
//! wakes exactly the waiters of that key instead of broadcasting to
//! every blocked rank (epoch advances and shutdown are the only
//! broadcasts). Values are stored as [`Bytes`] (`Arc<[u8]>`) — a
//! `Get`/`Wait` response is a refcount bump, never a deep copy — and
//! each connection reuses one read and one write buffer.
//!
//! Serving core (DESIGN.md §14): on Linux the default
//! [`StoreCore::Reactor`] serves *every* connection from one
//! readiness-driven event loop (`comms/reactor`, epoll vendored in
//! `util/epoll`) — nonblocking sockets, per-connection read/write
//! state machines, and blocked waiters parked as *entries* on the
//! same per-key slots, so 65k clients cost one thread instead of 65k.
//! [`StoreCore::Threads`] keeps the PR 5 token-accounted worker pool
//! (one thread per concurrently *active* connection) as the portable
//! fallback and the bench comparison baseline. Both cores share the
//! wire loop's semantics bit-for-bit: same opcodes, same `Batch`
//! stop rules, same replication log shipping, same trace trailers.
//!
//! [`establish`] measures store-establishment for `n` clients with a
//! configurable parallelism degree: `p = 1` is the serialized baseline
//! of Fig. 10, `p > 1` is FlashRecovery's parallelized strategy.

use super::replication::{
    DedupMap, Replicator, StoreEndpoints, ROLE_PRIMARY, ROLE_REPLICA,
};
use super::wire::{
    read_frame, write_frame, Bytes, Request, Response, MAX_FRAME_BYTES,
};
use crate::telemetry::{trace, Counter, Gauge, Registry, Snapshot, TraceCtx};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{ErrorKind, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Lock stripes for `map`/`counters`/`parked` (and, by rank, `beats`).
/// Power of two; 16 keeps per-stripe contention negligible at the
/// 8192-simulated-client sweep while the array stays cache-friendly.
const STRIPES: usize = 16;

/// Lock a store mutex, recovering from poisoning: one panicking
/// handler thread must degrade to at worst a stale value for *its*
/// client, never cascade panics into every later request (the map is
/// plain data — there is no invariant a partial update could tear
/// that the wire protocol does not already tolerate).
pub(super) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One worker's latest heartbeat as the store recorded it. `at` is the
/// server's receive clock — lease math never trusts sender timestamps.
#[derive(Debug, Clone, Copy)]
pub struct BeatRecord {
    pub rank: u64,
    pub incarnation: u64,
    pub step_tag: i64,
    pub device_code: i64,
    pub at: Instant,
}

/// Waiters parked on one key. The threaded core parks *threads*: they
/// wait on this slot's condvar (with the owning stripe's mutex), so a
/// `Set` of the key notifies exactly them. The reactor core parks
/// *entries*: `entries` holds the ids of suspended frame state
/// machines, and a `Set` enqueues exactly those ids onto the wakeup
/// queue the event loop drains. A slot lives while either population
/// is non-empty.
pub(super) struct WaitSlot {
    pub(super) cv: Arc<Condvar>,
    pub(super) waiters: usize,
    pub(super) entries: Vec<u64>,
}

impl WaitSlot {
    fn new() -> Self {
        WaitSlot { cv: Arc::new(Condvar::new()), waiters: 0, entries: Vec::new() }
    }
}

/// One lock stripe's worth of store state.
#[derive(Default)]
pub(super) struct Stripe {
    pub(super) map: HashMap<String, Bytes>,
    pub(super) counters: HashMap<String, i64>,
    pub(super) parked: HashMap<String, WaitSlot>,
}

impl Default for WaitSlot {
    fn default() -> Self {
        Self::new()
    }
}

/// A publish event the reactor core must fan out to parked entries.
/// Pushed by `set_value` (exactly the touched key) and `wake_all`
/// (epoch advance / shutdown broadcast), drained by the event loop.
/// The threaded core never enqueues (no parked entries exist there),
/// so the queue is free when unused.
pub(super) enum WakeEvent {
    Key(String),
    All,
}

pub(super) struct Shared {
    pub(super) stripes: Vec<Mutex<Stripe>>,
    /// rank % STRIPES -> (rank -> latest heartbeat; highest
    /// incarnation wins).
    beats: Vec<Mutex<HashMap<u64, BeatRecord>>>,
    /// Per-server metrics registry (DESIGN.md §12) — served verbatim
    /// by the `Stats` wire op. Per-server (not the process-global
    /// registry) so parallel test servers never share counters. The
    /// fields below are cached handles into it: the hot path updates
    /// an atomic cell, never a name map.
    metrics: Registry,
    hellos: Counter,
    /// Rendezvous epoch: fenced waiters registered at an older epoch
    /// are released with `EpochFenced` when this advances. Protocol
    /// state, not a metric (fence checks need SeqCst ordering) — the
    /// snapshot mirrors it as a gauge.
    pub(super) epoch: AtomicU64,
    /// Logical requests served (each batched sub-op counts as one) —
    /// lets tests assert that rebuild traffic is independent of
    /// cluster size even when ops are pipelined.
    pub(super) requests: Counter,
    /// Wire frames read (a `Batch` of k ops is one frame) — the
    /// round-trip count the pipelined client amortises.
    pub(super) frames: Counter,
    /// Parked waiters *released by a publish* (the waiter parked at
    /// least once, then found its key's value). Deliberately not a
    /// raw condvar-notify count — notifies race timeout boundaries
    /// and spurious wakeups, so only the deterministic observable is
    /// counted: per-key parking makes this exactly the matching
    /// waiters per publish, never the whole herd.
    pub(super) wakeups: Counter,
    /// Waiters currently parked — threads (threaded core) plus
    /// suspended entries (reactor core). Maintained incrementally
    /// (inc on park, dec on wake/fence/abort) so a `Stats` poll
    /// mid-episode is O(1) instead of a walk over every stripe's
    /// parked map.
    pub(super) parked: Gauge,
    /// Open connections registered with the serving core — reactor
    /// registrations, or queued/served sockets under the pool. The
    /// churn leak test asserts this returns to baseline.
    pub(super) registrations: Gauge,
    /// Peak store-serving threads (1 for the reactor; 1 + the worker
    /// high-water mark for the pool) — the "65k clients ≤ cores +
    /// constant threads" gate reads this off a `Stats` snapshot.
    pub(super) core_threads: Gauge,
    /// Publish events awaiting reactor fan-out (see [`WakeEvent`]).
    pub(super) pending_wakes: Mutex<Vec<WakeEvent>>,
    /// Reactor wake hook (an eventfd write): lets `wake_all` callers
    /// on foreign threads (server `Drop`) rouse the event loop out of
    /// `epoll_wait`. `None` under the threaded core.
    pub(super) reactor_waker: Mutex<Option<Arc<dyn Fn() + Send + Sync>>>,
    /// Pool workers currently alive, and total ever spawned.
    live_workers: Gauge,
    /// Readiness tokens: each pool worker announces one token per
    /// "ready for one connection" cycle; the accept loop consumes one
    /// token per accepted connection and spawns a fresh worker when
    /// none is available. Token conservation guarantees every queued
    /// connection has a committed consumer — a busy pool can never
    /// starve a new connection behind long-blocked peers. Functional
    /// state (the spawn decision runs a checked-sub CAS on it), so it
    /// stays a raw atomic rather than a registry gauge.
    free_workers: AtomicUsize,
    workers_spawned: Counter,
    /// [`ROLE_PRIMARY`] (the backward-compatible default — a lone
    /// server serves everything) or [`ROLE_REPLICA`] (mutations are
    /// refused with `NotPrimary`; only `Replicate` frames from the
    /// primary mutate state). Flipped by `Promote` / `set_replica`.
    pub(super) role: AtomicU8,
    /// Highest replication log index applied on this node. On the
    /// primary it advances as ops are logged; on a replica, as
    /// `Replicate` frames apply. Discovery compares it (after the
    /// epoch) to elect the most advanced replica.
    pub(super) applied: AtomicU64,
    /// Exactly-once cache for `Dedup`-wrapped ops, replicated via
    /// `DedupDone` log entries so replays are refused across failover.
    pub(super) dedup: Mutex<DedupMap>,
    /// The primary's log shipper (None = un-replicated: the entire
    /// replication path is skipped, zero added overhead).
    pub(super) repl: Mutex<Option<Arc<Replicator>>>,
}

impl Shared {
    fn new() -> Self {
        let metrics = Registry::new();
        let hellos = metrics.counter("store.hellos");
        let requests = metrics.counter("store.requests");
        let frames = metrics.counter("store.frames");
        let wakeups = metrics.counter("store.wakeups");
        let parked = metrics.gauge("store.parked_waiters");
        let registrations = metrics.gauge("store.registrations");
        let core_threads = metrics.gauge("store.core_threads");
        let live_workers = metrics.gauge("store.live_workers");
        let workers_spawned = metrics.counter("store.workers_spawned");
        Shared {
            stripes: (0..STRIPES).map(|_| Mutex::new(Stripe::default())).collect(),
            beats: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
            metrics,
            hellos,
            epoch: AtomicU64::new(0),
            requests,
            frames,
            wakeups,
            parked,
            registrations,
            core_threads,
            pending_wakes: Mutex::new(Vec::new()),
            reactor_waker: Mutex::new(None),
            live_workers,
            free_workers: AtomicUsize::new(0),
            workers_spawned,
            role: AtomicU8::new(ROLE_PRIMARY),
            applied: AtomicU64::new(0),
            dedup: Mutex::new(DedupMap::new()),
            repl: Mutex::new(None),
        }
    }

    /// Registry snapshot plus the derived levels (key/counter
    /// populations, epoch) refreshed at capture time — the `Stats`
    /// wire op's payload. `store.parked_waiters` is *not* recomputed
    /// here: it is maintained incrementally at park/wake time, so a
    /// `Stats` poll never walks the stripes' parked maps.
    pub(super) fn metrics_snapshot(&self) -> Snapshot {
        let keys: usize = self.stripes.iter().map(|s| lock(s).map.len()).sum();
        let counters: usize =
            self.stripes.iter().map(|s| lock(s).counters.len()).sum();
        self.metrics.gauge("store.keys").set(keys as i64);
        self.metrics.gauge("store.counters").set(counters as i64);
        self.metrics.gauge("store.epoch").set(self.epoch.load(Ordering::SeqCst) as i64);
        self.metrics.snapshot()
    }

    pub(super) fn stripe_for(&self, key: &str) -> &Mutex<Stripe> {
        let h = crate::util::fnv1a(key.as_bytes()) as usize;
        &self.stripes[h % STRIPES]
    }

    fn beats_for(&self, rank: u64) -> &Mutex<HashMap<u64, BeatRecord>> {
        &self.beats[(rank as usize) % STRIPES]
    }

    /// Flat-mutation dump of the entire store state — the payload of
    /// an `InstallState` bootstrap when a dead replica re-attaches
    /// (DESIGN.md §13). Same grammar as replication log entries: keys
    /// as `Set`, counters as `Add` (from zero), beats as `Heartbeat`
    /// (freshness restamps at the receiver — a just-installed beat
    /// reads as fresh, which only delays the first lease expiry by one
    /// interval), the dedup cache as `DedupDone`, and the epoch as a
    /// trailing `AdvanceEpoch` so the receiver's prune runs against
    /// the final epoch, exactly as it did here.
    pub(super) fn snapshot_ops(&self) -> Vec<Request> {
        let mut ops = Vec::new();
        for stripe in &self.stripes {
            let g = lock(stripe);
            for (k, v) in &g.map {
                ops.push(Request::Set { key: k.clone(), value: v.to_vec() });
            }
            for (k, v) in &g.counters {
                ops.push(Request::Add { key: k.clone(), delta: *v });
            }
        }
        for stripe in &self.beats {
            for rec in lock(stripe).values() {
                ops.push(Request::Heartbeat {
                    rank: rec.rank,
                    incarnation: rec.incarnation,
                    step_tag: rec.step_tag,
                    device_code: rec.device_code,
                });
            }
        }
        for (id, resp) in lock(&self.dedup).entries() {
            ops.push(Request::DedupDone { id, resp });
        }
        let epoch = self.epoch.load(Ordering::SeqCst);
        if epoch > 0 {
            ops.push(Request::AdvanceEpoch { to: epoch });
        }
        ops
    }

    /// Insert `key = value` and wake exactly that key's parked
    /// waiters (the per-key parking protocol's publish half): notify
    /// the slot's condvar for parked threads, and enqueue a key wake
    /// event for parked reactor entries (only when any exist — the
    /// threaded core never pays the queue push).
    pub(super) fn set_value(&self, key: String, value: Bytes) {
        let mut g = lock(self.stripe_for(&key));
        let (cv, has_entries) = match g.parked.get(&key) {
            Some(s) => (Some(s.cv.clone()), !s.entries.is_empty()),
            None => (None, false),
        };
        let wake_key = has_entries.then(|| key.clone());
        g.map.insert(key, value);
        drop(g);
        if let Some(cv) = cv {
            cv.notify_all();
        }
        if let Some(k) = wake_key {
            lock(&self.pending_wakes).push(WakeEvent::Key(k));
        }
    }

    /// Broadcast to every parked waiter — only for the rare global
    /// transitions (epoch advance, shutdown), never per `Set`. Also
    /// rouses the reactor (if one is serving) so it observes the stop
    /// flag / new epoch and fans the broadcast out to parked entries.
    pub(super) fn wake_all(&self) {
        let mut any_entries = false;
        for stripe in &self.stripes {
            let g = lock(stripe);
            any_entries |= g.parked.values().any(|s| !s.entries.is_empty());
            let cvs: Vec<Arc<Condvar>> =
                g.parked.values().map(|s| s.cv.clone()).collect();
            drop(g);
            for cv in cvs {
                cv.notify_all();
            }
        }
        if any_entries {
            lock(&self.pending_wakes).push(WakeEvent::All);
        }
        let waker = lock(&self.reactor_waker).clone();
        if let Some(w) = waker {
            w();
        }
    }
}

/// Which serving core a [`TcpStoreServer`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreCore {
    /// One readiness-driven event loop serves every connection
    /// (DESIGN.md §14). Linux only — requesting it elsewhere falls
    /// back to [`StoreCore::Threads`].
    Reactor,
    /// The PR 5 token-accounted worker pool: one OS thread per
    /// concurrently active (or parked) connection.
    Threads,
}

impl StoreCore {
    /// The platform default: the reactor wherever epoll exists.
    pub fn default_core() -> StoreCore {
        if cfg!(target_os = "linux") {
            StoreCore::Reactor
        } else {
            StoreCore::Threads
        }
    }
}

/// The store server. Dropping it shuts the listener down.
pub struct TcpStoreServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    core: StoreCore,
    serve_thread: Option<JoinHandle<()>>,
}

impl TcpStoreServer {
    /// Bind on 127.0.0.1 with an OS-assigned port.
    pub fn start() -> Result<Self> {
        Self::start_on("127.0.0.1:0".parse().expect("literal addr"))
    }

    /// Bind on a specific local address (e.g. a test racing a client
    /// that retries a known endpoint before the store is up).
    pub fn start_on(bind: SocketAddr) -> Result<Self> {
        Self::start_with(bind, StoreCore::default_core())
    }

    /// Bind and serve with an explicit core — the bench harness runs
    /// both cores side by side, and the pool's thread-accounting test
    /// pins [`StoreCore::Threads`].
    pub fn start_with(bind: SocketAddr, core: StoreCore) -> Result<Self> {
        let listener = TcpListener::bind(bind).context("bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared::new());
        let stop = Arc::new(AtomicBool::new(false));
        let core = if cfg!(target_os = "linux") { core } else { StoreCore::Threads };
        let serve_thread = match core {
            StoreCore::Reactor => spawn_reactor(listener, shared.clone(), stop.clone()),
            StoreCore::Threads => {
                spawn_thread_core(listener, shared.clone(), stop.clone())
            }
        };
        Ok(TcpStoreServer { addr, shared, stop, core, serve_thread: Some(serve_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The serving core this instance actually runs (a `Reactor`
    /// request degrades to `Threads` off-Linux).
    pub fn core(&self) -> StoreCore {
        self.core
    }

    /// Snapshot of the server's metrics registry — the same payload
    /// the `Stats` wire op serves to remote clients.
    pub fn metrics_snapshot(&self) -> Snapshot {
        self.shared.metrics_snapshot()
    }

    /// Snapshot of every rank's latest heartbeat record — what the
    /// controller-side [`crate::coordinator::LeaseMonitor`] consumes
    /// each scan.
    pub fn beats(&self) -> Vec<BeatRecord> {
        let mut out = Vec::new();
        self.beats_into(&mut out);
        out
    }

    /// [`Self::beats`] into a caller-owned scratch buffer (cleared
    /// first) — the controller's per-scan path, allocation-free at
    /// steady state.
    pub fn beats_into(&self, out: &mut Vec<BeatRecord>) {
        out.clear();
        for stripe in &self.shared.beats {
            out.extend(lock(stripe).values().copied());
        }
    }

    /// Current rendezvous epoch (advanced by `AdvanceEpoch`).
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::SeqCst)
    }

    /// Demote this server to a log-shipping replica: it refuses
    /// client mutations with `NotPrimary` and mutates only by
    /// applying `Replicate` frames from the primary. Reads (`Get`/
    /// `Count`/`Stats`) and discovery ops stay served.
    pub fn set_replica(&self) {
        self.shared.role.store(ROLE_REPLICA, Ordering::SeqCst);
    }

    /// Promote this server to primary of a plane whose replicas are
    /// `peers` (empty slice: un-replicated — no shipper is started).
    /// Idempotent: a second promote keeps the running replicator, so
    /// racing discoverers cannot double-spawn shippers.
    pub fn promote(&self, peers: &[SocketAddr]) {
        promote_shared(&self.shared, peers);
    }

    /// This server as a single-node endpoint set — the bridge from
    /// legacy single-address call sites onto the session API.
    pub fn endpoints(&self) -> StoreEndpoints {
        StoreEndpoints::one(self.addr)
    }

    /// Replication log index applied on this node (0 = nothing
    /// logged yet).
    pub fn applied_index(&self) -> u64 {
        self.shared.applied.load(Ordering::SeqCst)
    }

    /// Re-attach a (re)started replica at `addr` to this primary's
    /// log: bootstrap it with a full state snapshot (`InstallState` at
    /// the current log high-water) and then ship the live tail to it
    /// like any founding member (DESIGN.md §13). An un-replicated
    /// primary grows a shipper on first attach, so a store born alone
    /// can still adopt followers later.
    pub fn attach_replica(&self, addr: SocketAddr) -> Result<()> {
        let repl = {
            let mut g = lock(&self.shared.repl);
            if g.is_none() {
                let next = self.shared.applied.load(Ordering::SeqCst) + 1;
                *g = Some(Replicator::start(&[], next));
            }
            g.clone().expect("replicator just ensured")
        };
        repl.attach(addr, &self.shared)
    }
}

impl Drop for TcpStoreServer {
    fn drop(&mut self) {
        // Drain and stop the replication shipper first, so every
        // entry this primary acked is on the wire to its replicas
        // before the listener closes.
        if let Some(r) = lock(&self.shared.repl).take() {
            r.shutdown();
        }
        self.stop.store(true, Ordering::Relaxed);
        // Wake every parked waiter so their pool workers (or the
        // reactor, via its eventfd hook) can observe stop; idle pool
        // workers exit when the accept thread closes the connection
        // queue.
        self.shared.wake_all();
        if let Some(h) = self.serve_thread.take() {
            let _ = h.join();
        }
    }
}

/// Spawn the event-loop core (Linux): one thread owns the listener,
/// every connection, and every parked frame.
#[cfg(target_os = "linux")]
fn spawn_reactor(
    listener: TcpListener,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::spawn(move || super::reactor::run(listener, shared, stop))
}

/// Off-Linux the `Reactor` variant is unreachable (`start_with`
/// coerces to `Threads`); this stub keeps the call site monomorphic.
#[cfg(not(target_os = "linux"))]
fn spawn_reactor(
    listener: TcpListener,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    spawn_thread_core(listener, shared, stop)
}

/// Spawn the threaded core's accept loop. Worker pool: accepted
/// connections flow through a shared queue; a worker serves one
/// connection at a time and then returns to the queue. A new worker
/// is spawned only when no idle worker exists, so the pool (and its
/// `JoinHandle` list) is bounded by the concurrency high-water mark —
/// connection *churn* reuses threads instead of leaking one handle
/// per connection.
fn spawn_thread_core(
    listener: TcpListener,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::spawn(move || run_thread_core(listener, shared, stop))
}

/// The threaded core's accept loop body (also the reactor's fallback
/// if epoll/eventfd setup fails — it already owns the serve thread).
pub(super) fn run_thread_core(
    listener: TcpListener,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
) {
    shared.core_threads.set(1); // the accept thread itself
    let (conn_tx, conn_rx) = std::sync::mpsc::channel::<TcpStream>();
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let mut workers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Consume one readiness token; if none is
                // available every live worker is (or may soon
                // be) busy — possibly parked in a fenced wait
                // — so this connection gets its own worker.
                let has_free = shared
                    .free_workers
                    .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                        v.checked_sub(1)
                    })
                    .is_ok();
                if !has_free {
                    let sh = shared.clone();
                    let st = stop.clone();
                    let rx = conn_rx.clone();
                    sh.live_workers.add(1);
                    sh.workers_spawned.inc();
                    // peak serving threads = accept + live pool
                    // (this thread is the gauge's only writer)
                    let live = 1 + sh.live_workers.get();
                    if live > sh.core_threads.get() {
                        sh.core_threads.set(live);
                    }
                    workers.push(std::thread::spawn(move || {
                        pool_worker(rx, sh, st)
                    }));
                }
                let _ = conn_tx.send(stream);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_micros(200));
            }
            Err(_) => break,
        }
    }
    // Closing the queue releases idle workers; parked waiters
    // are released by the server's Drop broadcast.
    drop(conn_tx);
    for w in workers {
        let _ = w.join();
    }
}

/// Pool worker: serve one connection at a time from the shared queue.
/// Each cycle announces one readiness token *before* dequeueing, so
/// the accept loop's spawn decision never relies on a stale idle
/// count (see `Shared::free_workers`). Holding the queue mutex across
/// `recv` is deliberate — one worker receives while the rest of the
/// ready pool parks on the mutex.
fn pool_worker(
    rx: Arc<Mutex<Receiver<TcpStream>>>,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
) {
    // The first cycle does not announce: a worker is only spawned for
    // a connection that found no token, so its first dequeue is
    // already paid for — announcing would mint a phantom token and
    // resurrect the stale-count starvation this scheme exists to fix.
    let mut announce = false;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        if announce {
            shared.free_workers.fetch_add(1, Ordering::SeqCst);
        }
        announce = true;
        let conn = {
            let guard = lock(&rx);
            match guard.recv() {
                Ok(c) => c,
                Err(_) => break, // queue closed: shutdown
            }
        };
        shared.registrations.add(1);
        let _ = serve_connection(conn, &shared, &stop);
        shared.registrations.sub(1);
    }
    shared.live_workers.sub(1);
}

/// `read_exact` that tolerates the connection's 100ms read-timeout
/// polls without desyncing the stream: a timeout *before any byte of
/// `buf` arrived* returns `Ok(false)` when `idle_ok` (the caller's
/// stop-flag poll point); a timeout *mid-buffer* keeps reading — the
/// peer has committed to this frame, and abandoning consumed bytes
/// would make the next header read misparse the remainder. Large
/// `Batch`/table frames make multi-read frames routine, so this is
/// load-bearing, not defensive. Shutdown still interrupts a stalled
/// mid-frame read via the stop flag.
fn read_exact_persist(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    idle_ok: bool,
) -> std::io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => return Err(ErrorKind::UnexpectedEof.into()),
            Ok(n) => got += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if got == 0 && idle_ok {
                    return Ok(false);
                }
                if stop.load(Ordering::Relaxed) {
                    return Err(ErrorKind::UnexpectedEof.into());
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one frame into the reusable buffer, or `Ok(false)` for an
/// idle poll (no bytes consumed — the caller rechecks the stop flag).
fn read_frame_idle_aware(
    stream: &mut TcpStream,
    body: &mut Vec<u8>,
    stop: &AtomicBool,
) -> std::io::Result<bool> {
    let mut len_buf = [0u8; 4];
    if !read_exact_persist(stream, &mut len_buf, stop, true)? {
        return Ok(false);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame too large: {len}"),
        ));
    }
    body.clear();
    body.resize(len, 0);
    read_exact_persist(stream, body, stop, false)?;
    Ok(true)
}

fn serve_connection(
    mut stream: TcpStream,
    shared: &Shared,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .ok();
    // Per-connection reusable buffers: at steady state a request/
    // response cycle allocates nothing on the framing path.
    let mut read_buf: Vec<u8> = Vec::new();
    let mut write_buf: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match read_frame_idle_aware(&mut stream, &mut read_buf, stop) {
            Ok(true) => {}
            Ok(false) => continue, // idle poll: recheck the stop flag
            Err(_) => return Ok(()), // EOF/reset: done
        }
        shared.frames.inc();
        let (req, ctx) = Request::decode_traced(&read_buf)?;
        // A traced frame stitches the server into the sender's
        // episode trace: one instant per frame on the store track,
        // attached to the remote sender's span.
        if let Some(ctx) = ctx {
            trace::event_in(ctx, req.op_name(), "store", String::new());
        }
        let resp = handle(shared, stop, req);
        resp.encode_into(&mut write_buf);
        write_frame(&mut stream, &write_buf)?;
    }
}

/// Per-frame entry point. Dispatch runs in [`handle_inner`]; this
/// wrapper holds the frame's one quorum wait: after every op of the
/// frame is applied and logged, block once until the *highest* index
/// the frame enqueued is on a quorum of replicas (group commit —
/// a k-op `Batch` pays one commit wait, not k).
fn handle(shared: &Shared, stop: &AtomicBool, req: Request) -> Response {
    let repl = lock(&shared.repl).clone();
    let mut highest = 0u64;
    let resp = handle_inner(shared, stop, repl.as_deref(), &mut highest, req);
    if highest > 0 {
        if let Some(r) = repl.as_deref() {
            r.wait_committed(highest);
        }
    }
    resp
}

/// Ops a replica serves directly: reads, discovery, and the
/// replication protocol itself. Everything else answers `NotPrimary`
/// so the client's session fails over.
pub(super) fn replica_serves(req: &Request) -> bool {
    matches!(
        req,
        Request::Hello { .. }
            | Request::Get { .. }
            | Request::Count
            | Request::Stats
            | Request::Replicate { .. }
            | Request::ReplStatus
            | Request::Promote { .. }
            | Request::Beats
            | Request::InstallState { .. }
    )
}

fn handle_inner(
    shared: &Shared,
    stop: &AtomicBool,
    repl: Option<&Replicator>,
    highest: &mut u64,
    req: Request,
) -> Response {
    if shared.role.load(Ordering::SeqCst) == ROLE_REPLICA && !replica_serves(&req) {
        shared.requests.inc();
        return Response::NotPrimary;
    }
    match req {
        Request::Batch(items) => {
            // Pipelined sequence: execute serially, stop at the first
            // fence so a superseded prefix never commits its dependent
            // tail (e.g. a survivor's arrive after its delta wait was
            // fenced). Nesting is rejected at decode. A blocking
            // sub-op released by the shutdown broadcast (`NotFound`
            // under `stop`) also stops the batch: the dying server
            // must not run the tail the wait was guarding — the
            // client replays the rest against the new primary.
            let mut out = Vec::with_capacity(items.len());
            for item in items {
                let blocking = item.is_blocking();
                let resp = handle_inner(shared, stop, repl, highest, item);
                let fenced = matches!(resp, Response::EpochFenced { .. });
                let released = blocking
                    && resp == Response::NotFound
                    && stop.load(Ordering::Relaxed);
                out.push(resp);
                if fenced || released {
                    break;
                }
            }
            Response::Multi(out)
        }
        Request::Dedup { id, op } => {
            handle_dedup(shared, stop, repl, highest, id, *op)
        }
        Request::Replicate { start_index, ops } => {
            shared.requests.inc();
            handle_replicate(shared, stop, start_index, ops)
        }
        Request::InstallState { high_water, ops } => {
            shared.requests.inc();
            handle_install_state(shared, stop, high_water, ops)
        }
        Request::ReplStatus => {
            shared.requests.inc();
            repl_status_response(shared)
        }
        Request::Promote { peers } => {
            shared.requests.inc();
            let addrs: Vec<SocketAddr> =
                peers.iter().filter_map(|p| p.parse().ok()).collect();
            promote_shared(shared, &addrs);
            Response::Ok
        }
        req if req.is_mutating() => {
            shared.requests.inc();
            apply_mutating(shared, stop, repl, highest, req)
        }
        req => {
            shared.requests.inc();
            apply_op(shared, stop, req)
        }
    }
}

/// Apply a mutating op and, when replicated, log it under the same
/// lock that applied it (apply order == log order, even across racing
/// connections). Conditional mutations (`AbortEpoch`,
/// `AdvertiseRestore`) are logged only when they actually mutated.
pub(super) fn apply_mutating(
    shared: &Shared,
    stop: &AtomicBool,
    repl: Option<&Replicator>,
    highest: &mut u64,
    req: Request,
) -> Response {
    match repl {
        Some(r) => {
            let (resp, idx) = r.apply_logged(|| {
                let resp = apply_op(shared, stop, req.clone());
                if loggable(&req, &resp) {
                    (resp, vec![req])
                } else {
                    (resp, Vec::new())
                }
            });
            if let Some(idx) = idx {
                bump_applied(shared, highest, idx);
            }
            resp
        }
        None => apply_op(shared, stop, req),
    }
}

/// Should this executed op enter the replication log? Unconditional
/// mutations always do; conditional ones only when their response
/// shows they fired.
pub(super) fn loggable(req: &Request, resp: &Response) -> bool {
    match req {
        Request::Set { .. }
        | Request::Add { .. }
        | Request::AdvanceEpoch { .. }
        | Request::DelPrefix { .. }
        | Request::Heartbeat { .. }
        | Request::DedupDone { .. } => true,
        Request::AbortEpoch { .. } => matches!(resp, Response::Counter(1)),
        Request::AdvertiseRestore { .. } => matches!(resp, Response::Ok),
        _ => false,
    }
}

pub(super) fn bump_applied(shared: &Shared, highest: &mut u64, idx: u64) {
    shared.applied.fetch_max(idx, Ordering::SeqCst);
    *highest = (*highest).max(idx);
}

/// A `Response` body (no length prefix) — what the dedup cache stores
/// and `DedupDone` entries ship.
pub(super) fn encode_resp_body(resp: &Response) -> Vec<u8> {
    let mut buf = Vec::new();
    resp.encode_into(&mut buf);
    buf.split_off(4)
}

/// Exactly-once envelope: a cached id answers from the dedup table
/// without re-executing; a fresh id executes, then installs + logs
/// the cached response *in the same log append* as its mutations, so
/// a replica holds either none or all of {ops, done-marker} — a
/// failed-over primary can never re-execute a half-replicated op.
fn handle_dedup(
    shared: &Shared,
    stop: &AtomicBool,
    repl: Option<&Replicator>,
    highest: &mut u64,
    id: u64,
    op: Request,
) -> Response {
    if let Some(cached) = lock(&shared.dedup).get(id) {
        return Response::decode(&cached).unwrap_or(Response::NotFound);
    }
    match op {
        Request::Batch(items) => {
            let mut out = Vec::with_capacity(items.len());
            let mut entries: Vec<Request> = Vec::new();
            for item in items {
                shared.requests.inc();
                let blocking = item.is_blocking();
                let resp = apply_op(shared, stop, item.clone());
                if loggable(&item, &resp) {
                    entries.push(item);
                }
                let fenced = matches!(resp, Response::EpochFenced { .. });
                let released = blocking
                    && resp == Response::NotFound
                    && stop.load(Ordering::Relaxed);
                out.push(resp);
                if released {
                    // Dying server: ship nothing, cache nothing. The
                    // executed prefix dies with this primary (its
                    // replicas never saw it), and the client replays
                    // the whole batch — exactly once — on the
                    // survivor.
                    return Response::Multi(out);
                }
                if fenced {
                    break;
                }
            }
            let resp = Response::Multi(out);
            let body = encode_resp_body(&resp);
            lock(&shared.dedup).insert(id, body.clone());
            entries.push(Request::DedupDone { id, resp: body });
            if let Some(r) = repl {
                if let Some(idx) = r.append(entries) {
                    bump_applied(shared, highest, idx);
                }
            }
            resp
        }
        single if single.is_blocking() => {
            shared.requests.inc();
            let resp = apply_op(shared, stop, single);
            if resp == Response::NotFound && stop.load(Ordering::Relaxed) {
                // shutdown release: uncached, the client replays fresh
                return resp;
            }
            let body = encode_resp_body(&resp);
            lock(&shared.dedup).insert(id, body.clone());
            if let Some(r) = repl {
                let done = Request::DedupDone { id, resp: body };
                if let Some(idx) = r.append(vec![done]) {
                    bump_applied(shared, highest, idx);
                }
            }
            resp
        }
        single => {
            shared.requests.inc();
            match repl {
                Some(r) => {
                    let (resp, idx) = r.apply_logged(|| {
                        let resp = apply_op(shared, stop, single.clone());
                        let body = encode_resp_body(&resp);
                        lock(&shared.dedup).insert(id, body.clone());
                        let mut entries = Vec::new();
                        if loggable(&single, &resp) {
                            entries.push(single);
                        }
                        entries.push(Request::DedupDone { id, resp: body });
                        (resp, entries)
                    });
                    if let Some(idx) = idx {
                        bump_applied(shared, highest, idx);
                    }
                    resp
                }
                None => {
                    let resp = apply_op(shared, stop, single);
                    let body = encode_resp_body(&resp);
                    lock(&shared.dedup).insert(id, body);
                    resp
                }
            }
        }
    }
}

/// Replica side of log shipping: apply every not-yet-applied entry of
/// a contiguous frame and ack the applied index. A frame that starts
/// beyond `applied + 1` (a gap — this replica missed a frame) is
/// refused with a short ack, which the primary treats as replica
/// loss; already-applied prefixes (a re-ship) are skipped idempotently.
pub(super) fn handle_replicate(
    shared: &Shared,
    stop: &AtomicBool,
    start_index: u64,
    ops: Vec<Request>,
) -> Response {
    let applied = shared.applied.load(Ordering::SeqCst);
    if start_index > applied + 1 {
        return Response::Counter(applied as i64);
    }
    let mut idx = start_index;
    for op in ops {
        if idx > shared.applied.load(Ordering::SeqCst) {
            if op.is_mutating() {
                let _ = apply_op(shared, stop, op);
            }
            shared.applied.store(idx, Ordering::SeqCst);
        }
        idx += 1;
    }
    Response::Counter(shared.applied.load(Ordering::SeqCst) as i64)
}

/// Replica side of the re-attach bootstrap: replace the whole local
/// state with the primary's snapshot and fast-forward the applied
/// index to the snapshot's high-water. A primary refuses the install
/// (`NotFound`) — only a demoted/fresh replica may be overwritten.
/// Log shipments at indices `<= high_water` arriving after (or racing)
/// the install are skipped by `handle_replicate`'s idempotency check,
/// so an in-flight pre-snapshot batch can never regress the state.
pub(super) fn handle_install_state(
    shared: &Shared,
    stop: &AtomicBool,
    high_water: u64,
    ops: Vec<Request>,
) -> Response {
    if shared.role.load(Ordering::SeqCst) != ROLE_REPLICA {
        return Response::NotFound;
    }
    for stripe in &shared.stripes {
        let mut g = lock(stripe);
        g.map.clear();
        g.counters.clear();
    }
    for stripe in &shared.beats {
        lock(stripe).clear();
    }
    lock(&shared.dedup).clear();
    for op in ops {
        if op.is_mutating() {
            let _ = apply_op(shared, stop, op);
        }
    }
    shared.applied.store(high_water, Ordering::SeqCst);
    Response::Counter(high_water as i64)
}

/// `ReplStatus` payload: `role u8 | applied u64-le | epoch u64-le`.
/// The epoch leads the election key — a replica behind on epoch can
/// never be promoted over one that has seen the newer epoch.
pub(super) fn repl_status_response(shared: &Shared) -> Response {
    let mut v = Vec::with_capacity(17);
    v.push(shared.role.load(Ordering::SeqCst));
    v.extend_from_slice(&shared.applied.load(Ordering::SeqCst).to_le_bytes());
    v.extend_from_slice(&shared.epoch.load(Ordering::SeqCst).to_le_bytes());
    Response::Value(v.into())
}

/// Flip to primary and (once) start the log shipper toward `peers`.
/// Idempotent under racing `Promote`s: the first wins, later ones
/// keep the running replicator.
pub(super) fn promote_shared(shared: &Shared, peers: &[SocketAddr]) {
    shared.role.store(ROLE_PRIMARY, Ordering::SeqCst);
    let mut g = lock(&shared.repl);
    if g.is_none() && !peers.is_empty() {
        let next = shared.applied.load(Ordering::SeqCst) + 1;
        *g = Some(Replicator::start(peers, next));
    }
}

/// Execute one non-container op against local state — the shared
/// apply path for client-issued ops on the primary and `Replicate`d
/// entries on replicas. Never logs; callers decide that.
pub(super) fn apply_op(shared: &Shared, stop: &AtomicBool, req: Request) -> Response {
    match req {
        // containers and replication-protocol ops never reach the
        // apply path (dispatched in handle_inner; rejected at decode
        // inside Replicate frames) — answer benignly, never panic on
        // a hostile frame
        Request::Batch(_)
        | Request::Dedup { .. }
        | Request::Replicate { .. }
        | Request::ReplStatus
        | Request::Promote { .. }
        | Request::InstallState { .. } => Response::NotFound,
        Request::Beats => {
            let now = Instant::now();
            let mut recs = Vec::new();
            for stripe in &shared.beats {
                recs.extend(lock(stripe).values().copied());
            }
            Response::Value(encode_beats(&recs, now).into())
        }
        Request::DedupDone { id, resp } => {
            lock(&shared.dedup).insert(id, resp);
            Response::Ok
        }
        Request::Hello { .. } => {
            shared.hellos.inc();
            Response::HelloAck
        }
        Request::Set { key, value } => {
            shared.set_value(key, value.into());
            Response::Ok
        }
        Request::Get { key } => {
            let g = lock(shared.stripe_for(&key));
            match g.map.get(&key) {
                Some(v) => Response::Value(v.clone()),
                None => Response::NotFound,
            }
        }
        // An unfenced wait is a fenced wait that can never be
        // superseded (only published values, shutdown, or an epoch
        // broadcast wake it — and the epoch check never trips).
        Request::Wait { key } => fenced_wait(shared, stop, &key, u64::MAX),
        Request::Add { key, delta } => {
            let mut g = lock(shared.stripe_for(&key));
            let v = g.counters.entry(key).or_insert(0);
            *v += delta;
            Response::Counter(*v)
        }
        Request::Count => {
            let total: usize =
                shared.stripes.iter().map(|s| lock(s).map.len()).sum();
            Response::CountIs(total as u64)
        }
        Request::WaitEpoch { key, epoch } => fenced_wait(shared, stop, &key, epoch),
        Request::AdvanceEpoch { to } => {
            let prev = shared.epoch.fetch_max(to, Ordering::SeqCst);
            let current = prev.max(to);
            prune_stale_epochs(shared, current);
            // The one legitimate broadcast besides shutdown: every
            // fenced waiter must observe the new epoch and return
            // `EpochFenced`.
            shared.wake_all();
            Response::Counter(current as i64)
        }
        Request::AdvertiseRestore { epoch, tag, addr } => {
            let current = shared.epoch.load(Ordering::SeqCst);
            if current > epoch {
                // the restore this source belongs to is already stale
                Response::EpochFenced { current }
            } else {
                shared.set_value(restore_key(epoch, tag), addr.into_bytes().into());
                Response::Ok
            }
        }
        Request::ClaimRestore { epoch, tag } => {
            fenced_wait(shared, stop, &restore_key(epoch, tag), epoch)
        }
        Request::AbortEpoch { unless_key, tombstone_key, tombstone, to } => {
            // Atomic with `Set` and the fenced waits on the release
            // key's stripe: either the release key landed first and
            // the abort is a no-op, or the epoch is fenced while that
            // stripe is held — so no waiter can slip between a late
            // release and the fence — before the tombstone publishes.
            // Never a mix.
            let g = lock(shared.stripe_for(&unless_key));
            if g.map.contains_key(&unless_key) {
                Response::Counter(0)
            } else {
                let prev = shared.epoch.fetch_max(to, Ordering::SeqCst);
                drop(g);
                shared.set_value(tombstone_key, tombstone.into());
                prune_stale_epochs(shared, prev.max(to));
                shared.wake_all();
                Response::Counter(1)
            }
        }
        Request::Heartbeat { rank, incarnation, step_tag, device_code } => {
            let mut beats = lock(shared.beats_for(rank));
            let rec = BeatRecord { rank, incarnation, step_tag, device_code, at: Instant::now() };
            match beats.get(&rank) {
                // a stale incarnation must never refresh its
                // replacement's lease
                Some(old) if old.incarnation > incarnation => {}
                _ => {
                    beats.insert(rank, rec);
                }
            }
            Response::Ok
        }
        Request::Stats => {
            let snap = shared.metrics_snapshot();
            Response::Value(snap.to_json().render().into_bytes().into())
        }
        Request::DelPrefix { prefix } => {
            let mut removed = 0i64;
            for stripe in &shared.stripes {
                let mut g = lock(stripe);
                g.map.retain(|k, _| {
                    let keep = !k.starts_with(&prefix);
                    removed += i64::from(!keep);
                    keep
                });
                g.counters.retain(|k, _| {
                    let keep = !k.starts_with(&prefix);
                    removed += i64::from(!keep);
                    keep
                });
            }
            Response::Counter(removed)
        }
    }
}

/// Drop every per-epoch rendezvous/restore key (and arrive counter)
/// for epochs `<= current - 2`. Only epoch `e-1` is ever needed for
/// late resync (DESIGN.md §8), so epoch advance keeps the store's key
/// count bounded by two epochs' worth instead of leaking one key set
/// per recovery forever.
fn prune_stale_epochs(shared: &Shared, current: u64) {
    let keep_from = current.saturating_sub(1);
    let stale = |key: &str| -> bool {
        // `redund/` stripe advertisements are fenced and pruned like
        // restore sources; `redund/depot/<rank>` endpoints survive
        // because "depot" never parses as an epoch number.
        for prefix in ["rdzv/", "restore/", "redund/"] {
            if let Some(rest) = key.strip_prefix(prefix) {
                if let Some((e, _)) = rest.split_once('/') {
                    if let Ok(e) = e.parse::<u64>() {
                        return e < keep_from;
                    }
                }
            }
        }
        false
    };
    for stripe in &shared.stripes {
        let mut g = lock(stripe);
        g.map.retain(|k, _| !stale(k));
        g.counters.retain(|k, _| !stale(k));
    }
}

/// Store key under which a restore source's endpoint is advertised.
pub(super) fn restore_key(epoch: u64, tag: u64) -> String {
    format!("restore/{epoch}/{tag:016x}")
}

/// `Beats` response payload: `count u32-le | {rank u64 | incarnation
/// u64 | step_tag i64 | device_code i64 | age_ms u64}*`. Freshness
/// crosses the wire as an age relative to `now` (the serving node's
/// clock) — an `Instant` can't — and [`decode_beats`] reconstructs a
/// local receive time from it.
fn encode_beats(recs: &[BeatRecord], now: Instant) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + recs.len() * 40);
    out.extend_from_slice(&(recs.len() as u32).to_le_bytes());
    for r in recs {
        out.extend_from_slice(&r.rank.to_le_bytes());
        out.extend_from_slice(&r.incarnation.to_le_bytes());
        out.extend_from_slice(&r.step_tag.to_le_bytes());
        out.extend_from_slice(&r.device_code.to_le_bytes());
        let age = now.saturating_duration_since(r.at).as_millis().min(u64::MAX as u128);
        out.extend_from_slice(&(age as u64).to_le_bytes());
    }
    out
}

/// Parse a `Beats` payload back into [`BeatRecord`]s, restamping each
/// beat's receive time as `now - age_ms` on the local clock (clamped
/// to the epoch of this process's `Instant` domain). Network latency
/// between the store and this reader only makes beats look *older*,
/// never fresher — the safe direction for lease math.
pub fn decode_beats(bytes: &[u8]) -> Result<Vec<BeatRecord>> {
    if bytes.len() < 4 {
        bail!("beats payload underrun");
    }
    let count = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    if bytes.len() < 4 + count * 40 {
        bail!("beats payload truncated: {count} records, {} bytes", bytes.len());
    }
    let now = Instant::now();
    let mut out = Vec::with_capacity(count);
    let mut pos = 4;
    let mut u = |p: &mut usize| -> u64 {
        let v = u64::from_le_bytes(bytes[*p..*p + 8].try_into().unwrap());
        *p += 8;
        v
    };
    for _ in 0..count {
        let rank = u(&mut pos);
        let incarnation = u(&mut pos);
        let step_tag = u(&mut pos) as i64;
        let device_code = u(&mut pos) as i64;
        let age = Duration::from_millis(u(&mut pos));
        let at = now.checked_sub(age).unwrap_or(now);
        out.push(BeatRecord { rank, incarnation, step_tag, device_code, at });
    }
    Ok(out)
}

/// One pass of the fenced-wait state machine, caller holding the
/// key's stripe: fence check, then value check, then stop check —
/// `None` means "would park". The exact decision order both cores
/// share, so a blocking op resolves identically whether the waiter is
/// a parked thread re-checking after a notify or a parked reactor
/// entry resumed off the wakeup queue.
pub(super) fn wait_poll(
    shared: &Shared,
    stop: &AtomicBool,
    stripe: &Stripe,
    key: &str,
    epoch: u64,
) -> Option<Response> {
    let current = shared.epoch.load(Ordering::SeqCst);
    if current > epoch {
        return Some(Response::EpochFenced { current });
    }
    if let Some(v) = stripe.map.get(key) {
        return Some(Response::Value(v.clone()));
    }
    if stop.load(Ordering::Relaxed) {
        return Some(Response::NotFound);
    }
    None
}

/// Block until `key` is published or the rendezvous epoch passes
/// `epoch` — the threaded core's body of `Wait`, `WaitEpoch` and
/// `ClaimRestore` (the reactor suspends the frame instead of the
/// thread; see `comms/reactor`). The waiter parks on the key's own
/// slot: only a `Set` of this key (or an epoch/shutdown broadcast)
/// notifies it. A waiter that parked and is then released by its
/// key's publish is counted in `wakeups` — the deterministic
/// per-key-parking metric (raw notify counts would race timeout
/// boundaries and spurious wakeups). The `parked` gauge is kept
/// incrementally: +1 on first park, -1 on return.
fn fenced_wait(shared: &Shared, stop: &AtomicBool, key: &str, epoch: u64) -> Response {
    let stripe = shared.stripe_for(key);
    let mut g = lock(stripe);
    let mut parked = false;
    let resp = loop {
        if let Some(resp) = wait_poll(shared, stop, &g, key, epoch) {
            if parked && matches!(resp, Response::Value(_)) {
                shared.wakeups.inc();
            }
            break resp;
        }
        let cv = {
            let slot = g.parked.entry(key.to_string()).or_default();
            slot.waiters += 1;
            slot.cv.clone()
        };
        if !parked {
            shared.parked.add(1);
            parked = true;
        }
        let (guard, _timeout) = cv
            .wait_timeout(g, Duration::from_millis(100))
            .unwrap_or_else(PoisonError::into_inner);
        g = guard;
        if let Some(slot) = g.parked.get_mut(key) {
            slot.waiters -= 1;
            if slot.waiters == 0 && slot.entries.is_empty() {
                g.parked.remove(key);
            }
        }
    };
    if parked {
        shared.parked.sub(1);
    }
    resp
}

/// Outcome of an epoch-fenced wait: the published value, or notice
/// that the rendezvous epoch moved past the one waited on. The latter
/// is retryable — re-issue the wait at `current`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FencedWait {
    Value(Bytes),
    Superseded { current: u64 },
}

/// Client connection to the store. The transport is a pluggable
/// [`Link`](super::link::Link) — plain TCP through the default dialer,
/// or an impaired path when dialed via `comms::netem` — and the wire
/// protocol is byte-identical either way.
pub struct TcpStoreClient {
    link: Box<dyn super::link::Link>,
    ops: u64,
    /// Trace context stamped onto every outgoing frame (16 trailing
    /// bytes, DESIGN.md §12); `None` sends classic untraced frames.
    trace_ctx: Option<TraceCtx>,
}

impl TcpStoreClient {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        Self::connect_with_timeout(addr, Duration::from_secs(10))
    }

    /// Connect with an explicit connect timeout — discovery probes
    /// use a short one so a dead endpoint costs milliseconds, not the
    /// 10s client default. Dials through the process-default dialer.
    pub fn connect_with_timeout(addr: SocketAddr, timeout: Duration) -> Result<Self> {
        Self::connect_via(&*super::link::default_dialer(), addr, timeout)
    }

    /// Connect through an explicit [`Dialer`](super::link::Dialer) —
    /// the seam impaired campaigns use to put this client behind a
    /// degraded link without touching any protocol code.
    pub fn connect_via(
        dialer: &dyn super::link::Dialer,
        addr: SocketAddr,
        timeout: Duration,
    ) -> Result<Self> {
        let link = dialer.dial(addr, timeout)?;
        Ok(TcpStoreClient { link, ops: 0, trace_ctx: None })
    }

    /// Connect under a *source label* through the process-default
    /// dialer — the per-pair netem seam: a labeled link can be shaped
    /// by (src, dst) pair policies independently of unlabeled client
    /// traffic to the same address. Plain TCP ignores the label.
    pub fn connect_from(src: &str, addr: SocketAddr, timeout: Duration) -> Result<Self> {
        let link = super::link::default_dialer().dial_from(src, addr, timeout)?;
        Ok(TcpStoreClient { link, ops: 0, trace_ctx: None })
    }

    /// Set (or clear) the link's read timeout — the session layer
    /// widens it around blocking waits and bounds it on replication
    /// log connections.
    pub(crate) fn set_read_window(&mut self, d: Option<Duration>) -> Result<()> {
        self.link.set_read_timeout(d)?;
        Ok(())
    }

    /// Stamp (or clear) the trace context carried by this client's
    /// subsequent frames — typically the current episode span's
    /// [`Span::ctx`](crate::telemetry::Span::ctx), so the store's
    /// per-frame events stitch into the caller's trace.
    pub fn set_trace_ctx(&mut self, ctx: Option<TraceCtx>) {
        self.trace_ctx = ctx;
    }

    /// Logical store operations the server executed for this
    /// connection — the quantity the rendezvous protocol keeps O(1)
    /// per surviving node. Batched sub-ops count individually (a
    /// fence-aborted batch tail, which never executed, does not), so
    /// pipelining changes round-trips, not message budgets.
    pub fn ops_sent(&self) -> u64 {
        self.ops
    }

    fn call(&mut self, req: Request) -> Result<Response> {
        self.ops += 1;
        write_frame(&mut self.link, &req.encode_traced(self.trace_ctx))?;
        let body = read_frame(&mut self.link)?;
        Response::decode(&body)
    }

    /// Send one raw request and return its raw response — the generic
    /// op runner the throughput bench and property tests drive.
    pub fn roundtrip(&mut self, req: Request) -> Result<Response> {
        self.call(req)
    }

    /// Execute `reqs` as one pipelined `Batch` frame: one round-trip
    /// for the whole sequence. The server runs the ops serially and
    /// stops at the first `EpochFenced` (included in the returned
    /// responses; the skipped tail is absent), so dependent suffixes
    /// never run against a superseded epoch.
    pub fn batch(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let n = reqs.len();
        let blocking = reqs.iter().any(Request::is_blocking);
        if blocking {
            // waits can exceed the default read path; use a long timeout
            self.link.set_read_timeout(Some(Duration::from_secs(300)))?;
        }
        write_frame(
            &mut self.link,
            &Request::Batch(reqs).encode_traced(self.trace_ctx),
        )?;
        let body = read_frame(&mut self.link)?;
        match Response::decode(&body)? {
            Response::Multi(rs) => {
                if rs.len() > n {
                    bail!("batch returned {} responses for {n} ops", rs.len());
                }
                self.ops += rs.len() as u64;
                Ok(rs)
            }
            other => bail!("unexpected batch response {other:?}"),
        }
    }

    /// Handshake; returns once the server acknowledged.
    pub fn hello(&mut self, client_id: u64) -> Result<()> {
        match self.call(Request::Hello { client_id })? {
            Response::HelloAck => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn set(&mut self, key: &str, value: &[u8]) -> Result<()> {
        match self.call(Request::Set { key: key.into(), value: value.into() })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn get(&mut self, key: &str) -> Result<Option<Bytes>> {
        match self.call(Request::Get { key: key.into() })? {
            Response::Value(v) => Ok(Some(v)),
            Response::NotFound => Ok(None),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Block until `key` is published.
    pub fn wait(&mut self, key: &str) -> Result<Bytes> {
        // waits can exceed the default read path; use a long timeout
        self.link.set_read_timeout(Some(Duration::from_secs(300)))?;
        match self.call(Request::Wait { key: key.into() })? {
            Response::Value(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Block until `key` is published or the store's rendezvous epoch
    /// advances past `epoch` (a rebuild superseded this wait). Unlike
    /// [`Self::wait`], a stale waiter is *released* with
    /// [`FencedWait::Superseded`] rather than left hanging.
    pub fn wait_epoch(&mut self, key: &str, epoch: u64) -> Result<FencedWait> {
        self.link.set_read_timeout(Some(Duration::from_secs(300)))?;
        match self.call(Request::WaitEpoch { key: key.into(), epoch })? {
            Response::Value(v) => Ok(FencedWait::Value(v)),
            Response::EpochFenced { current } => {
                Ok(FencedWait::Superseded { current })
            }
            Response::NotFound => bail!("store shut down during fenced wait"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Advance the store's rendezvous epoch (monotonic max); returns
    /// the epoch after the advance. Releases all stale fenced waiters.
    pub fn advance_epoch(&mut self, to: u64) -> Result<u64> {
        match self.call(Request::AdvanceEpoch { to })? {
            Response::Counter(v) => Ok(v as u64),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Advertise this client's endpoint as the restore source for one
    /// state transfer (`tag` packs shard + source rank). Returns
    /// `None` on success, or `Some(current)` when the epoch has
    /// already moved past `epoch` (stale — replan the restore).
    pub fn advertise_restore(
        &mut self,
        epoch: u64,
        tag: u64,
        addr: &str,
    ) -> Result<Option<u64>> {
        let req = Request::AdvertiseRestore { epoch, tag, addr: addr.into() };
        match self.call(req)? {
            Response::Ok => Ok(None),
            Response::EpochFenced { current } => Ok(Some(current)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Claim the restore source advertised for `tag`: blocks until the
    /// advertisement lands or the epoch supersedes the claim (then
    /// released retryably, never left hanging).
    pub fn claim_restore(&mut self, epoch: u64, tag: u64) -> Result<FencedWait> {
        self.link.set_read_timeout(Some(Duration::from_secs(300)))?;
        match self.call(Request::ClaimRestore { epoch, tag })? {
            Response::Value(v) => Ok(FencedWait::Value(v)),
            Response::EpochFenced { current } => {
                Ok(FencedWait::Superseded { current })
            }
            Response::NotFound => bail!("store shut down during restore claim"),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Atomically abort an epoch unless its release key was already
    /// published (the supervised-barrier watchdog's weapon). Returns
    /// true when the abort happened, false when the barrier won.
    pub fn abort_epoch_unless(
        &mut self,
        unless_key: &str,
        tombstone_key: &str,
        tombstone: &[u8],
        to: u64,
    ) -> Result<bool> {
        let req = Request::AbortEpoch {
            unless_key: unless_key.into(),
            tombstone_key: tombstone_key.into(),
            tombstone: tombstone.to_vec(),
            to,
        };
        match self.call(req)? {
            Response::Counter(v) => Ok(v == 1),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Push one liveness beat for `(rank, incarnation)`. Fire-and-ack:
    /// one round trip, O(1) payload — the per-worker cost the
    /// detection-latency bench asserts is scale-independent. (A node
    /// agent coalescing several local ranks sends one `Batch` of these
    /// instead; see `training::worker::spawn_node_heartbeat`.)
    pub fn heartbeat(
        &mut self,
        rank: u64,
        incarnation: u64,
        step_tag: i64,
        device_code: i64,
    ) -> Result<()> {
        let req = Request::Heartbeat { rank, incarnation, step_tag, device_code };
        match self.call(req)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Delete every key (and counter) starting with `prefix`; returns
    /// how many entries were removed.
    pub fn del_prefix(&mut self, prefix: &str) -> Result<i64> {
        match self.call(Request::DelPrefix { prefix: prefix.into() })? {
            Response::Counter(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn add(&mut self, key: &str, delta: i64) -> Result<i64> {
        match self.call(Request::Add { key: key.into(), delta })? {
            Response::Counter(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn count(&mut self) -> Result<u64> {
        match self.call(Request::Count)? {
            Response::CountIs(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Fetch the server's live metrics snapshot (`Stats` wire op) —
    /// readable mid-episode, including while other clients block in
    /// fenced waits.
    pub fn stats(&mut self) -> Result<Snapshot> {
        match self.call(Request::Stats)? {
            Response::Value(v) => Snapshot::parse(&v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Fetch the store's heartbeat beat table over the wire (`Beats`
    /// op) — served by replicas too, so a promoted standby can rebuild
    /// lease state from real beats after the primary died.
    pub fn beats(&mut self) -> Result<Vec<BeatRecord>> {
        match self.call(Request::Beats)? {
            Response::Value(v) => decode_beats(&v),
            other => bail!("unexpected response {other:?}"),
        }
    }
}

/// Establish `n` store clients with parallelism degree `p` and return
/// (elapsed, clients). Each establishment = TCP connect + Hello RTT,
/// matching the per-rank TCPStore cost the paper parallelizes.
pub fn establish(
    addr: SocketAddr,
    n: usize,
    p: usize,
) -> Result<(Duration, Vec<TcpStoreClient>)> {
    establish_via(super::link::default_dialer(), addr, n, p)
}

/// [`establish`] through an explicit dialer: the §6 calibration
/// refresh measures the *real* per-link establishment cost over an
/// impaired path with this entry (DESIGN.md §15).
pub fn establish_via(
    dialer: std::sync::Arc<dyn super::link::Dialer>,
    addr: SocketAddr,
    n: usize,
    p: usize,
) -> Result<(Duration, Vec<TcpStoreClient>)> {
    let p = p.clamp(1, n.max(1));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for worker in 0..p {
        let count = n / p + usize::from(worker < n % p);
        let dialer = dialer.clone();
        handles.push(std::thread::spawn(move || -> Result<Vec<TcpStoreClient>> {
            let mut out = Vec::with_capacity(count);
            for i in 0..count {
                let mut c = TcpStoreClient::connect_via(
                    &*dialer,
                    addr,
                    Duration::from_secs(10),
                )?;
                c.hello((worker * 1_000_000 + i) as u64)?;
                out.push(c);
            }
            Ok(out)
        }));
    }
    let mut clients = Vec::with_capacity(n);
    for h in handles {
        clients.extend(h.join().expect("establish worker panicked")?);
    }
    Ok((t0.elapsed(), clients))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        assert_eq!(c.get("missing").unwrap(), None);
        c.set("k", b"hello").unwrap();
        assert_eq!(c.get("k").unwrap().as_deref(), Some(&b"hello"[..]));
        assert_eq!(c.count().unwrap(), 1);
    }

    #[test]
    fn wait_blocks_until_set() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.wait("late").unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.set("late", b"v").unwrap();
        assert_eq!(&waiter.join().unwrap()[..], b"v");
    }

    #[test]
    fn add_is_atomic_across_clients() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let mut handles = Vec::new();
        for _ in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut c = TcpStoreClient::connect(addr).unwrap();
                for _ in 0..25 {
                    c.add("ctr", 1).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut c = TcpStoreClient::connect(addr).unwrap();
        assert_eq!(c.add("ctr", 0).unwrap(), 100);
    }

    #[test]
    fn establish_counts_hellos() {
        let server = TcpStoreServer::start().unwrap();
        let (_elapsed, clients) = establish(server.addr(), 16, 4).unwrap();
        assert_eq!(clients.len(), 16);
        assert_eq!(server.metrics_snapshot().counter("store.hellos"), 16);
    }

    #[test]
    fn establish_serial_equals_parallel_results() {
        let server = TcpStoreServer::start().unwrap();
        let (_t1, c1) = establish(server.addr(), 10, 1).unwrap();
        let (_t2, c2) = establish(server.addr(), 10, 10).unwrap();
        assert_eq!(c1.len(), 10);
        assert_eq!(c2.len(), 10);
        assert_eq!(server.metrics_snapshot().counter("store.hellos"), 20);
    }

    #[test]
    fn epoch_bump_releases_stale_fenced_waiters() {
        // A rebuild epoch bump must release waiters fenced at an older
        // epoch with a retryable outcome — not leave them hanging.
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let t0 = Instant::now();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.wait_epoch("rdzv/1/delta", 1).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut c = TcpStoreClient::connect(addr).unwrap();
        assert_eq!(c.advance_epoch(2).unwrap(), 2);
        let out = waiter.join().unwrap();
        assert_eq!(out, FencedWait::Superseded { current: 2 });
        assert!(t0.elapsed() < Duration::from_secs(10), "waiter hung");
        assert_eq!(server.epoch(), 2);
    }

    #[test]
    fn fenced_wait_delivers_value_at_current_epoch() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.advance_epoch(3).unwrap();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            // fenced at the *current* epoch: must behave like wait()
            c.wait_epoch("rdzv/3/delta", 3).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        c.set("rdzv/3/delta", b"subs").unwrap();
        assert_eq!(
            waiter.join().unwrap(),
            FencedWait::Value(Bytes::from(&b"subs"[..]))
        );
    }

    #[test]
    fn advance_epoch_is_monotonic_max() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        assert_eq!(c.advance_epoch(5).unwrap(), 5);
        // going backwards is a no-op, not a rollback
        assert_eq!(c.advance_epoch(2).unwrap(), 5);
        assert_eq!(server.epoch(), 5);
    }

    #[test]
    fn client_counts_ops_sent() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        assert_eq!(c.ops_sent(), 0);
        c.hello(7).unwrap();
        c.set("k", b"v").unwrap();
        c.get("k").unwrap();
        assert_eq!(c.ops_sent(), 3);
        assert!(server.metrics_snapshot().counter("store.requests") >= 3);
    }

    #[test]
    fn batch_pipelines_ops_in_one_frame() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        let resps = c
            .batch(vec![
                Request::Set { key: "a".into(), value: b"1".to_vec() },
                Request::Get { key: "a".into() },
                Request::Add { key: "n".into(), delta: 5 },
                Request::Heartbeat { rank: 1, incarnation: 1, step_tag: 0, device_code: -1 },
            ])
            .unwrap();
        assert_eq!(resps.len(), 4);
        assert_eq!(resps[0], Response::Ok);
        assert_eq!(resps[1], Response::Value(Bytes::from(&b"1"[..])));
        assert_eq!(resps[2], Response::Counter(5));
        assert_eq!(resps[3], Response::Ok);
        // one wire frame, four logical ops: pipelining amortises the
        // round-trip without changing message budgets
        let snap = server.metrics_snapshot();
        assert_eq!(snap.counter("store.frames"), 1);
        assert_eq!(c.ops_sent(), 4);
        assert_eq!(snap.counter("store.requests"), 4);
        assert_eq!(server.beats().len(), 1);
    }

    #[test]
    fn batch_stops_at_epoch_fence() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.advance_epoch(5).unwrap();
        let resps = c
            .batch(vec![
                Request::Set { key: "x".into(), value: b"1".to_vec() },
                Request::WaitEpoch { key: "absent".into(), epoch: 2 },
                Request::Set { key: "never".into(), value: b"2".to_vec() },
            ])
            .unwrap();
        // the fenced wait is the last executed op; the tail is skipped
        assert_eq!(resps.len(), 2);
        assert_eq!(resps[0], Response::Ok);
        assert_eq!(resps[1], Response::EpochFenced { current: 5 });
        assert!(c.get("x").unwrap().is_some());
        assert_eq!(c.get("never").unwrap(), None);
    }

    #[test]
    fn wait_inside_batch_blocks_then_runs_tail() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.batch(vec![
                Request::WaitEpoch { key: "late".into(), epoch: 0 },
                Request::Add { key: "after".into(), delta: 1 },
            ])
            .unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut c = TcpStoreClient::connect(addr).unwrap();
        // the batched Add must not run before its wait releases
        assert_eq!(c.add("after", 0).unwrap(), 0);
        c.set("late", b"v").unwrap();
        let resps = waiter.join().unwrap();
        assert_eq!(resps[0], Response::Value(Bytes::from(&b"v"[..])));
        assert_eq!(resps[1], Response::Counter(1));
    }

    #[test]
    fn set_wakes_only_matching_waiters() {
        // The thundering-herd regression (§11): the old single global
        // condvar woke every blocked waiter on every Set. Per-key
        // parking notifies exactly the matching key's slot, so K
        // waiters on K distinct keys are released by exactly K
        // publishes — `wake_count` counts publish-released parked
        // waiters (deterministic), never raw condvar notifies.
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let k = 6;
        let mut waiters = Vec::new();
        for i in 0..k {
            waiters.push(std::thread::spawn(move || {
                let mut c = TcpStoreClient::connect(addr).unwrap();
                c.wait(&format!("park/{i}")).unwrap()
            }));
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.metrics_snapshot().gauge("store.parked_waiters") < k as i64 {
            assert!(Instant::now() < deadline, "waiters never parked");
            std::thread::sleep(Duration::from_millis(2));
        }
        let wake0 = server.metrics_snapshot().counter("store.wakeups");
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.set("park/3", b"v3").unwrap();
        assert_eq!(&waiters.remove(3).join().unwrap()[..], b"v3");
        assert_eq!(
            server.metrics_snapshot().counter("store.wakeups") - wake0,
            1,
            "one publish must release exactly its own key's waiter"
        );
        for i in [0usize, 1, 2, 4, 5] {
            c.set(&format!("park/{i}"), b"v").unwrap();
        }
        for w in waiters {
            w.join().unwrap();
        }
        assert_eq!(
            server.metrics_snapshot().counter("store.wakeups") - wake0,
            k as u64,
            "K publishes to K distinct keys must release exactly K waiters"
        );
    }

    #[test]
    fn worker_pool_reuses_threads_across_connection_churn() {
        // Regression (§11 satellite): the old accept loop spawned one
        // thread per connection and pushed every JoinHandle into a Vec
        // joined only at shutdown — a long churn of short-lived
        // connections grew both without bound. The pool hands finished
        // workers the next connection instead. Pinned to the threaded
        // core: the worker gauges it asserts only exist there.
        let server = TcpStoreServer::start_with(
            "127.0.0.1:0".parse().unwrap(),
            StoreCore::Threads,
        )
        .unwrap();
        for i in 0..50 {
            {
                let mut c = TcpStoreClient::connect(server.addr()).unwrap();
                c.set("churn", format!("v{i}").as_bytes()).unwrap();
            }
            // let the worker observe the EOF and return to the pool
            std::thread::sleep(Duration::from_millis(2));
        }
        let snap = server.metrics_snapshot();
        assert!(
            snap.gauge("store.live_workers") <= 8,
            "live workers must track peak concurrency, not churn: {}",
            snap.gauge("store.live_workers")
        );
        assert!(
            snap.counter("store.workers_spawned") <= 16,
            "threads must be reused across churn: {} spawns for 50 connections",
            snap.counter("store.workers_spawned")
        );
        assert_eq!(snap.gauge("store.keys"), 1);
    }

    #[test]
    fn restore_claim_blocks_until_advertised() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let claimer = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.claim_restore(3, 0xABC).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.advance_epoch(3).unwrap();
        assert_eq!(c.advertise_restore(3, 0xABC, "10.0.0.1:9").unwrap(), None);
        assert_eq!(
            claimer.join().unwrap(),
            FencedWait::Value(Bytes::from(&b"10.0.0.1:9"[..]))
        );
    }

    #[test]
    fn restore_claim_released_retryably_by_epoch_bump() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let claimer = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            // claims a source that will never advertise (it died)
            c.claim_restore(1, 0x42).unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.advance_epoch(2).unwrap();
        assert_eq!(
            claimer.join().unwrap(),
            FencedWait::Superseded { current: 2 }
        );
    }

    #[test]
    fn abort_epoch_unless_is_atomic_with_release() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        // release key present -> abort refused, nothing changes
        c.set("ep1/go", b"go").unwrap();
        assert!(!c
            .abort_epoch_unless("ep1/go", "ep2/delta", b"!abort", 2)
            .unwrap());
        assert_eq!(server.epoch(), 0);
        assert_eq!(c.get("ep2/delta").unwrap(), None);
        // release key absent -> tombstone published + epoch fenced
        assert!(c
            .abort_epoch_unless("ep2/go", "ep3/delta", b"!abort", 3)
            .unwrap());
        assert_eq!(server.epoch(), 3);
        assert_eq!(
            c.get("ep3/delta").unwrap().as_deref(),
            Some(&b"!abort"[..])
        );
    }

    #[test]
    fn stale_advertisement_is_fenced() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.advance_epoch(7).unwrap();
        // advertising for an already-superseded epoch is rejected
        assert_eq!(
            c.advertise_restore(6, 0x1, "10.0.0.2:9").unwrap(),
            Some(7)
        );
        // the current epoch is accepted
        assert_eq!(c.advertise_restore(7, 0x1, "10.0.0.2:9").unwrap(), None);
    }

    #[test]
    fn server_shutdown_releases_fenced_waiters() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            // shutdown surfaces as an error, not a hang
            c.wait_epoch("never", 0)
        });
        std::thread::sleep(Duration::from_millis(50));
        drop(server);
        assert!(waiter.join().unwrap().is_err());
    }

    #[test]
    fn poisoned_stripe_still_answers_requests() {
        // Regression (DESIGN §10 hardening): a panicking handler
        // thread used to poison the store mutex and turn every later
        // `.lock().unwrap()` into a cascading panic — one bad client
        // killed the whole control plane. Stripe guards are recovered.
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.set("pre", b"survives").unwrap();

        for key in ["pre", "post"] {
            let sh = server.shared.clone();
            let key = key.to_string();
            let _ = std::thread::spawn(move || {
                let _guard = sh.stripe_for(&key).lock().unwrap();
                panic!("poison a stripe mutex (expected panic)");
            })
            .join();
        }
        assert!(
            server.shared.stripe_for("pre").is_poisoned(),
            "setup: stripe must be poisoned"
        );

        assert_eq!(c.get("pre").unwrap().as_deref(), Some(&b"survives"[..]));
        c.set("post", b"v").unwrap();
        assert_eq!(c.get("post").unwrap().as_deref(), Some(&b"v"[..]));
        assert_eq!(server.metrics_snapshot().gauge("store.keys"), 2);
        // fenced waits cross the same stripes + parking slots
        c.advance_epoch(1).unwrap();
        assert_eq!(
            c.wait_epoch("absent", 0).unwrap(),
            FencedWait::Superseded { current: 1 }
        );
    }

    #[test]
    fn heartbeat_upserts_latest_beat_per_rank() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.heartbeat(3, 1, 7, -1).unwrap();
        c.heartbeat(3, 1, 8, -1).unwrap();
        c.heartbeat(9, 2, 0, 4).unwrap();
        let beats = server.beats();
        assert_eq!(beats.len(), 2);
        let b3 = beats.iter().find(|b| b.rank == 3).unwrap();
        assert_eq!((b3.incarnation, b3.step_tag, b3.device_code), (1, 8, -1));
        let b9 = beats.iter().find(|b| b.rank == 9).unwrap();
        assert_eq!((b9.incarnation, b9.step_tag, b9.device_code), (2, 0, 4));
    }

    #[test]
    fn stale_incarnation_beat_cannot_refresh_replacement_lease() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.heartbeat(5, 2, 10, -1).unwrap(); // replacement, incarnation 2
        c.heartbeat(5, 1, 99, -1).unwrap(); // zombie predecessor
        let beats = server.beats();
        let b = beats.iter().find(|b| b.rank == 5).unwrap();
        assert_eq!((b.incarnation, b.step_tag), (2, 10));
    }

    #[test]
    fn del_prefix_removes_keys_and_counters() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.set("rdzv/1/delta", b"d").unwrap();
        c.set("rdzv/1/table", b"t").unwrap();
        c.set("rdzv/2/delta", b"d").unwrap();
        c.add("rdzv/1/arrived", 1).unwrap();
        assert_eq!(c.del_prefix("rdzv/1/").unwrap(), 3);
        assert_eq!(c.get("rdzv/1/delta").unwrap(), None);
        assert_eq!(c.get("rdzv/2/delta").unwrap().as_deref(), Some(&b"d"[..]));
        assert_eq!(c.del_prefix("nothing/").unwrap(), 0);
    }

    #[test]
    fn epoch_advance_prunes_epochs_two_behind() {
        // DESIGN §8 known limitation, resolved: per-epoch keys used to
        // be retained forever. Advancing to epoch e drops every
        // rdzv/restore key of epochs <= e-2; e and e-1 (late resync)
        // stay.
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        for e in 1..=4u64 {
            c.set(&format!("rdzv/{e}/delta"), b"d").unwrap();
            c.set(&format!("rdzv/{e}/table"), b"t").unwrap();
            c.set(&format!("restore/{e}/00ff"), b"a").unwrap();
            c.add(&format!("rdzv/{e}/arrived"), 1).unwrap();
        }
        c.set("ranktable/v1", b"keep").unwrap();
        c.advance_epoch(4).unwrap();
        // epochs 1 and 2 pruned, 3 and 4 retained, non-epoch keys kept
        assert_eq!(c.get("rdzv/1/delta").unwrap(), None);
        assert_eq!(c.get("rdzv/2/table").unwrap(), None);
        assert_eq!(c.get("restore/2/00ff").unwrap(), None);
        assert!(c.get("rdzv/3/delta").unwrap().is_some());
        assert!(c.get("rdzv/4/table").unwrap().is_some());
        assert!(c.get("ranktable/v1").unwrap().is_some());
        let snap = server.metrics_snapshot();
        assert_eq!(snap.gauge("store.keys"), 1 + 2 * 3);
        assert_eq!(snap.gauge("store.counters"), 2);
    }

    #[test]
    fn stats_wire_op_serves_live_snapshot_mid_run() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.hello(1).unwrap();
        c.set("k", b"v").unwrap();
        // park a waiter so the snapshot is taken mid-episode, with
        // another client blocked server-side
        let waiter = std::thread::spawn(move || {
            let mut w = TcpStoreClient::connect(addr).unwrap();
            w.wait("late").unwrap()
        });
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.metrics_snapshot().gauge("store.parked_waiters") < 1 {
            assert!(Instant::now() < deadline, "waiter never parked");
            std::thread::sleep(Duration::from_millis(2));
        }
        let snap = c.stats().unwrap();
        assert!(snap.counter("store.requests") >= 3, "{snap:?}");
        assert!(snap.counter("store.frames") >= 3, "{snap:?}");
        assert_eq!(snap.gauge("store.keys"), 1, "{snap:?}");
        assert_eq!(snap.gauge("store.parked_waiters"), 1, "{snap:?}");
        // the wire snapshot equals the in-process snapshot view
        assert_eq!(
            snap.counter("store.hellos"),
            server.metrics_snapshot().counter("store.hellos")
        );
        c.set("late", b"v").unwrap();
        waiter.join().unwrap();
    }

    #[test]
    fn traced_frames_stitch_into_the_clients_trace() {
        trace::set_recording(true);
        let root = trace::root("episode", "client");
        let trace_id = root.trace_id();
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        c.set_trace_ctx(root.ctx());
        c.set("traced", b"v").unwrap();
        c.get("traced").unwrap();
        c.batch(vec![Request::Add { key: "n".into(), delta: 1 }]).unwrap();
        // untraced again: no further events for this trace
        c.set_trace_ctx(None);
        c.set("untraced", b"v").unwrap();
        root.end();

        let events = trace::events_for(trace_id);
        let names: Vec<&str> = events.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, ["Set", "Get", "Batch"], "{events:?}");
        assert!(events.iter().all(|e| e.track == "store"));
    }

    #[test]
    fn server_shutdown_releases_waiters() {
        let server = TcpStoreServer::start().unwrap();
        let addr = server.addr();
        let waiter = std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            // will get NotFound when the server shuts down
            let _ = c.wait("never");
        });
        std::thread::sleep(Duration::from_millis(50));
        drop(server);
        waiter.join().unwrap();
    }

    /// Open fds for this process (one dirent per fd; the readdir's own
    /// fd inflates every sample equally, so deltas are exact).
    #[cfg(target_os = "linux")]
    fn open_fd_count() -> usize {
        std::fs::read_dir("/proc/self/fd").map(|d| d.count()).unwrap_or(0)
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn reactor_connection_churn_leaks_no_fds_or_registrations() {
        use std::io::Write as _;
        let server = TcpStoreServer::start().unwrap();
        assert_eq!(server.core(), StoreCore::Reactor);
        let addr = server.addr();
        // settle the steady-state fd population (listener, epoll fd,
        // wake eventfd) before taking the baseline
        {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.set("seed", b"v").unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.metrics_snapshot().gauge("store.registrations") != 0 {
            assert!(Instant::now() < deadline, "seed conn never deregistered");
            std::thread::sleep(Duration::from_millis(2));
        }
        let fd_baseline = open_fd_count();
        // 1k connect → park (Wait on a never-published key) →
        // disconnect cycles: every parked frame must be torn down
        // with its socket — entry out of the slot, registration and
        // parked gauges decremented, fd closed. A third of the cycles
        // give the reactor time to actually park; the rest race the
        // disconnect against frame processing.
        let frame = Request::Wait { key: "never".into() }.encode();
        for i in 0..1000 {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            s.write_all(&frame).unwrap();
            if i % 3 == 0 {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        // Everything must return to baseline. The fd slack absorbs
        // concurrent tests in this process opening sockets of their
        // own — an O(cycles) leak still blows past it by 10x.
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let snap = server.metrics_snapshot();
            if snap.gauge("store.registrations") == 0
                && snap.gauge("store.parked_waiters") == 0
                && open_fd_count() <= fd_baseline + 64
            {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "churn leaked state: registrations={} parked={} fds={} (baseline {})",
                snap.gauge("store.registrations"),
                snap.gauge("store.parked_waiters"),
                open_fd_count(),
                fd_baseline
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // Slot hygiene: the churned key's slot must be gone too — a
        // late publish wakes nobody, so the deterministic wakeup
        // counter stays untouched.
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.set("never", b"late").unwrap();
        assert_eq!(server.metrics_snapshot().counter("store.wakeups"), 0);
    }

    /// §15 backpressure: a peer that stops draining its socket
    /// mid-`Batch` response (the in-process stand-in for a
    /// bandwidth-capped link) must park its *connection* on EPOLLOUT —
    /// never the event loop — so every other client keeps full-speed
    /// service; and once the slow peer finally drains, both its
    /// pipelined Multi responses must arrive intact and in order.
    #[test]
    #[cfg(target_os = "linux")]
    fn slow_reader_mid_batch_stalls_nobody_and_keeps_frames_intact() {
        use std::io::Write as _;
        let server = TcpStoreServer::start().unwrap();
        assert_eq!(server.core(), StoreCore::Reactor);
        let addr = server.addr();
        // 128 Gets of a 64KiB value: an ~8MB Multi response, far past
        // any kernel socket-buffer pair — the reactor WILL hit
        // WouldBlock mid-flush and must wait for writability
        let big = vec![0xABu8; 64 * 1024];
        {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.set("big", &big).unwrap();
        }
        let gets: Vec<Request> =
            (0..128).map(|_| Request::Get { key: "big".into() }).collect();
        let frame = Request::Batch(gets).encode();
        let mut slow = TcpStream::connect(addr).unwrap();
        slow.write_all(&frame).unwrap();
        // pipeline a second Batch behind the first before reading a
        // byte: it must sit buffered, un-corrupted, behind the parked
        // flush ("one frame in flight per connection")
        slow.write_all(&frame).unwrap();
        // let the reactor fill the socket pair and park the flush
        std::thread::sleep(Duration::from_millis(100));
        // trickle a few bytes like a rate-capped link would, forcing
        // at least one extra EPOLLOUT park/resume cycle mid-frame
        let mut sip = [0u8; 4096];
        slow.read_exact(&mut sip).unwrap();
        // a concurrent client must make progress at loopback speed
        // while the slow connection sits mid-flush
        let t0 = Instant::now();
        let mut fast = TcpStoreClient::connect(addr).unwrap();
        for i in 0..200 {
            let key = format!("fast/{i}");
            fast.set(&key, b"v").unwrap();
            assert_eq!(fast.get(&key).unwrap().as_deref(), Some(&b"v"[..]));
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "fast client stalled behind a slow reader: {:?}",
            t0.elapsed()
        );
        // now drain: both Multi responses arrive whole, in order,
        // every value bit-exact — no frame-state corruption. The
        // first frame's length prefix and leading bytes were already
        // sipped; chain them back ahead of the live socket.
        let mut joined = std::io::Read::chain(&sip[..], &mut slow);
        for _ in 0..2 {
            let body = read_frame(&mut joined).unwrap();
            match Response::decode(&body).unwrap() {
                Response::Multi(rs) => {
                    assert_eq!(rs.len(), 128);
                    for r in rs {
                        assert_eq!(r, Response::Value(Bytes::from(&big[..])));
                    }
                }
                other => panic!("expected Multi, got {other:?}"),
            }
        }
    }
}
