//! Shard-aware state streaming: the checkpoint-free restore path as a
//! real wire protocol (paper §III-E, Fig. 6; DESIGN.md §9).
//!
//! A surviving replica *serves* its snapshot to each rank that lost the
//! same model-state shard; the transfer is chunked, per-chunk
//! checksummed, and **epoch-fenced**: a restore begun under rendezvous
//! epoch `e` aborts with a retryable [`RestoreError::Superseded`] the
//! moment a failure-during-recovery bumps the epoch, instead of
//! completing a transfer whose topology is already stale.
//!
//! Wire layout (all integers little-endian), one direction only
//! (source -> target):
//!
//! ```text
//! header   "FSTM" | version u32 | step u64 | epoch u64
//!          | pp u32 | tp u32 | zero u32            (the ShardId)
//!          | total_bytes u64 | chunk_bytes u32
//! trace    0x04 | trace_id u64 | span_id u64       (optional, once)
//! chunk    0x01 | index u32 | len u32 | payload | fnv1a(payload) u64
//! abort    0x02 | current_epoch u64
//! end      0x03 | chunk_count u32 | chained_hash u64
//! ```
//!
//! The trace frame is emitted (immediately after the header) only when
//! [`StreamConfig::trace`] carries a recording context, so untraced
//! streams stay byte-identical to version 1; it lets the receiver's
//! fetch span nest under the source's serve span in one flight-recorder
//! trace (DESIGN.md §12).
//!
//! The payload is the snapshot's canonical encoding
//! (`checkpoint::codec`), produced lazily by `SnapshotStream` — the
//! source never materialises the whole model in one buffer. `end`
//! carries the chunk-chained word-wise hash; the payload additionally
//! embeds the codec's own whole-stream checksum, so corruption is
//! caught per chunk *and* end to end.
//!
//! Source discovery runs through the epoch-fenced TCP store: a source
//! advertises `(epoch, transfer tag) -> host:port` with
//! `AdvertiseRestore`; each target claims the tag with `ClaimRestore`,
//! which blocks like a fenced wait and is released retryably when the
//! epoch moves (`comms::wire`, `comms::tcp_store`).

use crate::checkpoint::{codec, Snapshot};
use crate::config::ShardId;
use crate::telemetry::{trace, TraceCtx};
use crate::util::hash::{fnv1a, FNV_OFFSET};
use anyhow::anyhow;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const STREAM_MAGIC: &[u8; 4] = b"FSTM";
const STREAM_VERSION: u32 = 1;
const FRAME_CHUNK: u8 = 1;
const FRAME_ABORT: u8 = 2;
const FRAME_END: u8 = 3;
const FRAME_TRACE: u8 = 4;

/// Default transfer chunk: large enough to amortise syscalls, small
/// enough that fence checks land within milliseconds of an epoch bump.
pub const DEFAULT_CHUNK_BYTES: usize = 256 * 1024;
const MIN_CHUNK_BYTES: usize = 4 * 1024;
const MAX_CHUNK_BYTES: usize = 64 * 1024 * 1024;
/// Sanity cap on a single snapshot transfer (16 GiB).
const MAX_TOTAL_BYTES: u64 = 16 << 30;
/// IO inactivity bound on data-plane sockets: a peer frozen by a
/// network partition surfaces as a bounded `Fatal` stall within this
/// window instead of hanging a transfer past the abort contract (the
/// fence is only observable between frames).
pub const IO_STALL_TIMEOUT: Duration = Duration::from_secs(60);

/// Shared view of the current rendezvous epoch: the controller (or the
/// chaos driver) advances it when a failure-during-recovery fences the
/// cluster into a new epoch, and every in-flight transfer observes the
/// bump between chunks.
#[derive(Clone, Debug, Default)]
pub struct EpochFence(Arc<AtomicU64>);

impl EpochFence {
    pub fn new(epoch: u64) -> Self {
        EpochFence(Arc::new(AtomicU64::new(epoch)))
    }

    pub fn current(&self) -> u64 {
        self.0.load(Ordering::SeqCst)
    }

    /// Monotonic advance (max), mirroring the store's `AdvanceEpoch`.
    pub fn advance(&self, to: u64) {
        self.0.fetch_max(to, Ordering::SeqCst);
    }
}

/// Why a transfer did not complete.
#[derive(Debug)]
pub enum RestoreError {
    /// The rendezvous epoch moved past the transfer's fence — the
    /// restore must be replanned and retried at `current`.
    Superseded { current: u64 },
    /// Permanent failure: IO, corruption, protocol violation.
    Fatal(anyhow::Error),
}

impl RestoreError {
    pub fn retryable(&self) -> bool {
        matches!(self, RestoreError::Superseded { .. })
    }
}

impl std::fmt::Display for RestoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RestoreError::Superseded { current } => {
                write!(f, "restore superseded by epoch {current} (retryable)")
            }
            RestoreError::Fatal(e) => write!(f, "restore failed: {e:#}"),
        }
    }
}

impl From<std::io::Error> for RestoreError {
    fn from(e: std::io::Error) -> Self {
        RestoreError::Fatal(e.into())
    }
}

impl From<anyhow::Error> for RestoreError {
    fn from(e: anyhow::Error) -> Self {
        RestoreError::Fatal(e)
    }
}

pub type RestoreResult<T> = std::result::Result<T, RestoreError>;

/// Pack a (shard, source rank) pair into the store's opaque transfer
/// tag: pp(12b) | tp(12b) | zero(20b) | source(20b). One tag names one
/// advertised transfer, so several sources can serve the same shard
/// concurrently (parallel per-shard restore).
pub fn transfer_tag(shard: ShardId, source: usize) -> u64 {
    debug_assert!(shard.pp < (1 << 12) && shard.tp < (1 << 12));
    debug_assert!(shard.zero < (1 << 20) && source < (1 << 20));
    ((shard.pp as u64) << 52)
        | ((shard.tp as u64) << 40)
        | ((shard.zero as u64) << 20)
        | source as u64
}

/// Transfer parameters; `throttle` is a deterministic per-chunk delay
/// for tests and chaos campaigns that need to land an epoch bump
/// mid-transfer.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    pub chunk_bytes: usize,
    pub throttle: Option<Duration>,
    /// How long a source waits for a receiver to connect before the
    /// transfer is declared dead (bounded, never a hang).
    pub accept_deadline: Duration,
    /// IO inactivity bound on this transfer's sockets — flows from
    /// [`Timeouts::io_stall`](crate::config::Timeouts) so an impaired
    /// link widens it instead of spuriously tripping the watchdog.
    pub io_stall: Duration,
    /// Serve a listener's receivers one after another instead of
    /// concurrently — models a source whose single uplink serializes
    /// the legs (the pre-refactor broadcast baseline; used by the
    /// `state_restore` bench, not the recovery path).
    pub serial_serve: bool,
    /// Flight-recorder context the transfer's spans nest under; also
    /// forwarded in-band (`FRAME_TRACE`) so the receiver joins the
    /// same trace. `None` (the default) leaves the wire untouched.
    pub trace: Option<TraceCtx>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            throttle: None,
            accept_deadline: Duration::from_secs(60),
            io_stall: IO_STALL_TIMEOUT,
            serial_serve: false,
            trace: None,
        }
    }
}

impl StreamConfig {
    /// Derive the transfer deadlines from one [`Timeouts`] config —
    /// the §15 seam that lets campaigns scale every state-stream
    /// watchdog for a slow link in one place.
    ///
    /// [`Timeouts`]: crate::config::Timeouts
    pub fn from_timeouts(t: &crate::config::Timeouts) -> Self {
        StreamConfig {
            accept_deadline: t.accept_deadline,
            io_stall: t.io_stall,
            ..Default::default()
        }
    }
}

/// The length-fixed stream header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamHeader {
    pub step: u64,
    pub epoch: u64,
    pub shard: ShardId,
    pub total_bytes: u64,
    pub chunk_bytes: u32,
}

pub const HEADER_LEN: usize = 4 + 4 + 8 + 8 + 4 + 4 + 4 + 8 + 4;

impl StreamHeader {
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut out = [0u8; HEADER_LEN];
        let mut pos = 0;
        let mut put = |bytes: &[u8]| {
            out[pos..pos + bytes.len()].copy_from_slice(bytes);
            pos += bytes.len();
        };
        put(STREAM_MAGIC);
        put(&STREAM_VERSION.to_le_bytes());
        put(&self.step.to_le_bytes());
        put(&self.epoch.to_le_bytes());
        put(&(self.shard.pp as u32).to_le_bytes());
        put(&(self.shard.tp as u32).to_le_bytes());
        put(&(self.shard.zero as u32).to_le_bytes());
        put(&self.total_bytes.to_le_bytes());
        put(&self.chunk_bytes.to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8; HEADER_LEN]) -> RestoreResult<StreamHeader> {
        if &buf[0..4] != STREAM_MAGIC {
            return Err(RestoreError::Fatal(anyhow!("bad state-stream magic")));
        }
        let u32_at = |at: usize| u32::from_le_bytes(buf[at..at + 4].try_into().unwrap());
        let u64_at = |at: usize| u64::from_le_bytes(buf[at..at + 8].try_into().unwrap());
        let version = u32_at(4);
        if version != STREAM_VERSION {
            return Err(RestoreError::Fatal(anyhow!(
                "unsupported state-stream version {version}"
            )));
        }
        Ok(StreamHeader {
            step: u64_at(8),
            epoch: u64_at(16),
            shard: ShardId {
                pp: u32_at(24) as usize,
                tp: u32_at(28) as usize,
                zero: u32_at(32) as usize,
            },
            total_bytes: u64_at(36),
            chunk_bytes: u32_at(44),
        })
    }
}

/// Outcome of one served transfer.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    pub bytes: u64,
    pub chunks: u32,
    pub wall_s: f64,
}

/// Outcome of one fetched transfer.
#[derive(Debug, Clone, Copy)]
pub struct FetchStats {
    pub bytes: u64,
    pub chunks: u32,
    pub wall_s: f64,
}

/// What the receiving side requires of the incoming stream.
#[derive(Debug, Clone, Copy)]
pub struct Expect {
    pub epoch: u64,
    pub shard: ShardId,
    /// Required snapshot step (the episode's resume step), if pinned.
    pub step: Option<u64>,
}

fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..])? {
            0 => break,
            n => filled += n,
        }
    }
    Ok(filled)
}

/// Serve one snapshot to one receiver over `w`, chunked and fenced at
/// `epoch`. Sends an abort frame (so the receiver fails retryably, not
/// on a dead socket) and returns [`RestoreError::Superseded`] if the
/// fence advances mid-transfer.
pub fn serve_snapshot<W: Write>(
    w: &mut W,
    snap: &Snapshot,
    shard: ShardId,
    epoch: u64,
    fence: &EpochFence,
    cfg: &StreamConfig,
) -> RestoreResult<ServeStats> {
    let t0 = Instant::now();
    // chunk length stays a multiple of 8 so the chained word-wise hash
    // is boundary-stable between serve and fetch
    let chunk_bytes = cfg.chunk_bytes.clamp(MIN_CHUNK_BYTES, MAX_CHUNK_BYTES) & !7;
    let total_bytes = codec::encoded_len(snap) as u64;
    let header = StreamHeader {
        step: snap.step,
        epoch,
        shard,
        total_bytes,
        chunk_bytes: chunk_bytes as u32,
    };
    w.write_all(&header.encode())?;

    let mut span = trace::from_opt_ctx(cfg.trace, "serve_state", "state-stream");
    if let Some(ctx) = span.ctx() {
        let mut ctx_buf = Vec::with_capacity(trace::CTX_WIRE_LEN);
        ctx.encode_into(&mut ctx_buf);
        w.write_all(&[FRAME_TRACE])?;
        w.write_all(&ctx_buf)?;
    }

    let mut reader = codec::SnapshotStream::new(snap);
    let mut buf = vec![0u8; chunk_bytes];
    let mut index: u32 = 0;
    let mut sent: u64 = 0;
    let mut chained = FNV_OFFSET;
    loop {
        let current = fence.current();
        if current > epoch {
            w.write_all(&[FRAME_ABORT])?;
            w.write_all(&current.to_le_bytes())?;
            w.flush()?;
            return Err(RestoreError::Superseded { current });
        }
        let n = read_full(&mut reader, &mut buf)?;
        if n == 0 {
            break;
        }
        let payload = &buf[..n];
        let sum = fnv1a(payload, FNV_OFFSET);
        chained = fnv1a(payload, chained);
        w.write_all(&[FRAME_CHUNK])?;
        w.write_all(&index.to_le_bytes())?;
        w.write_all(&(n as u32).to_le_bytes())?;
        w.write_all(payload)?;
        w.write_all(&sum.to_le_bytes())?;
        index += 1;
        sent += n as u64;
        if let Some(d) = cfg.throttle {
            std::thread::sleep(d);
        }
    }
    w.write_all(&[FRAME_END])?;
    w.write_all(&index.to_le_bytes())?;
    w.write_all(&chained.to_le_bytes())?;
    w.flush()?;
    debug_assert_eq!(sent, total_bytes);
    span.set_detail(format!("bytes={sent} chunks={index}"));
    Ok(ServeStats { bytes: sent, chunks: index, wall_s: t0.elapsed().as_secs_f64() })
}

/// Receive one snapshot from `r`, verifying the header against
/// `expect`, every chunk's checksum, the chained end-of-stream hash,
/// and the payload's embedded codec checksum. Returns retryably when
/// either side's fence supersedes the transfer.
pub fn fetch_snapshot<R: Read>(
    r: &mut R,
    expect: &Expect,
    fence: &EpochFence,
) -> RestoreResult<(Snapshot, FetchStats)> {
    let t0 = Instant::now();
    let mut hdr_buf = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr_buf)?;
    let header = StreamHeader::decode(&hdr_buf)?;
    if header.epoch != expect.epoch {
        return Err(RestoreError::Fatal(anyhow!(
            "stream epoch {} does not match claim epoch {}",
            header.epoch,
            expect.epoch
        )));
    }
    if header.shard != expect.shard {
        return Err(RestoreError::Fatal(anyhow!(
            "stream carries shard {:?}, expected {:?}",
            header.shard,
            expect.shard
        )));
    }
    if let Some(step) = expect.step {
        if header.step != step {
            return Err(RestoreError::Fatal(anyhow!(
                "stream carries step {}, expected resume step {step}",
                header.step
            )));
        }
    }
    if header.total_bytes > MAX_TOTAL_BYTES {
        return Err(RestoreError::Fatal(anyhow!(
            "implausible transfer size {}",
            header.total_bytes
        )));
    }
    let chunk_cap = header.chunk_bytes as usize;
    if chunk_cap == 0 || chunk_cap > MAX_CHUNK_BYTES {
        // validate before allocating the chunk buffer: a corrupt
        // header must not trigger a multi-GB allocation
        return Err(RestoreError::Fatal(anyhow!(
            "implausible chunk size {}",
            header.chunk_bytes
        )));
    }

    // Verified chunks feed the incremental decoder as they arrive
    // (DESIGN.md §9): the receiver's peak memory is the decoded
    // tensors plus one chunk buffer — never encoded + decoded at
    // once, and never a multi-GiB eager allocation off an 8-byte
    // header field.
    let mut decoder = codec::SnapshotDecoder::new();
    let mut span = trace::from_opt_ctx(None, "fetch_state", "state-stream");
    let mut received: u64 = 0;
    let mut chained = FNV_OFFSET;
    let mut next_index: u32 = 0;
    let mut payload = vec![0u8; chunk_cap];
    loop {
        let current = fence.current();
        if current > expect.epoch {
            return Err(RestoreError::Superseded { current });
        }
        let mut kind = [0u8; 1];
        r.read_exact(&mut kind)?;
        match kind[0] {
            FRAME_CHUNK => {
                let mut meta = [0u8; 8];
                r.read_exact(&mut meta)?;
                let index = u32::from_le_bytes(meta[0..4].try_into().unwrap());
                let len = u32::from_le_bytes(meta[4..8].try_into().unwrap()) as usize;
                if index != next_index {
                    return Err(RestoreError::Fatal(anyhow!(
                        "chunk {index} out of order (expected {next_index})"
                    )));
                }
                if len == 0 || len > payload.len() {
                    return Err(RestoreError::Fatal(anyhow!(
                        "chunk {index} has bad length {len}"
                    )));
                }
                r.read_exact(&mut payload[..len])?;
                let mut sum = [0u8; 8];
                r.read_exact(&mut sum)?;
                if u64::from_le_bytes(sum) != fnv1a(&payload[..len], FNV_OFFSET) {
                    return Err(RestoreError::Fatal(anyhow!(
                        "chunk {index} checksum mismatch (corrupt transfer)"
                    )));
                }
                if received + len as u64 > header.total_bytes {
                    return Err(RestoreError::Fatal(anyhow!(
                        "chunks exceed the promised {} bytes (corrupt header)",
                        header.total_bytes
                    )));
                }
                chained = fnv1a(&payload[..len], chained);
                decoder.push(&payload[..len]).map_err(RestoreError::Fatal)?;
                received += len as u64;
                next_index += 1;
            }
            FRAME_ABORT => {
                let mut cur = [0u8; 8];
                r.read_exact(&mut cur)?;
                return Err(RestoreError::Superseded {
                    current: u64::from_le_bytes(cur),
                });
            }
            FRAME_TRACE => {
                let mut ctx_buf = [0u8; trace::CTX_WIRE_LEN];
                r.read_exact(&mut ctx_buf)?;
                let ctx = TraceCtx::decode(&ctx_buf).filter(|_| !span.active());
                if let Some(ctx) = ctx {
                    span = trace::from_ctx(ctx, "fetch_state", "state-stream");
                }
            }
            FRAME_END => {
                let mut tail = [0u8; 12];
                r.read_exact(&mut tail)?;
                let count = u32::from_le_bytes(tail[0..4].try_into().unwrap());
                let whole = u64::from_le_bytes(tail[4..12].try_into().unwrap());
                if count != next_index {
                    return Err(RestoreError::Fatal(anyhow!(
                        "stream ended after {next_index} chunks, header promised {count}"
                    )));
                }
                if whole != chained {
                    return Err(RestoreError::Fatal(anyhow!(
                        "end-of-stream hash mismatch (corrupt transfer)"
                    )));
                }
                break;
            }
            other => {
                return Err(RestoreError::Fatal(anyhow!(
                    "unknown state-stream frame kind {other}"
                )));
            }
        }
    }
    if received != header.total_bytes {
        return Err(RestoreError::Fatal(anyhow!(
            "received {received} bytes, header promised {}",
            header.total_bytes
        )));
    }
    let snap = decoder.finish().map_err(RestoreError::Fatal)?;
    if snap.step != header.step {
        return Err(RestoreError::Fatal(anyhow!(
            "payload step {} disagrees with header step {}",
            snap.step,
            header.step
        )));
    }
    span.set_detail(format!("bytes={received} chunks={next_index}"));
    Ok((
        snap,
        FetchStats {
            bytes: header.total_bytes,
            chunks: next_index,
            wall_s: t0.elapsed().as_secs_f64(),
        },
    ))
}

/// Serve `receivers` fenced transfers on a pre-bound listener — the
/// shared source-side loop of the worker plane and the restore-episode
/// driver. Connections are accepted under the fence + accept deadline,
/// then every receiver is served *concurrently*: one slow leg must
/// not stall (or IO-stall-timeout) the others, since each target's
/// read clock starts the moment it connects. Each socket gets the IO
/// stall bound, so a frozen receiver is a bounded `Fatal`, not a hang.
pub fn serve_listener(
    listener: &TcpListener,
    snap: &Snapshot,
    shard: ShardId,
    epoch: u64,
    receivers: usize,
    fence: &EpochFence,
    cfg: &StreamConfig,
) -> RestoreResult<ServeStats> {
    let t0 = Instant::now();
    listener
        .set_nonblocking(true)
        .map_err(|e| RestoreError::Fatal(e.into()))?;
    let deadline = Instant::now() + cfg.accept_deadline;
    let mut streams = Vec::with_capacity(receivers);
    while streams.len() < receivers {
        let current = fence.current();
        if current > epoch {
            return Err(RestoreError::Superseded { current });
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // some platforms let accepted sockets inherit the
                // listener's non-blocking mode; the framed writes
                // need blocking IO
                stream
                    .set_nonblocking(false)
                    .map_err(|e| RestoreError::Fatal(e.into()))?;
                stream.set_write_timeout(Some(cfg.io_stall)).ok();
                stream.set_nodelay(true).ok();
                streams.push(stream);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    return Err(RestoreError::Fatal(anyhow!(
                        "only {} of {receivers} receivers connected within {:?}",
                        streams.len(),
                        cfg.accept_deadline
                    )));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(RestoreError::Fatal(e.into())),
        }
    }

    let results: Vec<RestoreResult<ServeStats>> = if cfg.serial_serve {
        streams
            .iter_mut()
            .map(|stream| serve_snapshot(stream, snap, shard, epoch, fence, cfg))
            .collect()
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = streams
                .iter_mut()
                .map(|stream| {
                    scope.spawn(move || {
                        serve_snapshot(stream, snap, shard, epoch, fence, cfg)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(RestoreError::Fatal(anyhow!("serve thread panicked")))
                    })
                })
                .collect()
        })
    };
    let mut bytes = 0u64;
    let mut chunks = 0u32;
    let mut superseded: Option<u64> = None;
    let mut first_fatal: Option<anyhow::Error> = None;
    for r in results {
        match r {
            Ok(s) => {
                bytes += s.bytes;
                chunks += s.chunks;
            }
            Err(RestoreError::Superseded { current }) => {
                superseded = Some(superseded.unwrap_or(0).max(current));
            }
            Err(RestoreError::Fatal(e)) => {
                first_fatal.get_or_insert(e);
            }
        }
    }
    if let Some(current) = superseded {
        return Err(RestoreError::Superseded { current });
    }
    if let Some(e) = first_fatal {
        return Err(RestoreError::Fatal(e));
    }
    Ok(ServeStats { bytes, chunks, wall_s: t0.elapsed().as_secs_f64() })
}

/// Connect to an advertised source and fetch one shard, with connect
/// and IO-stall bounds so a dead or frozen source is a bounded
/// failure — the shared target-side entry of the worker plane and the
/// restore-episode driver.
pub fn fetch_from_addr(
    addr: SocketAddr,
    expect: &Expect,
    fence: &EpochFence,
) -> RestoreResult<(Snapshot, FetchStats)> {
    fetch_from_addr_via(
        &*crate::comms::link::default_dialer(),
        addr,
        expect,
        fence,
        &StreamConfig::default(),
    )
}

/// [`fetch_from_addr`] through an explicit dialer with explicit
/// deadlines — the entry impaired restore campaigns use to pull a
/// shard across a degraded link (DESIGN.md §15).
pub fn fetch_from_addr_via(
    dialer: &dyn crate::comms::link::Dialer,
    addr: SocketAddr,
    expect: &Expect,
    fence: &EpochFence,
    cfg: &StreamConfig,
) -> RestoreResult<(Snapshot, FetchStats)> {
    let mut link = dialer
        .dial(addr, Duration::from_secs(10))
        .map_err(|e| RestoreError::Fatal(e.into()))?;
    link.set_read_timeout(Some(cfg.io_stall)).ok();
    link.set_nodelay(true).ok();
    fetch_snapshot(&mut link, expect, fence)
}

/// Serve an arbitrary byte payload over the same chunk grammar —
/// header, per-chunk checksums, chained end hash, fenced abort — with
/// `step` carrying the payload's version (for redundancy stripes: the
/// training step the stripe encodes, DESIGN.md §16). The wire is
/// frame-identical to [`serve_snapshot`]; only the payload bytes
/// differ, so every transport property (retryable `Superseded`,
/// corruption detection, stall bounds) carries over to stripe
/// shipping unchanged.
pub fn serve_blob<W: Write>(
    w: &mut W,
    data: &[u8],
    step: u64,
    shard: ShardId,
    epoch: u64,
    fence: &EpochFence,
    cfg: &StreamConfig,
) -> RestoreResult<ServeStats> {
    let t0 = Instant::now();
    let chunk_bytes = cfg.chunk_bytes.clamp(MIN_CHUNK_BYTES, MAX_CHUNK_BYTES) & !7;
    if data.len() as u64 > MAX_TOTAL_BYTES {
        return Err(RestoreError::Fatal(anyhow!(
            "implausible blob size {}",
            data.len()
        )));
    }
    let header = StreamHeader {
        step,
        epoch,
        shard,
        total_bytes: data.len() as u64,
        chunk_bytes: chunk_bytes as u32,
    };
    w.write_all(&header.encode())?;

    let mut index: u32 = 0;
    let mut sent: u64 = 0;
    let mut chained = FNV_OFFSET;
    for payload in data.chunks(chunk_bytes) {
        let current = fence.current();
        if current > epoch {
            w.write_all(&[FRAME_ABORT])?;
            w.write_all(&current.to_le_bytes())?;
            w.flush()?;
            return Err(RestoreError::Superseded { current });
        }
        let sum = fnv1a(payload, FNV_OFFSET);
        chained = fnv1a(payload, chained);
        w.write_all(&[FRAME_CHUNK])?;
        w.write_all(&index.to_le_bytes())?;
        w.write_all(&(payload.len() as u32).to_le_bytes())?;
        w.write_all(payload)?;
        w.write_all(&sum.to_le_bytes())?;
        index += 1;
        sent += payload.len() as u64;
        if let Some(d) = cfg.throttle {
            std::thread::sleep(d);
        }
    }
    w.write_all(&[FRAME_END])?;
    w.write_all(&index.to_le_bytes())?;
    w.write_all(&chained.to_le_bytes())?;
    w.flush()?;
    Ok(ServeStats { bytes: sent, chunks: index, wall_s: t0.elapsed().as_secs_f64() })
}

/// Receive one [`serve_blob`] payload, verifying the header against
/// `expect`, every chunk checksum, and the chained end hash. Returns
/// the header (its `step` is the payload version) alongside the bytes.
pub fn fetch_blob<R: Read>(
    r: &mut R,
    expect: &Expect,
    fence: &EpochFence,
) -> RestoreResult<(StreamHeader, Vec<u8>, FetchStats)> {
    let t0 = Instant::now();
    let mut hdr_buf = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr_buf)?;
    let header = StreamHeader::decode(&hdr_buf)?;
    if header.epoch != expect.epoch {
        return Err(RestoreError::Fatal(anyhow!(
            "blob stream epoch {} does not match expected epoch {}",
            header.epoch,
            expect.epoch
        )));
    }
    if header.shard != expect.shard {
        return Err(RestoreError::Fatal(anyhow!(
            "blob stream carries shard {:?}, expected {:?}",
            header.shard,
            expect.shard
        )));
    }
    if let Some(step) = expect.step {
        if header.step != step {
            return Err(RestoreError::Fatal(anyhow!(
                "blob stream carries version {}, expected {step}",
                header.step
            )));
        }
    }
    if header.total_bytes > MAX_TOTAL_BYTES {
        return Err(RestoreError::Fatal(anyhow!(
            "implausible transfer size {}",
            header.total_bytes
        )));
    }
    let chunk_cap = header.chunk_bytes as usize;
    if chunk_cap == 0 || chunk_cap > MAX_CHUNK_BYTES {
        return Err(RestoreError::Fatal(anyhow!(
            "implausible chunk size {}",
            header.chunk_bytes
        )));
    }

    let mut data = Vec::with_capacity(header.total_bytes as usize);
    let mut chained = FNV_OFFSET;
    let mut next_index: u32 = 0;
    let mut payload = vec![0u8; chunk_cap];
    loop {
        let current = fence.current();
        if current > expect.epoch {
            return Err(RestoreError::Superseded { current });
        }
        let mut kind = [0u8; 1];
        r.read_exact(&mut kind)?;
        match kind[0] {
            FRAME_CHUNK => {
                let mut meta = [0u8; 8];
                r.read_exact(&mut meta)?;
                let index = u32::from_le_bytes(meta[0..4].try_into().unwrap());
                let len = u32::from_le_bytes(meta[4..8].try_into().unwrap()) as usize;
                if index != next_index {
                    return Err(RestoreError::Fatal(anyhow!(
                        "chunk {index} out of order (expected {next_index})"
                    )));
                }
                if len == 0 || len > payload.len() {
                    return Err(RestoreError::Fatal(anyhow!(
                        "chunk {index} has bad length {len}"
                    )));
                }
                r.read_exact(&mut payload[..len])?;
                let mut sum = [0u8; 8];
                r.read_exact(&mut sum)?;
                if u64::from_le_bytes(sum) != fnv1a(&payload[..len], FNV_OFFSET) {
                    return Err(RestoreError::Fatal(anyhow!(
                        "chunk {index} checksum mismatch (corrupt transfer)"
                    )));
                }
                if data.len() as u64 + len as u64 > header.total_bytes {
                    return Err(RestoreError::Fatal(anyhow!(
                        "chunks exceed the promised {} bytes (corrupt header)",
                        header.total_bytes
                    )));
                }
                chained = fnv1a(&payload[..len], chained);
                data.extend_from_slice(&payload[..len]);
                next_index += 1;
            }
            FRAME_ABORT => {
                let mut cur = [0u8; 8];
                r.read_exact(&mut cur)?;
                return Err(RestoreError::Superseded {
                    current: u64::from_le_bytes(cur),
                });
            }
            FRAME_TRACE => {
                let mut ctx_buf = [0u8; trace::CTX_WIRE_LEN];
                r.read_exact(&mut ctx_buf)?;
            }
            FRAME_END => {
                let mut tail = [0u8; 12];
                r.read_exact(&mut tail)?;
                let count = u32::from_le_bytes(tail[0..4].try_into().unwrap());
                let whole = u64::from_le_bytes(tail[4..12].try_into().unwrap());
                if count != next_index {
                    return Err(RestoreError::Fatal(anyhow!(
                        "stream ended after {next_index} chunks, header promised {count}"
                    )));
                }
                if whole != chained {
                    return Err(RestoreError::Fatal(anyhow!(
                        "end-of-stream hash mismatch (corrupt transfer)"
                    )));
                }
                break;
            }
            other => {
                return Err(RestoreError::Fatal(anyhow!(
                    "unknown state-stream frame kind {other}"
                )));
            }
        }
    }
    if data.len() as u64 != header.total_bytes {
        return Err(RestoreError::Fatal(anyhow!(
            "received {} bytes, header promised {}",
            data.len(),
            header.total_bytes
        )));
    }
    let stats = FetchStats {
        bytes: header.total_bytes,
        chunks: next_index,
        wall_s: t0.elapsed().as_secs_f64(),
    };
    Ok((header, data, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::net::{TcpListener, TcpStream};

    fn snap(step: u64, elems: usize) -> Snapshot {
        let t: Vec<f32> = (0..elems)
            .map(|i| ((i as u64).wrapping_mul(2654435761) % 1000) as f32 * 0.001)
            .collect();
        Snapshot { step, tensors: vec![t.clone(), t] }
    }

    fn shard() -> ShardId {
        ShardId { pp: 1, tp: 2, zero: 3 }
    }

    #[test]
    fn header_roundtrip() {
        let h = StreamHeader {
            step: 42,
            epoch: 7,
            shard: shard(),
            total_bytes: 1 << 20,
            chunk_bytes: 4096,
        };
        assert_eq!(StreamHeader::decode(&h.encode()).unwrap(), h);
    }

    #[test]
    fn transfer_tags_are_injective_within_bounds() {
        let mut seen = std::collections::BTreeSet::new();
        for pp in 0..3 {
            for tp in 0..3 {
                for zero in 0..4 {
                    for src in 0..5 {
                        assert!(seen.insert(transfer_tag(ShardId { pp, tp, zero }, src)));
                    }
                }
            }
        }
    }

    #[test]
    fn blob_roundtrip_multi_chunk() {
        // the stripe-shipping grammar: same frames, raw payload
        let data: Vec<u8> = (0..40_000u32).map(|i| (i % 251) as u8).collect();
        let fence = EpochFence::new(2);
        let cfg = StreamConfig { chunk_bytes: 8 * 1024, ..Default::default() };
        let mut wire = Vec::new();
        let stats = serve_blob(&mut wire, &data, 11, shard(), 2, &fence, &cfg).unwrap();
        assert!(stats.chunks > 1);
        assert_eq!(stats.bytes, data.len() as u64);
        assert_eq!(wire[HEADER_LEN], FRAME_CHUNK, "blob wire must share the grammar");

        let expect = Expect { epoch: 2, shard: shard(), step: Some(11) };
        let (hdr, back, fstats) =
            fetch_blob(&mut Cursor::new(&wire), &expect, &fence).unwrap();
        assert_eq!(back, data);
        assert_eq!(hdr.step, 11);
        assert_eq!(fstats.chunks, stats.chunks);

        // corruption is caught per chunk
        let mut bad = wire.clone();
        bad[HEADER_LEN + 20] ^= 0x10;
        let err = fetch_blob(&mut Cursor::new(&bad), &expect, &fence).unwrap_err();
        assert!(!err.retryable());
    }

    #[test]
    fn blob_serve_aborts_retryably_on_epoch_bump() {
        let data = vec![7u8; 64 * 1024];
        let fence = EpochFence::new(3);
        let cfg = StreamConfig { chunk_bytes: 4 * 1024, ..Default::default() };
        // bump the fence before serving: the first fence check trips
        fence.advance(4);
        let mut wire = Vec::new();
        match serve_blob(&mut wire, &data, 1, shard(), 3, &fence, &cfg) {
            Err(RestoreError::Superseded { current }) => assert_eq!(current, 4),
            other => panic!("expected Superseded, got {other:?}"),
        }
        // the receiver sees the in-band abort frame, also retryably
        let rx_fence = EpochFence::new(3);
        let expect = Expect { epoch: 3, shard: shard(), step: None };
        match fetch_blob(&mut Cursor::new(&wire), &expect, &rx_fence) {
            Err(RestoreError::Superseded { current }) => assert_eq!(current, 4),
            other => panic!("expected Superseded, got {other:?}"),
        }
    }

    #[test]
    fn in_memory_roundtrip_multi_chunk() {
        let s = snap(9, 20_000); // ~160 KB payload
        let fence = EpochFence::new(4);
        let cfg = StreamConfig { chunk_bytes: 8 * 1024, ..Default::default() };
        let mut wire = Vec::new();
        let stats = serve_snapshot(&mut wire, &s, shard(), 4, &fence, &cfg).unwrap();
        assert!(stats.chunks > 1, "must exercise the multi-chunk path");
        assert_eq!(stats.bytes, codec::encoded_len(&s) as u64);

        let expect = Expect { epoch: 4, shard: shard(), step: Some(9) };
        let (back, fstats) =
            fetch_snapshot(&mut Cursor::new(&wire), &expect, &fence).unwrap();
        assert_eq!(back, s);
        assert_eq!(fstats.chunks, stats.chunks);
        assert_eq!(fstats.bytes, stats.bytes);
    }

    #[test]
    fn fetch_decodes_incrementally_with_odd_tensor_sizes() {
        // Multi-tensor snapshot with word-unaligned tensor lengths
        // crossing many chunk boundaries: the receive path now feeds
        // verified chunks straight into the incremental decoder
        // (bounded receiver memory, DESIGN.md §9) and must agree
        // bit-for-bit with the reference codec.
        let t = |n: usize| (0..n).map(|i| (i as f32).sin()).collect::<Vec<f32>>();
        let s = Snapshot { step: 4, tensors: vec![t(10_001), t(333), t(7), t(0)] };
        let fence = EpochFence::new(2);
        let cfg = StreamConfig { chunk_bytes: 4 * 1024, ..Default::default() };
        let mut wire = Vec::new();
        let stats = serve_snapshot(&mut wire, &s, shard(), 2, &fence, &cfg).unwrap();
        assert!(stats.chunks > 5, "must cross many chunk boundaries");
        let expect = Expect { epoch: 2, shard: shard(), step: Some(4) };
        let (back, _) = fetch_snapshot(&mut Cursor::new(&wire), &expect, &fence).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn trace_frame_rides_in_band_and_stitches_fetch_under_serve() {
        trace::set_recording(true);
        let root = trace::root("restore", "test");
        let tid = root.trace_id();
        let s = snap(6, 2_000);
        let fence = EpochFence::new(1);
        let cfg = StreamConfig { chunk_bytes: 4096, trace: root.ctx(), ..Default::default() };
        let mut wire = Vec::new();
        serve_snapshot(&mut wire, &s, shard(), 1, &fence, &cfg).unwrap();
        assert_eq!(wire[HEADER_LEN], FRAME_TRACE, "trace frame must follow the header");
        let expect = Expect { epoch: 1, shard: shard(), step: Some(6) };
        let (back, _) = fetch_snapshot(&mut Cursor::new(&wire), &expect, &fence).unwrap();
        assert_eq!(back, s);
        root.end();
        let spans = trace::spans_for(tid);
        let serve = spans.iter().find(|sp| sp.name == "serve_state").unwrap();
        let fetch = spans.iter().find(|sp| sp.name == "fetch_state").unwrap();
        assert_eq!(fetch.parent, serve.span_id, "fetch must nest under serve");
        assert!(serve.detail.contains("bytes="), "{}", serve.detail);
        // an untraced config leaves the wire byte-identical to v1:
        // the first frame after the header is a chunk, not a trace
        let mut plain = Vec::new();
        serve_snapshot(&mut plain, &s, shard(), 1, &fence, &StreamConfig::default()).unwrap();
        assert_eq!(plain[HEADER_LEN], FRAME_CHUNK);
    }

    #[test]
    fn chunk_corruption_is_fatal_not_retryable() {
        let s = snap(2, 5_000);
        let fence = EpochFence::new(0);
        let cfg = StreamConfig { chunk_bytes: 4096, ..Default::default() };
        let mut wire = Vec::new();
        serve_snapshot(&mut wire, &s, shard(), 0, &fence, &cfg).unwrap();
        // flip a byte inside the first chunk payload (past header+frame meta)
        let at = HEADER_LEN + 9 + 100;
        wire[at] ^= 0x20;
        let expect = Expect { epoch: 0, shard: shard(), step: None };
        let err = fetch_snapshot(&mut Cursor::new(&wire), &expect, &fence).unwrap_err();
        assert!(!err.retryable(), "corruption must not be retried: {err}");
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn pre_bumped_fence_aborts_before_first_chunk() {
        let s = snap(1, 1_000);
        let fence = EpochFence::new(5);
        fence.advance(6);
        let mut wire = Vec::new();
        let err = serve_snapshot(
            &mut wire,
            &s,
            shard(),
            5,
            &fence,
            &StreamConfig::default(),
        )
        .unwrap_err();
        match err {
            RestoreError::Superseded { current } => assert_eq!(current, 6),
            other => panic!("expected superseded, got {other}"),
        }
        // the wire carries header + abort frame; the receiver sees a
        // retryable outcome, not a truncated stream
        let expect = Expect { epoch: 5, shard: shard(), step: None };
        let err = fetch_snapshot(&mut Cursor::new(&wire), &expect, &fence).unwrap_err();
        assert!(err.retryable(), "{err}");
    }

    #[test]
    fn mid_transfer_epoch_bump_aborts_over_sockets() {
        // Real sockets, throttled chunks, fence bumped mid-flight:
        // the source aborts retryably and the target observes either
        // the abort frame or its own fence — never a hang.
        let s = snap(3, 50_000);
        let fence = EpochFence::new(1);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        let server_fence = fence.clone();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let cfg = StreamConfig {
                chunk_bytes: 4096,
                throttle: Some(Duration::from_millis(2)),
                ..Default::default()
            };
            serve_snapshot(&mut stream, &s, shard(), 1, &server_fence, &cfg)
        });

        let bump_fence = fence.clone();
        let bumper = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            bump_fence.advance(2);
        });

        let mut stream = TcpStream::connect(addr).unwrap();
        let expect = Expect { epoch: 1, shard: shard(), step: Some(3) };
        let t0 = Instant::now();
        let res = fetch_snapshot(&mut stream, &expect, &fence);
        bumper.join().unwrap();
        let serve_res = server.join().unwrap();
        assert!(serve_res.is_err(), "source must abort");
        let err = res.unwrap_err();
        assert!(err.retryable(), "target must see a retryable abort: {err}");
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "abort must be prompt, not a hang"
        );
    }

    #[test]
    fn fetch_rejects_wrong_shard_epoch_and_step() {
        let s = snap(5, 100);
        let fence = EpochFence::new(2);
        let mut wire = Vec::new();
        serve_snapshot(&mut wire, &s, shard(), 2, &fence, &StreamConfig::default())
            .unwrap();
        let wrong_shard = Expect {
            epoch: 2,
            shard: ShardId { pp: 0, tp: 0, zero: 0 },
            step: None,
        };
        assert!(fetch_snapshot(&mut Cursor::new(&wire), &wrong_shard, &fence).is_err());
        let wrong_epoch = Expect { epoch: 3, shard: shard(), step: None };
        assert!(fetch_snapshot(&mut Cursor::new(&wire), &wrong_epoch, &fence).is_err());
        let wrong_step = Expect { epoch: 2, shard: shard(), step: Some(6) };
        assert!(fetch_snapshot(&mut Cursor::new(&wire), &wrong_step, &fence).is_err());
    }
}
