//! Pluggable link layer: every live-plane socket is created through a
//! [`Dialer`] and driven through the [`Link`] trait, so the same wire
//! protocols run unchanged over a perfect loopback (`DirectDialer`) or
//! an impaired path (`comms::netem`) injecting delay, jitter, loss,
//! bandwidth caps, and asymmetric partitions (DESIGN.md §15).
//!
//! The abstraction is deliberately thin — `Read + Write` plus the three
//! socket knobs the live plane actually uses (read deadline, Nagle,
//! peer identity) — so wire format and op accounting stay bit-identical
//! through any `Link` implementation: an impaired link may *delay* or
//! *drop* traffic, never reorder bytes within a direction or alter
//! frame contents.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Duration;

/// One established bidirectional byte stream of the live plane.
///
/// Implementations must preserve byte order per direction and deliver
/// writes atomically enough for the framed protocols: `comms::wire`
/// always hands a whole pre-encoded frame to a single `write` call, so
/// a link that drops or delays *whole writes* (netem partitions) can
/// never tear a frame.
pub trait Link: Read + Write + Send {
    /// Bound how long a blocking read may stall (None = forever).
    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()>;
    /// Disable (true) or re-enable Nagle batching on the underlying
    /// transport, where one exists.
    fn set_nodelay(&self, on: bool) -> io::Result<()>;
    /// Remote address of the link, for accounting and diagnostics.
    fn peer_addr(&self) -> io::Result<SocketAddr>;
}

impl Link for TcpStream {
    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        TcpStream::set_read_timeout(self, d)
    }

    fn set_nodelay(&self, on: bool) -> io::Result<()> {
        TcpStream::set_nodelay(self, on)
    }

    fn peer_addr(&self) -> io::Result<SocketAddr> {
        TcpStream::peer_addr(self)
    }
}

/// Creates [`Link`]s: the single seam through which every live-plane
/// client socket is opened — store clients, heartbeat emitters, state
/// streams, replication probes, endpoint discovery.
pub trait Dialer: Send + Sync {
    fn dial(&self, addr: SocketAddr, timeout: Duration) -> io::Result<Box<dyn Link>>;

    /// [`Dialer::dial`] with a caller-supplied *source label* — the
    /// (src, dst) pair key netem per-pair policies shape on (e.g. the
    /// replication shipper dials under `"repl"` so its follower links
    /// can be impaired independently of client traffic to the same
    /// address). The plain dialer ignores the label; only labeled
    /// impairment layers override this.
    fn dial_from(
        &self,
        src: &str,
        addr: SocketAddr,
        timeout: Duration,
    ) -> io::Result<Box<dyn Link>> {
        let _ = src;
        self.dial(addr, timeout)
    }

    /// Short label for diagnostics ("direct", "netem", ...).
    fn name(&self) -> &'static str {
        "dialer"
    }
}

/// The plain TCP dialer: `connect_timeout` + `TCP_NODELAY`, exactly
/// the socket the live plane always opened before the link layer.
#[derive(Debug, Clone, Copy, Default)]
pub struct DirectDialer;

impl Dialer for DirectDialer {
    fn dial(&self, addr: SocketAddr, timeout: Duration) -> io::Result<Box<dyn Link>> {
        let stream = TcpStream::connect_timeout(&addr, timeout)?;
        stream.set_nodelay(true).ok();
        Ok(Box::new(stream))
    }

    fn name(&self) -> &'static str {
        "direct"
    }
}

fn default_slot() -> &'static RwLock<Arc<dyn Dialer>> {
    static SLOT: OnceLock<RwLock<Arc<dyn Dialer>>> = OnceLock::new();
    SLOT.get_or_init(|| RwLock::new(Arc::new(DirectDialer)))
}

/// The process-wide default dialer. Paths with no explicit dialer in
/// hand (bare `TcpStoreClient::connect`, the replication shipper) dial
/// through this; campaigns that impair a whole process install a netem
/// dialer here. Parallel-running tests must *not* mutate it — they
/// pass explicit dialers (or front a `NetemProxy`) instead.
pub fn default_dialer() -> Arc<dyn Dialer> {
    default_slot().read().unwrap().clone()
}

/// Replace the process-wide default dialer (returns the previous one).
pub fn install_default_dialer(d: Arc<dyn Dialer>) -> Arc<dyn Dialer> {
    std::mem::replace(&mut *default_slot().write().unwrap(), d)
}

/// Restore the plain TCP default.
pub fn reset_default_dialer() {
    install_default_dialer(Arc::new(DirectDialer));
}

/// Bounded reconnect jitter: uniform in [0.5·base, 1.5·base), keyed by
/// `(salt, attempt)` so each client draws a deterministic but distinct
/// delay. After a partition heals or a primary dies, the fleet's
/// reconnect attempts spread across a full base interval instead of
/// stampeding the promoted store in lockstep (DESIGN.md §15).
pub fn jittered(base: Duration, salt: u64, attempt: u32) -> Duration {
    let seed = salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (attempt as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93);
    let mut rng = crate::util::Rng::new(seed);
    base.mul_f64(rng.range_f64(0.5, 1.5))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpListener;

    #[test]
    fn direct_dialer_is_a_transparent_tcp_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 5];
            s.read_exact(&mut buf).unwrap();
            s.write_all(&buf).unwrap();
        });
        let mut link = DirectDialer.dial(addr, Duration::from_secs(5)).unwrap();
        assert_eq!(link.peer_addr().unwrap(), addr);
        link.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        link.write_all(b"hello").unwrap();
        let mut back = [0u8; 5];
        link.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"hello");
        echo.join().unwrap();
    }

    #[test]
    fn default_dialer_roundtrip_install_reset() {
        // Only sanity-check the accessor contract — parallel tests
        // must not observe a mutated global, so install/reset happen
        // back to back with the same value.
        let prev = default_dialer();
        let again = install_default_dialer(prev.clone());
        assert_eq!(again.name(), prev.name());
    }

    #[test]
    fn jitter_is_bounded_and_spread() {
        let base = Duration::from_millis(100);
        let mut seen = std::collections::BTreeSet::new();
        for salt in 0..32u64 {
            let d = jittered(base, salt, 1);
            assert!(d >= Duration::from_millis(50), "{d:?} below bound");
            assert!(d < Duration::from_millis(150), "{d:?} above bound");
            seen.insert(d.as_micros());
        }
        assert!(seen.len() >= 16, "jitter must spread, got {} values", seen.len());
        // deterministic per (salt, attempt)
        assert_eq!(jittered(base, 7, 3), jittered(base, 7, 3));
        assert_ne!(jittered(base, 7, 3), jittered(base, 7, 4));
    }
}
