//! In-process synchronous collectives for the DP worker threads.
//!
//! The real training engine runs each simulated device as an OS thread;
//! these collectives provide the gradient allreduce (which doubles as
//! the paper's pre-optimizer barrier, §III-E Fig. 7), broadcast (replica
//! restoration), barrier, and gather (original-ranktable baseline).
//!
//! Failure semantics mirror NCCL-style stacks:
//! * if a participant dies and never arrives, peers block until the
//!   configured timeout — exactly the "hang" the vanilla baseline
//!   detects after 1800 s;
//! * `poison()` aborts all pending and future calls (the controller's
//!   stop/clean/reset path);
//! * after recovery the group is rebuilt with `reset()`, bumping the
//!   epoch so stale participants cannot rejoin silently.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveError {
    /// Aborted via `poison()` (controller-initiated reset).
    Poisoned,
    /// A peer failed to arrive within the timeout (hang detection).
    Timeout,
}

impl std::fmt::Display for CollectiveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CollectiveError::Poisoned => write!(f, "collective poisoned"),
            CollectiveError::Timeout => write!(f, "collective timeout"),
        }
    }
}

impl std::error::Error for CollectiveError {}

struct State {
    epoch: u64,
    size: usize,
    poisoned: bool,
    // generation state shared by all collective kinds (one op at a time
    // per group, as in a CUDA-stream-ordered collective sequence)
    arrived: usize,
    departed: usize,
    complete: bool,
    acc: Vec<f32>,
    bytes: Option<Arc<Vec<u8>>>,
    gathered: Vec<Option<Vec<u8>>>,
}

/// A synchronous collective group of fixed size.
pub struct Collective {
    state: Mutex<State>,
    cv: Condvar,
    timeout: Duration,
}

impl Collective {
    pub fn new(size: usize, timeout: Duration) -> Arc<Self> {
        assert!(size > 0);
        Arc::new(Collective {
            state: Mutex::new(State {
                epoch: 0,
                size,
                poisoned: false,
                arrived: 0,
                departed: 0,
                complete: false,
                acc: Vec::new(),
                bytes: None,
                gathered: Vec::new(),
            }),
            cv: Condvar::new(),
            timeout,
        })
    }

    pub fn size(&self) -> usize {
        self.state.lock().unwrap().size
    }

    pub fn epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }

    /// Abort all pending and future operations.
    pub fn poison(&self) {
        let mut st = self.state.lock().unwrap();
        st.poisoned = true;
        self.cv.notify_all();
    }

    /// Rebuild the group after recovery: clears poison, bumps the epoch,
    /// resets generation state, optionally resizes.
    pub fn reset(&self, size: usize) {
        let mut st = self.state.lock().unwrap();
        st.epoch += 1;
        st.size = size;
        st.poisoned = false;
        st.arrived = 0;
        st.departed = 0;
        st.complete = false;
        st.acc.clear();
        st.bytes = None;
        st.gathered.clear();
        self.cv.notify_all();
    }

    fn enter<'a>(
        &'a self,
        deadline: Instant,
    ) -> Result<std::sync::MutexGuard<'a, State>, CollectiveError> {
        let mut st = self.state.lock().unwrap();
        // Wait out the tail of a previous generation.
        loop {
            if st.poisoned {
                return Err(CollectiveError::Poisoned);
            }
            if !(st.complete && st.departed < st.size) {
                return Ok(st);
            }
            let (guard, res) = self
                .cv
                .wait_timeout(st, remaining(deadline)?)
                .unwrap();
            st = guard;
            if res.timed_out() {
                st.poisoned = true;
                self.cv.notify_all();
                return Err(CollectiveError::Timeout);
            }
        }
    }

    fn wait_complete<'a>(
        &'a self,
        mut st: std::sync::MutexGuard<'a, State>,
        deadline: Instant,
    ) -> Result<std::sync::MutexGuard<'a, State>, CollectiveError> {
        loop {
            if st.poisoned {
                return Err(CollectiveError::Poisoned);
            }
            if st.complete {
                return Ok(st);
            }
            let (guard, res) = self
                .cv
                .wait_timeout(st, remaining(deadline)?)
                .unwrap();
            st = guard;
            if res.timed_out() {
                st.poisoned = true;
                self.cv.notify_all();
                return Err(CollectiveError::Timeout);
            }
        }
    }

    fn depart(&self, mut st: std::sync::MutexGuard<'_, State>) {
        st.departed += 1;
        if st.departed == st.size {
            st.complete = false;
            st.arrived = 0;
            st.acc.clear();
            st.bytes = None;
            st.gathered.clear();
        }
        self.cv.notify_all();
    }

    /// All-reduce (mean) over f32 buffers. Blocks until all `size`
    /// participants contribute; `data` is replaced by the element-wise
    /// mean. This is the gradient synchronization *and* the paper's
    /// pre-optimizer barrier in one operation.
    pub fn allreduce_mean(&self, data: &mut [f32]) -> Result<(), CollectiveError> {
        let deadline = Instant::now() + self.timeout;
        let mut st = self.enter(deadline)?;
        if st.arrived == 0 {
            st.acc = data.to_vec();
        } else {
            assert_eq!(st.acc.len(), data.len(), "allreduce shape mismatch");
            for (a, d) in st.acc.iter_mut().zip(data.iter()) {
                *a += *d;
            }
        }
        st.arrived += 1;
        if st.arrived == st.size {
            let n = st.size as f32;
            for a in st.acc.iter_mut() {
                *a /= n;
            }
            st.complete = true;
            st.departed = 0;
            self.cv.notify_all();
        } else {
            st = self.wait_complete(st, deadline)?;
        }
        data.copy_from_slice(&st.acc);
        self.depart(st);
        Ok(())
    }

    /// Barrier: returns when all participants arrive.
    pub fn barrier(&self) -> Result<(), CollectiveError> {
        let deadline = Instant::now() + self.timeout;
        let mut st = self.enter(deadline)?;
        st.arrived += 1;
        if st.arrived == st.size {
            st.complete = true;
            st.departed = 0;
            self.cv.notify_all();
        } else {
            st = self.wait_complete(st, deadline)?;
        }
        self.depart(st);
        Ok(())
    }

    /// Broadcast: the root passes `Some(bytes)`, everyone receives them.
    /// Used for DP-replica state restoration (§III-E Fig. 6).
    pub fn broadcast(
        &self,
        root_data: Option<Arc<Vec<u8>>>,
    ) -> Result<Arc<Vec<u8>>, CollectiveError> {
        let deadline = Instant::now() + self.timeout;
        let mut st = self.enter(deadline)?;
        if let Some(d) = root_data {
            assert!(st.bytes.is_none(), "two roots in broadcast");
            st.bytes = Some(d);
        }
        st.arrived += 1;
        if st.arrived == st.size {
            assert!(st.bytes.is_some(), "broadcast completed without a root");
            st.complete = true;
            st.departed = 0;
            self.cv.notify_all();
        } else {
            st = self.wait_complete(st, deadline)?;
        }
        let out = st.bytes.clone().expect("broadcast payload");
        self.depart(st);
        Ok(out)
    }

    /// Gather: every rank contributes bytes; all receive the full list
    /// (the original ranktable collect+distribute baseline).
    pub fn all_gather(
        &self,
        rank: usize,
        data: Vec<u8>,
    ) -> Result<Vec<Vec<u8>>, CollectiveError> {
        let deadline = Instant::now() + self.timeout;
        let mut st = self.enter(deadline)?;
        if st.gathered.is_empty() {
            let size = st.size;
            st.gathered = vec![None; size];
        }
        assert!(st.gathered[rank].is_none(), "duplicate rank {rank}");
        st.gathered[rank] = Some(data);
        st.arrived += 1;
        if st.arrived == st.size {
            st.complete = true;
            st.departed = 0;
            self.cv.notify_all();
        } else {
            st = self.wait_complete(st, deadline)?;
        }
        let out: Vec<Vec<u8>> = st
            .gathered
            .iter()
            .map(|o| o.clone().expect("gather slot"))
            .collect();
        self.depart(st);
        Ok(out)
    }
}

fn remaining(deadline: Instant) -> Result<Duration, CollectiveError> {
    let now = Instant::now();
    if now >= deadline {
        Err(CollectiveError::Timeout)
    } else {
        Ok(deadline - now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn group(n: usize) -> Arc<Collective> {
        Collective::new(n, Duration::from_secs(5))
    }

    #[test]
    fn allreduce_mean_of_ranks() {
        let g = group(4);
        let mut handles = Vec::new();
        for rank in 0..4 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                let mut data = vec![rank as f32; 8];
                g.allreduce_mean(&mut data).unwrap();
                data
            }));
        }
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out, vec![1.5f32; 8]); // mean(0,1,2,3)
        }
    }

    #[test]
    fn consecutive_generations_do_not_mix() {
        let g = group(2);
        let mut handles = Vec::new();
        for rank in 0..2 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                let mut results = Vec::new();
                for step in 0..50 {
                    let mut data = vec![(rank + step) as f32];
                    g.allreduce_mean(&mut data).unwrap();
                    results.push(data[0]);
                }
                results
            }));
        }
        for h in handles {
            let results = h.join().unwrap();
            for (step, v) in results.iter().enumerate() {
                assert_eq!(*v, step as f32 + 0.5);
            }
        }
    }

    #[test]
    fn broadcast_from_root() {
        let g = group(3);
        let mut handles = Vec::new();
        for rank in 0..3 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                let payload = (rank == 1).then(|| Arc::new(vec![7u8, 8, 9]));
                g.broadcast(payload).unwrap()
            }));
        }
        for h in handles {
            assert_eq!(*h.join().unwrap(), vec![7u8, 8, 9]);
        }
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let g = group(3);
        let mut handles = Vec::new();
        for rank in 0..3 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                g.all_gather(rank, vec![rank as u8]).unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![vec![0u8], vec![1], vec![2]]);
        }
    }

    #[test]
    fn missing_peer_times_out() {
        let g = Collective::new(2, Duration::from_millis(100));
        let mut data = vec![1.0f32];
        let err = g.allreduce_mean(&mut data).unwrap_err();
        assert_eq!(err, CollectiveError::Timeout);
    }

    #[test]
    fn poison_aborts_waiters() {
        let g = group(2);
        let g2 = g.clone();
        let waiter = std::thread::spawn(move || {
            let mut data = vec![1.0f32];
            g2.allreduce_mean(&mut data)
        });
        std::thread::sleep(Duration::from_millis(50));
        g.poison();
        assert_eq!(waiter.join().unwrap().unwrap_err(), CollectiveError::Poisoned);
        // and future calls fail fast
        assert_eq!(g.barrier().unwrap_err(), CollectiveError::Poisoned);
    }

    #[test]
    fn reset_revives_group_and_bumps_epoch() {
        let g = group(2);
        g.poison();
        assert!(g.barrier().is_err());
        g.reset(3);
        assert_eq!(g.epoch(), 1);
        assert_eq!(g.size(), 3);
        let mut handles = Vec::new();
        for _ in 0..3 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || g.barrier()));
        }
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn timeout_poisons_group_for_peers() {
        let g = Collective::new(3, Duration::from_millis(150));
        let mut handles = Vec::new();
        for _ in 0..2 {
            // only 2 of 3 arrive
            let g = g.clone();
            handles.push(std::thread::spawn(move || g.barrier()));
        }
        let mut errs = Vec::new();
        for h in handles {
            errs.push(h.join().unwrap().unwrap_err());
        }
        assert!(errs.contains(&CollectiveError::Timeout));
    }
}
