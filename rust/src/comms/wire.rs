//! Length-prefixed wire protocol for the TCP store.
//!
//! Frames: `u32-le length | u8 opcode | payload`. Payload strings are
//! `u32-le len | bytes`. Deliberately tiny — just enough to implement
//! the PyTorch-TCPStore-style set/get/wait/add operations.
//!
//! Since the data-plane redesign (DESIGN.md §11):
//!
//! * values travel as [`Bytes`] (`Arc<[u8]>`) so the store can answer
//!   `Get`/`Wait` with a reference-count bump instead of a deep copy;
//! * `Batch`/`Multi` carry a pipelined op sequence in one frame — one
//!   round-trip for multi-op protocols (survivor re-key, per-node
//!   heartbeat coalescing). The server executes a batch serially and
//!   **stops at the first `EpochFenced` response** (the remaining ops
//!   are not executed), so fenced sequences never run their tail
//!   against a superseded epoch;
//! * responses are encoded into a reusable per-connection buffer
//!   ([`Response::encode_into`]) instead of a fresh `Vec` per frame,
//!   and [`read_frame_into`] reuses the connection's read buffer;
//! * any request frame may carry an **optional trailing trace
//!   context** (16 bytes, DESIGN.md §12) after its structured fields —
//!   [`Request::encode_traced`] appends it, [`Request::decode_traced`]
//!   recovers it, and decoders that don't know the field ignore
//!   trailing bytes, so old and new peers interoperate in both
//!   directions.

use crate::telemetry::trace::TraceCtx;
use anyhow::{bail, Result};
use std::io::{Read, Write};

/// Reference-counted value bytes: cloned by refcount on the store's
/// hot path, copied only at the wire boundary.
pub type Bytes = std::sync::Arc<[u8]>;

/// Cap on ops per `Batch` frame (sanity bound for decode).
pub const MAX_BATCH_OPS: usize = 65_536;

/// Hard cap on one wire frame's body — shared by every reader (the
/// client codec here and the server's idle-aware read path) so the
/// two sides can never disagree on what is "too large".
pub const MAX_FRAME_BYTES: usize = 256 * 1024 * 1024;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// set(key, value)
    Set { key: String, value: Vec<u8> },
    /// get(key) -> value | NotFound
    Get { key: String },
    /// wait(key): block until key exists -> value
    Wait { key: String },
    /// add(key, delta) -> new value (atomic counter, used for barriers)
    Add { key: String, delta: i64 },
    /// number of keys in the store
    Count,
    /// connection handshake (counts clients, used by establishment)
    Hello { client_id: u64 },
    /// wait(key) fenced at a rendezvous epoch: blocks like `Wait`, but
    /// if the store's epoch advances past `epoch` the waiter is
    /// released with `EpochFenced` instead of the value (retryable —
    /// re-issue at the returned epoch). The group-rebuild primitive.
    WaitEpoch { key: String, epoch: u64 },
    /// advance the store's rendezvous epoch to max(current, to) and
    /// wake every blocked waiter -> Counter(new epoch)
    AdvanceEpoch { to: u64 },
    /// a restore source advertises `addr` for one state transfer
    /// (`tag` = packed shard + source, see `state_stream::transfer_tag`)
    /// under the given epoch -> Ok, or EpochFenced when the epoch has
    /// already moved on (the advertisement would be stale)
    AdvertiseRestore { epoch: u64, tag: u64, addr: String },
    /// a restore target claims the advertised source for `tag`: blocks
    /// like `WaitEpoch` until the advertisement lands -> Value(addr),
    /// or EpochFenced when a failure-during-recovery bumps the epoch
    /// (retryable — replan the restore at the returned epoch)
    ClaimRestore { epoch: u64, tag: u64 },
    /// atomically abort a rendezvous epoch *unless* its release key
    /// already exists: under the release key's stripe lock, if
    /// `unless_key` is absent, fence the epoch to `to`, then publish
    /// `tombstone_key = tombstone` -> Counter(1); if `unless_key` is
    /// present the barrier released first and nothing happens ->
    /// Counter(0). Serialized with `Set` and the fenced waits on that
    /// stripe, this closes the supervised barrier's check-then-abort
    /// race.
    AbortEpoch {
        unless_key: String,
        tombstone_key: String,
        tombstone: Vec<u8>,
        to: u64,
    },
    /// one worker liveness beat (the paper's monitoring process +
    /// device plugin on the wire, DESIGN.md §10): upserts the rank's
    /// beat record keyed by `(rank, incarnation)` — a beat from a
    /// stale incarnation is dropped, so a replacement's lease can
    /// never be refreshed by its dead predecessor -> Ok
    Heartbeat {
        rank: u64,
        incarnation: u64,
        /// Paper step tag: i / -1 / i+1 (stall detection input).
        step_tag: i64,
        /// Device-plugin hardware report: -1 = none, else a
        /// `FailureKind` discriminant.
        device_code: i64,
    },
    /// delete every key starting with `prefix` -> Counter(removed).
    /// The pruning primitive behind bounded per-epoch key retention.
    DelPrefix { prefix: String },
    /// pipelined op sequence, executed serially server-side ->
    /// Multi(responses). Execution stops at the first `EpochFenced`
    /// sub-response (included in the Multi; the tail is skipped), so a
    /// fenced prefix can never commit its dependent suffix. Batches do
    /// not nest.
    Batch(Vec<Request>),
    /// live introspection (DESIGN.md §12): serve the store's unified
    /// metrics-registry snapshot -> Value(JSON bytes), readable
    /// mid-episode by any client (`telemetry::Snapshot::parse`).
    Stats,
    /// replication log shipment, primary -> replica (DESIGN.md §13):
    /// apply `ops` starting at log index `start_index` ->
    /// Counter(replica's applied index). Entries are flat committed
    /// mutations (plus `DedupDone` markers); `Batch`, `Dedup` and
    /// nested `Replicate` are rejected at decode.
    Replicate { start_index: u64, ops: Vec<Request> },
    /// replication status probe -> Value(17 bytes: `role u8 |
    /// applied-index u64-le | epoch u64-le`). The `StoreSession`
    /// primary-discovery primitive — cheap enough to send to every
    /// endpoint on (re)connect.
    ReplStatus,
    /// promote the receiving node to primary, shipping its log to the
    /// given peer replica addresses from now on -> Ok. Idempotent on
    /// an existing primary.
    Promote { peers: Vec<String> },
    /// exactly-once wrapper (client failover replay primitive):
    /// execute `op` once and cache its encoded response under `id`; a
    /// replayed `Dedup` with the same id returns the cached response
    /// without re-executing -> the inner op's response. May wrap a
    /// `Batch`; never wraps `Replicate`/`Dedup`/`DedupDone`.
    Dedup { id: u64, op: Box<Request> },
    /// log-only entry: a dedup-cached encoded response being
    /// replicated so the cache survives failover. Never sent by
    /// clients; a replica installs the cache entry instead of
    /// re-executing anything -> Ok.
    DedupDone { id: u64, resp: Vec<u8> },
    /// read the store's heartbeat beat table -> Value(encoded records:
    /// `count u32 | {rank u64 | incarnation u64 | step_tag i64 |
    /// device_code i64 | age_ms u64}*`). Beat freshness crosses the
    /// wire as an age relative to the serving node's clock (an
    /// `Instant` can't), so a promoted standby can rebuild lease state
    /// from real beats instead of derived `ctl/leases` keys. Served by
    /// replicas too — the whole point is reading it after the primary
    /// died.
    Beats,
    /// replica (re)attach bootstrap, primary -> rejoining replica
    /// (DESIGN.md §13): replace the replica's entire state with the
    /// snapshot `ops` (flat mutations, same grammar as `Replicate`
    /// entries) and set its applied index to `high_water` ->
    /// Counter(applied). Log shipments at indices <= `high_water`
    /// arriving afterwards are skipped idempotently; the tail replays
    /// normally.
    InstallState { high_water: u64, ops: Vec<Request> },
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    Ok,
    Value(Bytes),
    NotFound,
    Counter(i64),
    CountIs(u64),
    HelloAck,
    /// A fenced wait was superseded: the store's rendezvous epoch is
    /// now `current`, past the epoch the waiter was fenced at.
    EpochFenced { current: u64 },
    /// Per-op responses for a `Batch`; possibly shorter than the batch
    /// when an `EpochFenced` aborted the tail.
    Multi(Vec<Response>),
    /// The receiving store node is a replica: mutating and blocking
    /// ops must go to the primary. The `StoreSession` treats this as
    /// a failover trigger — rediscover the primary and retry.
    NotPrimary,
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    if *pos + 4 > buf.len() {
        bail!("frame underrun");
    }
    let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

fn get_bytes(buf: &[u8], pos: &mut usize) -> Result<Vec<u8>> {
    let len = get_u32(buf, pos)? as usize;
    if *pos + len > buf.len() {
        bail!("frame underrun");
    }
    let v = buf[*pos..*pos + len].to_vec();
    *pos += len;
    Ok(v)
}

fn get_string(buf: &[u8], pos: &mut usize) -> Result<String> {
    Ok(String::from_utf8(get_bytes(buf, pos)?)?)
}

fn get_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    if *pos + 8 > buf.len() {
        bail!("frame underrun");
    }
    let v = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

impl Request {
    /// Short op label used by the flight recorder's per-frame events.
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::Set { .. } => "Set",
            Request::Get { .. } => "Get",
            Request::Wait { .. } => "Wait",
            Request::Add { .. } => "Add",
            Request::Count => "Count",
            Request::Hello { .. } => "Hello",
            Request::WaitEpoch { .. } => "WaitEpoch",
            Request::AdvanceEpoch { .. } => "AdvanceEpoch",
            Request::AdvertiseRestore { .. } => "AdvertiseRestore",
            Request::ClaimRestore { .. } => "ClaimRestore",
            Request::AbortEpoch { .. } => "AbortEpoch",
            Request::Heartbeat { .. } => "Heartbeat",
            Request::DelPrefix { .. } => "DelPrefix",
            Request::Batch(_) => "Batch",
            Request::Stats => "Stats",
            Request::Replicate { .. } => "Replicate",
            Request::ReplStatus => "ReplStatus",
            Request::Promote { .. } => "Promote",
            Request::Dedup { .. } => "Dedup",
            Request::DedupDone { .. } => "DedupDone",
            Request::Beats => "Beats",
            Request::InstallState { .. } => "InstallState",
        }
    }

    /// Ops that may park server-side until another client publishes
    /// (or the epoch fence trips). Blocking ops are never shipped to
    /// replicas and force a fresh replay after failover.
    pub fn is_blocking(&self) -> bool {
        matches!(
            self,
            Request::Wait { .. } | Request::WaitEpoch { .. } | Request::ClaimRestore { .. }
        )
    }

    /// Ops that mutate replicated store state — the candidate set for
    /// the primary's replication log. `Batch`/`Dedup` containers are
    /// not themselves logged; their executed sub-ops are.
    pub fn is_mutating(&self) -> bool {
        matches!(
            self,
            Request::Set { .. }
                | Request::Add { .. }
                | Request::AdvanceEpoch { .. }
                | Request::DelPrefix { .. }
                | Request::Heartbeat { .. }
                | Request::AbortEpoch { .. }
                | Request::AdvertiseRestore { .. }
                | Request::DedupDone { .. }
        )
    }

    /// Append the opcode + payload *body* (no length prefix) to
    /// `body` — the form `Batch` nests. Nested items are encoded in
    /// place with a back-patched length (no per-item allocation),
    /// mirroring `Response::Multi`.
    fn encode_body_into(&self, body: &mut Vec<u8>) {
        match self {
            Request::Set { key, value } => {
                body.push(0);
                put_bytes(body, key.as_bytes());
                put_bytes(body, value);
            }
            Request::Get { key } => {
                body.push(1);
                put_bytes(body, key.as_bytes());
            }
            Request::Wait { key } => {
                body.push(2);
                put_bytes(body, key.as_bytes());
            }
            Request::Add { key, delta } => {
                body.push(3);
                put_bytes(body, key.as_bytes());
                body.extend_from_slice(&delta.to_le_bytes());
            }
            Request::Count => body.push(4),
            Request::Hello { client_id } => {
                body.push(5);
                body.extend_from_slice(&client_id.to_le_bytes());
            }
            Request::WaitEpoch { key, epoch } => {
                body.push(6);
                put_bytes(body, key.as_bytes());
                body.extend_from_slice(&epoch.to_le_bytes());
            }
            Request::AdvanceEpoch { to } => {
                body.push(7);
                body.extend_from_slice(&to.to_le_bytes());
            }
            Request::AdvertiseRestore { epoch, tag, addr } => {
                body.push(8);
                body.extend_from_slice(&epoch.to_le_bytes());
                body.extend_from_slice(&tag.to_le_bytes());
                put_bytes(body, addr.as_bytes());
            }
            Request::ClaimRestore { epoch, tag } => {
                body.push(9);
                body.extend_from_slice(&epoch.to_le_bytes());
                body.extend_from_slice(&tag.to_le_bytes());
            }
            Request::AbortEpoch { unless_key, tombstone_key, tombstone, to } => {
                body.push(10);
                put_bytes(body, unless_key.as_bytes());
                put_bytes(body, tombstone_key.as_bytes());
                put_bytes(body, tombstone);
                body.extend_from_slice(&to.to_le_bytes());
            }
            Request::Heartbeat { rank, incarnation, step_tag, device_code } => {
                body.push(11);
                body.extend_from_slice(&rank.to_le_bytes());
                body.extend_from_slice(&incarnation.to_le_bytes());
                body.extend_from_slice(&step_tag.to_le_bytes());
                body.extend_from_slice(&device_code.to_le_bytes());
            }
            Request::DelPrefix { prefix } => {
                body.push(12);
                put_bytes(body, prefix.as_bytes());
            }
            Request::Batch(items) => {
                body.push(13);
                body.extend_from_slice(&(items.len() as u32).to_le_bytes());
                for item in items {
                    let at = body.len();
                    body.extend_from_slice(&[0u8; 4]);
                    item.encode_body_into(body);
                    let len = (body.len() - at - 4) as u32;
                    body[at..at + 4].copy_from_slice(&len.to_le_bytes());
                }
            }
            Request::Stats => body.push(14),
            Request::Replicate { start_index, ops } => {
                body.push(15);
                body.extend_from_slice(&start_index.to_le_bytes());
                body.extend_from_slice(&(ops.len() as u32).to_le_bytes());
                for item in ops {
                    let at = body.len();
                    body.extend_from_slice(&[0u8; 4]);
                    item.encode_body_into(body);
                    let len = (body.len() - at - 4) as u32;
                    body[at..at + 4].copy_from_slice(&len.to_le_bytes());
                }
            }
            Request::ReplStatus => body.push(16),
            Request::Promote { peers } => {
                body.push(17);
                body.extend_from_slice(&(peers.len() as u32).to_le_bytes());
                for p in peers {
                    put_bytes(body, p.as_bytes());
                }
            }
            Request::Dedup { id, op } => {
                body.push(18);
                body.extend_from_slice(&id.to_le_bytes());
                let at = body.len();
                body.extend_from_slice(&[0u8; 4]);
                op.encode_body_into(body);
                let len = (body.len() - at - 4) as u32;
                body[at..at + 4].copy_from_slice(&len.to_le_bytes());
            }
            Request::DedupDone { id, resp } => {
                body.push(19);
                body.extend_from_slice(&id.to_le_bytes());
                put_bytes(body, resp);
            }
            Request::Beats => body.push(20),
            Request::InstallState { high_water, ops } => {
                body.push(21);
                body.extend_from_slice(&high_water.to_le_bytes());
                body.extend_from_slice(&(ops.len() as u32).to_le_bytes());
                for item in ops {
                    let at = body.len();
                    body.extend_from_slice(&[0u8; 4]);
                    item.encode_body_into(body);
                    let len = (body.len() - at - 4) as u32;
                    body[at..at + 4].copy_from_slice(&len.to_le_bytes());
                }
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        self.encode_traced(None)
    }

    /// Encode the full frame, appending the optional trace context
    /// after the structured payload (inside the length prefix) —
    /// context adds zero logical ops and zero extra frames, it rides
    /// the request it annotates.
    pub fn encode_traced(&self, ctx: Option<TraceCtx>) -> Vec<u8> {
        let mut out = vec![0u8; 4];
        self.encode_body_into(&mut out);
        if let Some(ctx) = ctx {
            ctx.encode_into(&mut out);
        }
        let len = (out.len() - 4) as u32;
        out[..4].copy_from_slice(&len.to_le_bytes());
        out
    }

    /// Decode one request, ignoring any trailing bytes (the pre-§12
    /// behaviour every deployed decoder shares — which is exactly what
    /// makes the trailing trace context backward compatible).
    pub fn decode(body: &[u8]) -> Result<Request> {
        Ok(Self::decode_at(body)?.0)
    }

    /// Decode one request plus its optional trailing [`TraceCtx`]:
    /// a context is present iff exactly [`trace::CTX_WIRE_LEN`] bytes
    /// remain after the structured fields (and the trace id is
    /// non-zero). Frames from peers that never append a context decode
    /// with `None`.
    ///
    /// [`trace::CTX_WIRE_LEN`]: crate::telemetry::trace::CTX_WIRE_LEN
    pub fn decode_traced(body: &[u8]) -> Result<(Request, Option<TraceCtx>)> {
        let (req, end) = Self::decode_at(body)?;
        Ok((req, TraceCtx::decode(&body[end..])))
    }

    /// Decode one request and report how many bytes its structured
    /// fields consumed — every arm advances `pos` past everything it
    /// reads, so `body[consumed..]` is exactly the trailing extension
    /// area.
    fn decode_at(body: &[u8]) -> Result<(Request, usize)> {
        let mut pos = 1;
        let req = match body.first() {
            Some(0) => Request::Set {
                key: get_string(body, &mut pos)?,
                value: get_bytes(body, &mut pos)?,
            },
            Some(1) => Request::Get { key: get_string(body, &mut pos)? },
            Some(2) => Request::Wait { key: get_string(body, &mut pos)? },
            Some(3) => {
                let key = get_string(body, &mut pos)?;
                let delta = get_u64(body, &mut pos)? as i64;
                Request::Add { key, delta }
            }
            Some(4) => Request::Count,
            Some(5) => Request::Hello { client_id: get_u64(body, &mut pos)? },
            Some(6) => {
                let key = get_string(body, &mut pos)?;
                let epoch = get_u64(body, &mut pos)?;
                Request::WaitEpoch { key, epoch }
            }
            Some(7) => Request::AdvanceEpoch { to: get_u64(body, &mut pos)? },
            Some(8) => {
                let epoch = get_u64(body, &mut pos)?;
                let tag = get_u64(body, &mut pos)?;
                Request::AdvertiseRestore {
                    epoch,
                    tag,
                    addr: get_string(body, &mut pos)?,
                }
            }
            Some(9) => {
                let epoch = get_u64(body, &mut pos)?;
                let tag = get_u64(body, &mut pos)?;
                Request::ClaimRestore { epoch, tag }
            }
            Some(10) => {
                let unless_key = get_string(body, &mut pos)?;
                let tombstone_key = get_string(body, &mut pos)?;
                let tombstone = get_bytes(body, &mut pos)?;
                let to = get_u64(body, &mut pos)?;
                Request::AbortEpoch { unless_key, tombstone_key, tombstone, to }
            }
            Some(11) => {
                let rank = get_u64(body, &mut pos)?;
                let incarnation = get_u64(body, &mut pos)?;
                let step_tag = get_u64(body, &mut pos)? as i64;
                let device_code = get_u64(body, &mut pos)? as i64;
                Request::Heartbeat { rank, incarnation, step_tag, device_code }
            }
            Some(12) => Request::DelPrefix { prefix: get_string(body, &mut pos)? },
            Some(13) => {
                let count = get_u32(body, &mut pos)? as usize;
                if count > MAX_BATCH_OPS {
                    bail!("batch too large: {count} ops");
                }
                let mut items = Vec::with_capacity(count.min(1024));
                for _ in 0..count {
                    let sub = get_bytes(body, &mut pos)?;
                    if matches!(sub.first(), Some(&13) | Some(&15) | Some(&18) | Some(&19) | Some(&21)) {
                        bail!("nested batch/replication op rejected");
                    }
                    items.push(Request::decode(&sub)?);
                }
                Request::Batch(items)
            }
            Some(14) => Request::Stats,
            Some(15) => {
                let start_index = get_u64(body, &mut pos)?;
                let count = get_u32(body, &mut pos)? as usize;
                if count > MAX_BATCH_OPS {
                    bail!("replicate too large: {count} ops");
                }
                let mut ops = Vec::with_capacity(count.min(1024));
                for _ in 0..count {
                    let sub = get_bytes(body, &mut pos)?;
                    // the log carries flat committed mutations (plus
                    // DedupDone cache installs) — containers and Dedup
                    // wrappers never appear as entries
                    if matches!(sub.first(), Some(&13) | Some(&15) | Some(&18) | Some(&21)) {
                        bail!("nested container rejected in replicate");
                    }
                    ops.push(Request::decode(&sub)?);
                }
                Request::Replicate { start_index, ops }
            }
            Some(16) => Request::ReplStatus,
            Some(17) => {
                let count = get_u32(body, &mut pos)? as usize;
                if count > 64 {
                    bail!("too many promote peers: {count}");
                }
                let mut peers = Vec::with_capacity(count);
                for _ in 0..count {
                    peers.push(get_string(body, &mut pos)?);
                }
                Request::Promote { peers }
            }
            Some(18) => {
                let id = get_u64(body, &mut pos)?;
                let sub = get_bytes(body, &mut pos)?;
                if matches!(sub.first(), Some(&15) | Some(&18) | Some(&19) | Some(&21)) {
                    bail!("dedup may not wrap replication ops");
                }
                Request::Dedup { id, op: Box::new(Request::decode(&sub)?) }
            }
            Some(19) => {
                let id = get_u64(body, &mut pos)?;
                Request::DedupDone { id, resp: get_bytes(body, &mut pos)? }
            }
            Some(20) => Request::Beats,
            Some(21) => {
                let high_water = get_u64(body, &mut pos)?;
                let count = get_u32(body, &mut pos)? as usize;
                if count > MAX_BATCH_OPS {
                    bail!("install too large: {count} ops");
                }
                let mut ops = Vec::with_capacity(count.min(1024));
                for _ in 0..count {
                    let sub = get_bytes(body, &mut pos)?;
                    // the snapshot carries the same flat-mutation
                    // grammar as the log — no containers, no wrappers
                    if matches!(sub.first(), Some(&13) | Some(&15) | Some(&18) | Some(&21)) {
                        bail!("nested container rejected in install");
                    }
                    ops.push(Request::decode(&sub)?);
                }
                Request::InstallState { high_water, ops }
            }
            other => bail!("bad request opcode {other:?}"),
        };
        Ok((req, pos))
    }
}

impl Response {
    /// Append the opcode + payload body to `out` (no length prefix).
    fn encode_body_into(&self, out: &mut Vec<u8>) {
        match self {
            Response::Ok => out.push(0),
            Response::Value(v) => {
                out.push(1);
                put_bytes(out, v);
            }
            Response::NotFound => out.push(2),
            Response::Counter(v) => {
                out.push(3);
                out.extend_from_slice(&v.to_le_bytes());
            }
            Response::CountIs(v) => {
                out.push(4);
                out.extend_from_slice(&v.to_le_bytes());
            }
            Response::HelloAck => out.push(5),
            Response::EpochFenced { current } => {
                out.push(6);
                out.extend_from_slice(&current.to_le_bytes());
            }
            Response::Multi(items) => {
                out.push(7);
                out.extend_from_slice(&(items.len() as u32).to_le_bytes());
                for item in items {
                    let at = out.len();
                    out.extend_from_slice(&[0u8; 4]);
                    item.encode_body_into(out);
                    let len = (out.len() - at - 4) as u32;
                    out[at..at + 4].copy_from_slice(&len.to_le_bytes());
                }
            }
            Response::NotPrimary => out.push(8),
        }
    }

    /// Encode the full frame (length prefix + body) into a reusable
    /// buffer — the server's per-connection hot path: no allocation
    /// once the buffer has grown to the connection's working set.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&[0u8; 4]);
        self.encode_body_into(out);
        let len = (out.len() - 4) as u32;
        out[..4].copy_from_slice(&len.to_le_bytes());
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    pub fn decode(body: &[u8]) -> Result<Response> {
        let mut pos = 1;
        match body.first() {
            Some(0) => Ok(Response::Ok),
            Some(1) => Ok(Response::Value(Bytes::from(get_bytes(body, &mut pos)?))),
            Some(2) => Ok(Response::NotFound),
            Some(3) => {
                if pos + 8 > body.len() {
                    bail!("frame underrun");
                }
                Ok(Response::Counter(i64::from_le_bytes(
                    body[pos..pos + 8].try_into().unwrap(),
                )))
            }
            Some(4) => {
                if pos + 8 > body.len() {
                    bail!("frame underrun");
                }
                Ok(Response::CountIs(u64::from_le_bytes(
                    body[pos..pos + 8].try_into().unwrap(),
                )))
            }
            Some(5) => Ok(Response::HelloAck),
            Some(6) => {
                if pos + 8 > body.len() {
                    bail!("frame underrun");
                }
                let current = u64::from_le_bytes(body[pos..pos + 8].try_into().unwrap());
                Ok(Response::EpochFenced { current })
            }
            Some(7) => {
                let count = get_u32(body, &mut pos)? as usize;
                if count > MAX_BATCH_OPS {
                    bail!("multi too large: {count} responses");
                }
                let mut items = Vec::with_capacity(count.min(1024));
                for _ in 0..count {
                    let sub = get_bytes(body, &mut pos)?;
                    if sub.first() == Some(&7) {
                        bail!("nested multi rejected");
                    }
                    items.push(Response::decode(&sub)?);
                }
                Ok(Response::Multi(items))
            }
            Some(8) => Ok(Response::NotPrimary),
            other => bail!("bad response opcode {other:?}"),
        }
    }
}

/// Read one length-prefixed frame body from a stream.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut body = Vec::new();
    read_frame_into(r, &mut body)?;
    Ok(body)
}

/// Read one length-prefixed frame body into a reusable buffer — the
/// server's per-connection read path (no allocation at steady state).
pub fn read_frame_into<R: Read>(r: &mut R, body: &mut Vec<u8>) -> Result<()> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("frame too large: {len}");
    }
    body.clear();
    body.resize(len, 0);
    r.read_exact(body)?;
    Ok(())
}

/// Write one pre-encoded frame (already length-prefixed).
pub fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    w.write_all(frame)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: Request) {
        let enc = r.encode();
        // strip the length prefix the way the server does
        let body = &enc[4..];
        assert_eq!(Request::decode(body).unwrap(), r);
    }

    fn roundtrip_resp(r: Response) {
        let enc = r.encode();
        let body = &enc[4..];
        assert_eq!(Response::decode(body).unwrap(), r);
    }

    /// The traced roundtrip doubles as a position-accounting check:
    /// if any `decode_at` arm under-consumes its fields, the leftover
    /// bytes break the exactly-16-trailing-bytes rule and the context
    /// comes back mangled or `None`.
    fn roundtrip_traced(r: Request) {
        let ctx = TraceCtx { trace_id: 0xA1B2_C3D4_E5F6_0708, span_id: 42 };
        let enc = r.encode_traced(Some(ctx));
        let body = &enc[4..];
        assert_eq!(Request::decode_traced(body).unwrap(), (r.clone(), Some(ctx)), "{r:?}");
        // a context-unaware decoder ignores the trailing bytes
        assert_eq!(Request::decode(body).unwrap(), r, "{r:?}");
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_req(Request::Set { key: "k".into(), value: vec![1, 2, 3] });
        roundtrip_req(Request::Get { key: "ranktable/v1".into() });
        roundtrip_req(Request::Wait { key: "".into() });
        roundtrip_req(Request::Add { key: "barrier".into(), delta: -7 });
        roundtrip_req(Request::Count);
        roundtrip_req(Request::Hello { client_id: u64::MAX });
        roundtrip_req(Request::WaitEpoch { key: "rdzv/3/delta".into(), epoch: 3 });
        roundtrip_req(Request::AdvanceEpoch { to: u64::MAX });
        roundtrip_req(Request::AdvertiseRestore {
            epoch: 5,
            tag: 0xDEAD_BEEF_0042,
            addr: "127.0.0.1:30321".into(),
        });
        roundtrip_req(Request::ClaimRestore { epoch: u64::MAX, tag: 0 });
        roundtrip_req(Request::AbortEpoch {
            unless_key: "rdzv/4/go".into(),
            tombstone_key: "rdzv/5/delta".into(),
            tombstone: b"!abort".to_vec(),
            to: 5,
        });
        roundtrip_req(Request::Heartbeat {
            rank: 4096,
            incarnation: u64::MAX,
            step_tag: -1,
            device_code: 3,
        });
        roundtrip_req(Request::Heartbeat {
            rank: 0,
            incarnation: 1,
            step_tag: i64::MAX,
            device_code: -1,
        });
        roundtrip_req(Request::DelPrefix { prefix: "rdzv/3/".into() });
        roundtrip_req(Request::Stats);
        roundtrip_req(Request::ReplStatus);
        roundtrip_req(Request::Promote {
            peers: vec!["127.0.0.1:30001".into(), "127.0.0.1:30002".into()],
        });
        roundtrip_req(Request::Promote { peers: vec![] });
        roundtrip_req(Request::Replicate {
            start_index: 41,
            ops: vec![
                Request::Set { key: "k".into(), value: vec![1, 2] },
                Request::Add { key: "rdzv/2/arrived".into(), delta: 1 },
                Request::AdvanceEpoch { to: 3 },
                Request::DedupDone { id: 9, resp: vec![0] },
            ],
        });
        roundtrip_req(Request::Dedup {
            id: u64::MAX,
            op: Box::new(Request::Add { key: "ctr".into(), delta: 1 }),
        });
        roundtrip_req(Request::Dedup {
            id: 7,
            op: Box::new(Request::Batch(vec![
                Request::WaitEpoch { key: "rdzv/1/delta".into(), epoch: 1 },
                Request::Add { key: "rdzv/1/arrived".into(), delta: 1 },
            ])),
        });
        roundtrip_req(Request::DedupDone { id: 3, resp: vec![3, 1, 0, 0, 0, 0, 0, 0, 0] });
        roundtrip_req(Request::Beats);
        roundtrip_req(Request::InstallState { high_water: 0, ops: vec![] });
        roundtrip_req(Request::InstallState {
            high_water: 41,
            ops: vec![
                Request::Set { key: "ctl/leases".into(), value: vec![1, 2, 3] },
                Request::Heartbeat { rank: 2, incarnation: 1, step_tag: 7, device_code: -1 },
                Request::DedupDone { id: 4, resp: vec![0] },
                Request::AdvanceEpoch { to: 6 },
            ],
        });
    }

    #[test]
    fn every_request_carries_optional_trace_context() {
        roundtrip_traced(Request::Set { key: "k".into(), value: vec![1, 2, 3] });
        roundtrip_traced(Request::Get { key: "ranktable/v1".into() });
        roundtrip_traced(Request::Wait { key: "".into() });
        roundtrip_traced(Request::Add { key: "barrier".into(), delta: -7 });
        roundtrip_traced(Request::Count);
        roundtrip_traced(Request::Hello { client_id: u64::MAX });
        roundtrip_traced(Request::WaitEpoch { key: "rdzv/3/delta".into(), epoch: 3 });
        roundtrip_traced(Request::AdvanceEpoch { to: u64::MAX });
        roundtrip_traced(Request::AdvertiseRestore {
            epoch: 5,
            tag: 0xDEAD_BEEF_0042,
            addr: "127.0.0.1:30321".into(),
        });
        roundtrip_traced(Request::ClaimRestore { epoch: u64::MAX, tag: 0 });
        roundtrip_traced(Request::AbortEpoch {
            unless_key: "rdzv/4/go".into(),
            tombstone_key: "rdzv/5/delta".into(),
            tombstone: b"!abort".to_vec(),
            to: 5,
        });
        roundtrip_traced(Request::Heartbeat {
            rank: 4096,
            incarnation: u64::MAX,
            step_tag: -1,
            device_code: 3,
        });
        roundtrip_traced(Request::DelPrefix { prefix: "rdzv/3/".into() });
        roundtrip_traced(Request::Batch(vec![
            Request::Set { key: "a".into(), value: vec![7; 64] },
            Request::Add { key: "rdzv/2/arrived".into(), delta: 1 },
        ]));
        roundtrip_traced(Request::Stats);
        roundtrip_traced(Request::ReplStatus);
        roundtrip_traced(Request::Promote { peers: vec!["127.0.0.1:30001".into()] });
        roundtrip_traced(Request::Replicate {
            start_index: 5,
            ops: vec![Request::Set { key: "k".into(), value: vec![1] }],
        });
        roundtrip_traced(Request::Dedup {
            id: 11,
            op: Box::new(Request::Add { key: "ctr".into(), delta: 2 }),
        });
        roundtrip_traced(Request::DedupDone { id: 11, resp: vec![0] });
        roundtrip_traced(Request::Beats);
        roundtrip_traced(Request::InstallState {
            high_water: 9,
            ops: vec![Request::Set { key: "k".into(), value: vec![5] }],
        });
    }

    #[test]
    fn untraced_frames_decode_with_no_context() {
        let reqs = [
            Request::Count,
            Request::Hello { client_id: 7 },
            Request::Heartbeat { rank: 1, incarnation: 1, step_tag: 0, device_code: -1 },
            Request::Stats,
        ];
        for r in reqs {
            let enc = r.encode();
            let (back, ctx) = Request::decode_traced(&enc[4..]).unwrap();
            assert_eq!(back, r);
            assert_eq!(ctx, None, "{r:?}");
        }
        // an all-zero context is the unrecorded sentinel -> None
        let enc = Request::Count.encode_traced(Some(TraceCtx { trace_id: 0, span_id: 0 }));
        assert_eq!(Request::decode_traced(&enc[4..]).unwrap().1, None);
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(Response::Ok);
        roundtrip_resp(Response::Value(Bytes::from(vec![0u8; 1000])));
        roundtrip_resp(Response::NotFound);
        roundtrip_resp(Response::Counter(-1));
        roundtrip_resp(Response::CountIs(42));
        roundtrip_resp(Response::HelloAck);
        roundtrip_resp(Response::EpochFenced { current: 9 });
        roundtrip_resp(Response::NotPrimary);
    }

    #[test]
    fn batch_roundtrips() {
        roundtrip_req(Request::Batch(vec![]));
        roundtrip_req(Request::Batch(vec![
            Request::Set { key: "a".into(), value: vec![7; 64] },
            Request::WaitEpoch { key: "rdzv/2/delta".into(), epoch: 2 },
            Request::Add { key: "rdzv/2/arrived".into(), delta: 1 },
            Request::Heartbeat { rank: 3, incarnation: 2, step_tag: 9, device_code: -1 },
        ]));
        roundtrip_resp(Response::Multi(vec![]));
        roundtrip_resp(Response::Multi(vec![
            Response::Ok,
            Response::Value(Bytes::from(&b"delta"[..])),
            Response::Counter(4),
            Response::EpochFenced { current: 3 },
        ]));
    }

    #[test]
    fn nested_batch_is_rejected() {
        let nested = Request::Batch(vec![Request::Batch(vec![Request::Count])]);
        let enc = nested.encode();
        assert!(Request::decode(&enc[4..]).is_err());
        let multi = Response::Multi(vec![Response::Multi(vec![Response::Ok])]);
        let enc = multi.encode();
        assert!(Response::decode(&enc[4..]).is_err());
    }

    #[test]
    fn replication_ops_reject_bad_nesting() {
        // the log never carries containers or Dedup wrappers
        for bad in [
            Request::Batch(vec![Request::Count]),
            Request::Replicate { start_index: 1, ops: vec![] },
            Request::Dedup { id: 1, op: Box::new(Request::Count) },
        ] {
            let enc = Request::Replicate { start_index: 1, ops: vec![bad] }.encode();
            assert!(Request::decode(&enc[4..]).is_err());
        }
        // Dedup wraps client ops (incl. Batch), never replication ops
        for bad in [
            Request::Replicate { start_index: 1, ops: vec![] },
            Request::Dedup { id: 2, op: Box::new(Request::Count) },
            Request::DedupDone { id: 2, resp: vec![0] },
        ] {
            let enc = Request::Dedup { id: 1, op: Box::new(bad) }.encode();
            assert!(Request::decode(&enc[4..]).is_err());
        }
        // batches never smuggle replication ops either
        for bad in [
            Request::Replicate { start_index: 1, ops: vec![] },
            Request::Dedup { id: 1, op: Box::new(Request::Count) },
            Request::DedupDone { id: 1, resp: vec![0] },
            Request::InstallState { high_water: 1, ops: vec![] },
        ] {
            let enc = Request::Batch(vec![Request::Count, bad]).encode();
            assert!(Request::decode(&enc[4..]).is_err());
        }
        // InstallState carries the same flat grammar as the log: no
        // containers, no wrappers, no nested installs — and it never
        // rides inside Replicate or Dedup itself
        for bad in [
            Request::Batch(vec![Request::Count]),
            Request::Replicate { start_index: 1, ops: vec![] },
            Request::Dedup { id: 1, op: Box::new(Request::Count) },
            Request::InstallState { high_water: 1, ops: vec![] },
        ] {
            let enc = Request::InstallState { high_water: 1, ops: vec![bad] }.encode();
            assert!(Request::decode(&enc[4..]).is_err());
        }
        let enc = Request::Replicate {
            start_index: 1,
            ops: vec![Request::InstallState { high_water: 1, ops: vec![] }],
        }
        .encode();
        assert!(Request::decode(&enc[4..]).is_err());
        let enc = Request::Dedup {
            id: 1,
            op: Box::new(Request::InstallState { high_water: 1, ops: vec![] }),
        }
        .encode();
        assert!(Request::decode(&enc[4..]).is_err());
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let mut buf = Vec::new();
        Response::Value(Bytes::from(&b"abcdef"[..])).encode_into(&mut buf);
        let first = buf.clone();
        // a second, smaller encode reuses (and truncates) the buffer
        Response::Ok.encode_into(&mut buf);
        assert_eq!(Response::decode(&buf[4..]).unwrap(), Response::Ok);
        assert_eq!(Response::decode(&first[4..]).unwrap(), Response::Value(Bytes::from(&b"abcdef"[..])));
    }

    #[test]
    fn stream_framing() {
        let msg = Request::Set { key: "a".into(), value: vec![9; 100] };
        let enc = msg.encode();
        let mut cursor = std::io::Cursor::new(enc.clone());
        let body = read_frame(&mut cursor).unwrap();
        assert_eq!(Request::decode(&body).unwrap(), msg);
    }

    #[test]
    fn read_frame_into_reuses_buffer() {
        let a = Request::Set { key: "a".into(), value: vec![9; 100] }.encode();
        let b = Request::Count.encode();
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        let mut cursor = std::io::Cursor::new(stream);
        let mut buf = Vec::new();
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert!(matches!(Request::decode(&buf).unwrap(), Request::Set { .. }));
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(Request::decode(&buf).unwrap(), Request::Count);
    }

    #[test]
    fn truncated_frame_errors() {
        let msg = Request::Get { key: "abc".into() };
        let enc = msg.encode();
        let mut cursor = std::io::Cursor::new(enc[..enc.len() - 1].to_vec());
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn decode_rejects_bad_opcode() {
        assert!(Request::decode(&[99]).is_err());
        assert!(Response::decode(&[]).is_err());
    }
}
