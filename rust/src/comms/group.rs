//! Communication-group derivation: DP / TP / PP process groups from
//! `Ranktable` x `ParallelismConfig` (paper §III-D).
//!
//! Every device belongs to exactly one group of each kind. After a
//! failure, groups containing a substituted rank must re-establish
//! their communicator (*rebuilt*), while every other group only
//! re-stamps itself into the new rendezvous epoch (*re-keyed*) — the
//! paper's differentiated normal/faulty-node strategy, which is what
//! makes reconstruction cost independent of cluster size.

use crate::config::{DeviceCoord, ParallelismConfig};
use crate::coordinator::ranktable::{RankEntry, Ranktable};
use anyhow::{bail, Result};

/// Which parallelism axis a group spans.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GroupKind {
    /// Gradient all-reduce group (spans the dp axis).
    Dp,
    /// Tensor-parallel group (spans the tp axis).
    Tp,
    /// Pipeline stage group (spans the pp axis).
    Pp,
}

impl GroupKind {
    pub fn name(&self) -> &'static str {
        match self {
            GroupKind::Dp => "dp",
            GroupKind::Tp => "tp",
            GroupKind::Pp => "pp",
        }
    }
}

/// Stable identity of one communication group within a topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GroupId {
    pub kind: GroupKind,
    pub index: usize,
}

/// One process group: ordered members plus the endpoint each member
/// publishes in the ranktable, stamped with the rendezvous epoch its
/// communicator was established in.
#[derive(Debug, Clone, PartialEq)]
pub struct CommGroup {
    pub id: GroupId,
    /// Rendezvous epoch of the current communicator.
    pub epoch: u64,
    /// Global ranks, in axis order.
    pub ranks: Vec<usize>,
    /// Endpoint per member, parallel to `ranks`.
    pub endpoints: Vec<String>,
}

impl CommGroup {
    pub fn contains(&self, rank: usize) -> bool {
        self.ranks.contains(&rank)
    }
}

/// Result of re-keying a group set into a new epoch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RekeyStats {
    /// Groups whose membership endpoints changed — communicator must
    /// be re-established with the replacement node(s).
    pub rebuilt: usize,
    /// Groups untouched by the substitution — epoch re-stamp only.
    pub rekeyed: usize,
}

/// The communication groups derived for a topology — either the full
/// set (coordinator view) or one rank's three groups (node view).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupSet {
    pub epoch: u64,
    pub world: usize,
    pub groups: Vec<CommGroup>,
}

/// rank -> endpoint lookup; errors unless the table covers exactly
/// `world` contiguous ranks.
fn endpoint_index(table: &Ranktable, world: usize) -> Result<Vec<String>> {
    if table.entries.len() != world {
        bail!(
            "ranktable has {} entries but topology world size is {world}",
            table.entries.len()
        );
    }
    table.validate()?;
    let mut addrs = vec![String::new(); world];
    for e in &table.entries {
        addrs[e.rank] = e.addr.clone();
    }
    Ok(addrs)
}

fn group(
    id: GroupId,
    epoch: u64,
    ranks: Vec<usize>,
    addrs: &[String],
) -> CommGroup {
    let endpoints = ranks.iter().map(|&r| addrs[r].clone()).collect();
    CommGroup { id, epoch, ranks, endpoints }
}

/// The three group ids `rank` belongs to under `cfg`.
pub fn group_ids_for(cfg: &ParallelismConfig, rank: usize) -> [GroupId; 3] {
    let c = cfg.coord(rank);
    [
        GroupId { kind: GroupKind::Dp, index: c.pp * cfg.tp + c.tp },
        GroupId { kind: GroupKind::Tp, index: c.dp * cfg.pp + c.pp },
        GroupId { kind: GroupKind::Pp, index: c.dp * cfg.tp + c.tp },
    ]
}

/// Members of `id`, in axis order.
fn members(cfg: &ParallelismConfig, id: GroupId) -> Vec<usize> {
    match id.kind {
        GroupKind::Dp => {
            let (pp, tp) = (id.index / cfg.tp, id.index % cfg.tp);
            (0..cfg.dp)
                .map(|dp| cfg.global(DeviceCoord { dp, pp, tp }))
                .collect()
        }
        GroupKind::Tp => {
            let (dp, pp) = (id.index / cfg.pp, id.index % cfg.pp);
            (0..cfg.tp)
                .map(|tp| cfg.global(DeviceCoord { dp, pp, tp }))
                .collect()
        }
        GroupKind::Pp => {
            let (dp, tp) = (id.index / cfg.tp, id.index % cfg.tp);
            (0..cfg.pp)
                .map(|pp| cfg.global(DeviceCoord { dp, pp, tp }))
                .collect()
        }
    }
}

impl GroupSet {
    /// Derive every group in the topology (coordinator view):
    /// `pp*tp` DP groups, `dp*pp` TP groups, `dp*tp` PP groups.
    pub fn derive(
        table: &Ranktable,
        cfg: &ParallelismConfig,
        epoch: u64,
    ) -> Result<GroupSet> {
        cfg.validate()?;
        let world = cfg.world_size();
        let addrs = endpoint_index(table, world)?;
        let mut groups =
            Vec::with_capacity(cfg.pp * cfg.tp + cfg.dp * cfg.pp + cfg.dp * cfg.tp);
        for (kind, count) in [
            (GroupKind::Dp, cfg.pp * cfg.tp),
            (GroupKind::Tp, cfg.dp * cfg.pp),
            (GroupKind::Pp, cfg.dp * cfg.tp),
        ] {
            for index in 0..count {
                let id = GroupId { kind, index };
                groups.push(group(id, epoch, members(cfg, id), &addrs));
            }
        }
        Ok(GroupSet { epoch, world, groups })
    }

    /// Derive only the three groups containing `rank` (node view) —
    /// O(dp + tp + pp) work and memory, what a live device actually
    /// materializes at any cluster size.
    pub fn derive_for(
        table: &Ranktable,
        cfg: &ParallelismConfig,
        epoch: u64,
        rank: usize,
    ) -> Result<GroupSet> {
        cfg.validate()?;
        let world = cfg.world_size();
        if rank >= world {
            bail!("rank {rank} outside world {world}");
        }
        let addrs = endpoint_index(table, world)?;
        let groups = group_ids_for(cfg, rank)
            .into_iter()
            .map(|id| group(id, epoch, members(cfg, id), &addrs))
            .collect();
        Ok(GroupSet { epoch, world, groups })
    }

    pub fn group(&self, id: GroupId) -> Option<&CommGroup> {
        self.groups.iter().find(|g| g.id == id)
    }

    /// Groups containing `rank` (three in the full set; up to three in
    /// a node view).
    pub fn groups_for(&self, rank: usize) -> Vec<&CommGroup> {
        self.groups.iter().filter(|g| g.contains(rank)).collect()
    }

    /// Re-key the set into `epoch`, applying endpoint substitutions.
    /// Groups containing a substituted rank are *rebuilt* (endpoints
    /// refreshed); all others are only epoch re-stamped. O(k) in the
    /// substitution count for the node view — independent of world.
    pub fn rekey(&mut self, subs: &[RankEntry], epoch: u64) -> RekeyStats {
        let mut stats = RekeyStats::default();
        for g in &mut self.groups {
            let mut touched = false;
            for s in subs {
                if let Some(i) = g.ranks.iter().position(|&r| r == s.rank) {
                    g.endpoints[i] = s.addr.clone();
                    touched = true;
                }
            }
            g.epoch = epoch;
            if touched {
                stats.rebuilt += 1;
            } else {
                stats.rekeyed += 1;
            }
        }
        self.epoch = epoch;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn entry(rank: usize) -> RankEntry {
        RankEntry {
            rank,
            node: rank / 8,
            device: rank % 8,
            addr: format!("10.0.{}.{}:2900", rank / 8, rank % 8),
        }
    }

    fn table(n: usize) -> Ranktable {
        Ranktable::new((0..n).map(entry).collect())
    }

    #[test]
    fn derive_partitions_world_per_kind() {
        let cfg = ParallelismConfig::new(4, 3, 2);
        let set = GroupSet::derive(&table(cfg.world_size()), &cfg, 1).unwrap();
        for kind in [GroupKind::Dp, GroupKind::Tp, GroupKind::Pp] {
            let mut seen: Vec<usize> = set
                .groups
                .iter()
                .filter(|g| g.id.kind == kind)
                .flat_map(|g| g.ranks.iter().copied())
                .collect();
            seen.sort();
            let world: Vec<usize> = (0..cfg.world_size()).collect();
            assert_eq!(seen, world, "{} groups must partition the world", kind.name());
        }
    }

    #[test]
    fn every_rank_in_exactly_three_groups() {
        let cfg = ParallelismConfig::new(2, 2, 2);
        let set = GroupSet::derive(&table(8), &cfg, 0).unwrap();
        for r in 0..8 {
            assert_eq!(set.groups_for(r).len(), 3, "rank {r}");
        }
    }

    #[test]
    fn endpoints_track_ranktable() {
        let cfg = ParallelismConfig::new(2, 2, 2);
        let t = table(8);
        let set = GroupSet::derive(&t, &cfg, 0).unwrap();
        for g in &set.groups {
            for (r, ep) in g.ranks.iter().zip(&g.endpoints) {
                assert_eq!(ep, &t.entries[*r].addr);
            }
        }
    }

    #[test]
    fn node_view_matches_full_view() {
        let cfg = ParallelismConfig::new(3, 2, 2);
        let t = table(cfg.world_size());
        let full = GroupSet::derive(&t, &cfg, 4).unwrap();
        for rank in [0, 5, 11] {
            let node = GroupSet::derive_for(&t, &cfg, 4, rank).unwrap();
            assert_eq!(node.groups.len(), 3);
            for g in &node.groups {
                assert!(g.contains(rank));
                assert_eq!(full.group(g.id), Some(g));
            }
        }
    }

    #[test]
    fn rekey_rebuilds_only_touched_groups() {
        let cfg = ParallelismConfig::new(2, 2, 2);
        let t = table(8);
        let mut set = GroupSet::derive(&t, &cfg, 1).unwrap();
        let mut sub = entry(3);
        sub.addr = "10.9.9.9:2900".to_string();
        let stats = set.rekey(&[sub.clone()], 2);
        // rank 3 sits in exactly one group of each kind
        assert_eq!(stats.rebuilt, 3);
        assert_eq!(stats.rebuilt + stats.rekeyed, set.groups.len());
        assert_eq!(set.epoch, 2);
        for g in &set.groups {
            assert_eq!(g.epoch, 2);
            if let Some(i) = g.ranks.iter().position(|&r| r == 3) {
                assert_eq!(g.endpoints[i], sub.addr);
            }
        }
    }

    #[test]
    fn derive_rejects_mismatched_table() {
        let cfg = ParallelismConfig::new(2, 2, 2);
        assert!(GroupSet::derive(&table(7), &cfg, 0).is_err());
        assert!(GroupSet::derive_for(&table(8), &cfg, 0, 8).is_err());
    }

    #[test]
    fn prop_group_ids_consistent_with_membership() {
        prop::check("group ids vs membership", 150, |rng| {
            let dp = 1 + rng.below(4) as usize;
            let pp = 1 + rng.below(3) as usize;
            let tp = 1 + rng.below(3) as usize;
            let cfg = ParallelismConfig::new(dp, pp, tp);
            let set = GroupSet::derive(&table(cfg.world_size()), &cfg, 0)
                .map_err(|e| e.to_string())?;
            let rank = rng.below(cfg.world_size() as u64) as usize;
            let ids = group_ids_for(&cfg, rank);
            for id in ids {
                let g = set.group(id).ok_or("missing group")?;
                prop::assert_prop(
                    g.contains(rank),
                    format!("rank {rank} missing from its {:?}", id),
                )?;
            }
            // and no other group claims the rank
            prop::assert_eq_prop(&set.groups_for(rank).len(), &3)
        });
    }
}
