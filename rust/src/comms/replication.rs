//! Coordination-plane HA (DESIGN.md §13): epoch-consistent log
//! shipping across 2–3 store nodes, and the endpoint-set client API
//! that rides it.
//!
//! * [`Replicator`] — the primary's group-commit log: every committed
//!   mutating op is assigned a monotonic log index *under the same
//!   lock that applied it* (so local apply order and log order can
//!   never diverge across concurrent connections), batches of entries
//!   are shipped to replicas as one `Replicate` frame, and a client
//!   ack is released only once a quorum of replicas appended
//!   (`wait_committed`). With zero live replicas the plane degrades
//!   to un-replicated operation — availability over durability.
//! * [`StoreEndpoints`] / [`StoreSession`] — the client redesign:
//!   instead of `TcpStoreClient::connect(addr)` hard-coding one
//!   endpoint, a session owns the endpoint set, discovers the current
//!   primary via `ReplStatus`, and transparently fails over on an IO
//!   error or `NotPrimary` — including mid-`Wait`/`WaitEpoch`, where
//!   the parked wait is replayed against the new primary. Batches
//!   that carry non-idempotent ops (`Add`) are wrapped in a `Dedup`
//!   envelope so a replayed frame can never double-apply.
//! * [`ReplicaSet`] — in-process primary + N replicas, the harness
//!   the controller's rebuild plane, the chaos drivers, and the
//!   replicated-mode bench column all build on.

use super::link::{default_dialer, jittered, Dialer};
use super::tcp_store::{
    decode_beats, BeatRecord, FencedWait, TcpStoreClient, TcpStoreServer,
};
use super::wire::{Bytes, Request, Response};
use crate::telemetry::{trace::TraceCtx, Snapshot};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, VecDeque};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Wire byte for the primary role in `ReplStatus` payloads.
pub const ROLE_PRIMARY: u8 = 0;
/// Wire byte for the replica role in `ReplStatus` payloads.
pub const ROLE_REPLICA: u8 = 1;

/// Connect timeout for discovery probes and replica log connections —
/// short, so a dead endpoint costs milliseconds, not the client
/// connect default.
const PROBE_CONNECT: Duration = Duration::from_millis(250);

/// How long a session keeps rediscovering before giving up on a
/// failover (covers the promote + replicator-spawn window).
const FAILOVER_PATIENCE: Duration = Duration::from_secs(10);

/// Failover retries per logical op before surfacing the error.
const SESSION_RETRIES: usize = 6;

/// Entries the dedup cache retains (FIFO) — bounds replicated memory
/// while comfortably covering every in-flight replayable op.
const DEDUP_CAP: usize = 4096;

/// Source label the replication shipper dials its follower links
/// under — the key netem per-pair policies use to shape replication
/// traffic independently of client traffic on the same destination.
pub const REPL_LINK_SRC: &str = "repl";

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

// ---------------------------------------------------------------------------
// Dedup cache
// ---------------------------------------------------------------------------

/// Exactly-once cache: encoded responses keyed by client-chosen dedup
/// id, FIFO-bounded. Replicated via `DedupDone` log entries so a
/// failed-over primary still refuses to re-execute a replayed op.
pub(crate) struct DedupMap {
    map: HashMap<u64, Vec<u8>>,
    order: VecDeque<u64>,
}

impl DedupMap {
    pub(crate) fn new() -> Self {
        DedupMap { map: HashMap::new(), order: VecDeque::new() }
    }

    pub(crate) fn get(&self, id: u64) -> Option<Vec<u8>> {
        self.map.get(&id).cloned()
    }

    pub(crate) fn insert(&mut self, id: u64, resp: Vec<u8>) {
        if self.map.insert(id, resp).is_none() {
            self.order.push_back(id);
            if self.order.len() > DEDUP_CAP {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                }
            }
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.map.len()
    }

    /// Drop every cached entry — the receiving side of an
    /// `InstallState` wipe before the snapshot's `DedupDone` entries
    /// repopulate the cache.
    pub(crate) fn clear(&mut self) {
        self.map.clear();
        self.order.clear();
    }

    /// Every cached `(id, response)` in FIFO order — the snapshot dump
    /// for a replica re-attach, ordered so the installed cache evicts
    /// in the same order this one will.
    pub(crate) fn entries(&self) -> Vec<(u64, Vec<u8>)> {
        self.order
            .iter()
            .filter_map(|id| self.map.get(id).map(|v| (*id, v.clone())))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Replicator (primary side)
// ---------------------------------------------------------------------------

struct LogInner {
    /// Entries assigned an index but not yet handed to the shipper.
    queue: Vec<(u64, Request)>,
    next_index: u64,
}

struct CommitState {
    /// Highest log index known appended on a quorum of replicas.
    watermark: u64,
    /// No live replicas left: acks release immediately (documented
    /// availability-over-durability degradation).
    degraded: bool,
    live_replicas: usize,
}

/// The primary's replication log: index assignment, group-commit
/// shipping, and quorum tracking. One shipper thread drains the
/// queue and ships each drained batch as a single `Replicate` frame
/// per replica; entries appended under one lock acquisition are
/// therefore always shipped in the same frame (the atomic-contiguity
/// guarantee `Dedup` batches rely on).
pub struct Replicator {
    inner: Mutex<LogInner>,
    ship_cv: Condvar,
    commit: Mutex<CommitState>,
    commit_cv: Condvar,
    stop: AtomicBool,
    shipper: Mutex<Option<JoinHandle<()>>>,
    /// Replicas attached after start ([`Self::attach`]): bootstrapped
    /// connections (with the log index their install covered) parked
    /// here until the shipper splices them into its live set at the
    /// top of the next batch.
    pending: Mutex<Vec<(TcpStoreClient, u64)>>,
    /// Event-loop hook: the store reactor parks commit waits as
    /// entries instead of blocking in [`Self::wait_committed`], so the
    /// shipper pings this callback (an eventfd write) whenever the
    /// watermark moves or the plane degrades. `None` under the
    /// threaded core — the condvar alone covers blocked threads.
    commit_waker: Mutex<Option<Arc<dyn Fn() + Send + Sync>>>,
}

impl Replicator {
    /// Connect to `peers` and start the shipper. `next_index` is the
    /// first index this primary will assign (applied + 1 on a
    /// freshly promoted replica; 1 on a new plane). Unreachable peers
    /// are dropped immediately.
    pub fn start(peers: &[SocketAddr], next_index: u64) -> Arc<Replicator> {
        let mut conns = Vec::new();
        for &p in peers {
            // Shipper links carry the "repl" source label so netem
            // campaigns can shape follower links independently of
            // client traffic (per-pair policies, DESIGN.md §15).
            if let Ok(mut c) = TcpStoreClient::connect_from(REPL_LINK_SRC, p, PROBE_CONNECT)
            {
                // bound a stalled replica read so shutdown can't wedge
                let _ = c.set_read_window(Some(Duration::from_secs(2)));
                conns.push(c);
            }
        }
        let next_index = next_index.max(1);
        let repl = Arc::new(Replicator {
            inner: Mutex::new(LogInner { queue: Vec::new(), next_index }),
            ship_cv: Condvar::new(),
            commit: Mutex::new(CommitState {
                watermark: next_index - 1,
                degraded: conns.is_empty(),
                live_replicas: conns.len(),
            }),
            commit_cv: Condvar::new(),
            stop: AtomicBool::new(false),
            shipper: Mutex::new(None),
            pending: Mutex::new(Vec::new()),
            commit_waker: Mutex::new(None),
        });
        let r2 = repl.clone();
        let h = std::thread::spawn(move || shipper_loop(&r2, conns));
        *lock(&repl.shipper) = Some(h);
        repl
    }

    /// Run `apply` and, when it reports loggable entries, assign them
    /// consecutive log indices — apply and index assignment happen
    /// under ONE lock, so two racing connections can never apply in
    /// one order and log in the other. Returns the last assigned
    /// index, if any. `apply` must never block (blocking ops are
    /// never logged).
    pub(crate) fn apply_logged(
        &self,
        apply: impl FnOnce() -> (Response, Vec<Request>),
    ) -> (Response, Option<u64>) {
        let mut g = lock(&self.inner);
        let (resp, entries) = apply();
        if entries.is_empty() {
            return (resp, None);
        }
        let mut last = 0;
        for e in entries {
            let idx = g.next_index;
            g.next_index += 1;
            g.queue.push((idx, e));
            last = idx;
        }
        drop(g);
        self.ship_cv.notify_all();
        (resp, Some(last))
    }

    /// Append pre-executed entries in one lock acquisition (they ship
    /// in one `Replicate` frame). Returns the last assigned index.
    pub(crate) fn append(&self, entries: Vec<Request>) -> Option<u64> {
        if entries.is_empty() {
            return None;
        }
        let (_, idx) = self.apply_logged(|| (Response::Ok, entries));
        idx
    }

    /// Block until `index` is on a quorum of replicas (or the plane
    /// is degraded / shutting down). Bounded: a shipper wedged for
    /// 10s degrades to availability rather than freezing the data
    /// plane.
    pub fn wait_committed(&self, index: u64) {
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut cs = lock(&self.commit);
        while cs.watermark < index
            && !cs.degraded
            && !self.stop.load(Ordering::Relaxed)
            && Instant::now() < deadline
        {
            let (g, _) = self
                .commit_cv
                .wait_timeout(cs, Duration::from_millis(50))
                .unwrap_or_else(PoisonError::into_inner);
            cs = g;
        }
    }

    /// Live replica connections (0 = degraded un-replicated mode).
    pub fn live_replicas(&self) -> usize {
        lock(&self.commit).live_replicas
    }

    /// Highest log index known committed on a quorum — the reactor's
    /// nonblocking commit-wait check ([`Self::wait_committed`] is the
    /// blocking form the threaded core uses).
    pub(crate) fn watermark(&self) -> u64 {
        lock(&self.commit).watermark
    }

    /// Degraded (no live replicas): pending commit waits release
    /// immediately.
    pub(crate) fn is_degraded(&self) -> bool {
        lock(&self.commit).degraded
    }

    /// Install the event-loop wake hook the shipper pings on every
    /// watermark advance / degradation (see `commit_waker`).
    pub(crate) fn set_commit_waker(&self, waker: Arc<dyn Fn() + Send + Sync>) {
        *lock(&self.commit_waker) = Some(waker);
    }

    fn ping_commit_waker(&self) {
        let waker = lock(&self.commit_waker).clone();
        if let Some(w) = waker {
            w();
        }
    }

    /// Bootstrap a (re)started replica at `addr` and splice it into
    /// the live shipping set (the re-attach half of ROADMAP item 1):
    /// under the log lock — so no index can be assigned mid-snapshot —
    /// dump the primary's full state, install it on the replica at the
    /// current high-water (`InstallState`), then park the connection
    /// for the shipper's tail replay. Entries already queued at
    /// indices `<=` the high-water re-ship and are skipped
    /// idempotently by the replica's applied check. Mutations block
    /// for the install round-trip; attaches are rare (one per replica
    /// death), so that pause is the price of a torn-free snapshot.
    pub(crate) fn attach(
        &self,
        addr: SocketAddr,
        shared: &super::tcp_store::Shared,
    ) -> Result<()> {
        let mut c = TcpStoreClient::connect_from(REPL_LINK_SRC, addr, PROBE_CONNECT)?;
        c.set_read_window(Some(Duration::from_secs(10)))?;
        let g = lock(&self.inner);
        let high = g.next_index - 1;
        let ops = shared.snapshot_ops();
        match c.roundtrip(Request::InstallState { high_water: high, ops })? {
            Response::Counter(a) if a as u64 == high => {}
            other => bail!("unexpected InstallState response {other:?}"),
        }
        c.set_read_window(Some(Duration::from_secs(2)))?;
        // still under the log lock: no batch beyond `high` can ship
        // before this connection is visible to the shipper
        lock(&self.pending).push((c, high));
        drop(g);
        let mut cs = lock(&self.commit);
        cs.live_replicas += 1;
        cs.degraded = false;
        drop(cs);
        self.commit_cv.notify_all();
        self.ping_commit_waker();
        Ok(())
    }

    /// Stop the shipper (after it drains any queued entries) and join.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        self.ship_cv.notify_all();
        self.commit_cv.notify_all();
        self.ping_commit_waker();
        if let Some(h) = lock(&self.shipper).take() {
            let _ = h.join();
        }
    }
}

fn shipper_loop(r: &Replicator, mut conns: Vec<TcpStoreClient>) {
    let mut acked: Vec<u64> = vec![0; conns.len()];
    let mut live: Vec<bool> = vec![true; conns.len()];
    loop {
        // Splice in replicas attached since the last batch — before
        // shipping, so the very next frame (and quorum computation)
        // includes them. Take-and-release: never hold `pending` while
        // waiting on the log lock (attach pushes under that lock).
        for (c, ack) in std::mem::take(&mut *lock(&r.pending)) {
            conns.push(c);
            acked.push(ack);
            live.push(true);
        }
        let batch = {
            let mut g = lock(&r.inner);
            while g.queue.is_empty() && !r.stop.load(Ordering::Relaxed) {
                let (g2, _) = r
                    .ship_cv
                    .wait_timeout(g, Duration::from_millis(100))
                    .unwrap_or_else(PoisonError::into_inner);
                g = g2;
            }
            if g.queue.is_empty() {
                // stop requested and nothing left to drain
                break;
            }
            std::mem::take(&mut g.queue)
        };
        let start = batch[0].0;
        let last = batch[batch.len() - 1].0;
        let ops: Vec<Request> = batch.into_iter().map(|(_, op)| op).collect();
        let frame = Request::Replicate { start_index: start, ops };
        for i in 0..conns.len() {
            if !live[i] {
                continue;
            }
            match conns[i].roundtrip(frame.clone()) {
                Ok(Response::Counter(a)) if a as u64 >= last => acked[i] = a as u64,
                // short ack (gap) or IO error: the replica is lost —
                // drop it rather than stall the plane behind it
                _ => live[i] = false,
            }
        }
        let n_live = live.iter().filter(|l| **l).count();
        // quorum = primary + 1 replica, the majority of both a 2-node
        // and a 3-node plane, so the watermark is the highest live
        // replica ack (degraded: everything assigned is "committed")
        let new_mark = if n_live == 0 {
            last
        } else {
            acked
                .iter()
                .zip(&live)
                .filter(|(_, l)| **l)
                .map(|(a, _)| *a)
                .max()
                .unwrap_or(last)
        };
        let mut cs = lock(&r.commit);
        cs.live_replicas = n_live;
        cs.degraded = n_live == 0;
        if new_mark > cs.watermark {
            cs.watermark = new_mark;
        }
        drop(cs);
        r.commit_cv.notify_all();
        r.ping_commit_waker();
    }
    // release every committer on the way out
    let mut cs = lock(&r.commit);
    cs.degraded = true;
    drop(cs);
    r.commit_cv.notify_all();
    r.ping_commit_waker();
}

// ---------------------------------------------------------------------------
// StoreEndpoints
// ---------------------------------------------------------------------------

/// The set of store node addresses a client may talk to. Replaces the
/// bare `SocketAddr` that used to be threaded through `establish`,
/// the heartbeat emitters, rendezvous, restore discovery, and the
/// controller: every consumer now owns the full set and can fail
/// over. The set also carries the [`Dialer`] its links are opened
/// through, so handing impaired endpoints to a session, an emitter,
/// or discovery puts *every* connection they open behind the same
/// degraded path (DESIGN.md §15).
#[derive(Clone)]
pub struct StoreEndpoints {
    addrs: Vec<SocketAddr>,
    dialer: Arc<dyn Dialer>,
}

impl std::fmt::Debug for StoreEndpoints {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StoreEndpoints")
            .field("addrs", &self.addrs)
            .field("dialer", &self.dialer.name())
            .finish()
    }
}

// Identity is the address set: the dialer shapes *how* links reach
// those addresses, not *which* plane they name.
impl PartialEq for StoreEndpoints {
    fn eq(&self, other: &Self) -> bool {
        self.addrs == other.addrs
    }
}

impl Eq for StoreEndpoints {}

impl StoreEndpoints {
    /// Single-node plane (the backward-compatible common case).
    pub fn one(addr: SocketAddr) -> Self {
        StoreEndpoints { addrs: vec![addr], dialer: default_dialer() }
    }

    /// Multi-node plane. The first address is the primary hint;
    /// discovery still probes every endpoint.
    pub fn new(addrs: Vec<SocketAddr>) -> Self {
        assert!(!addrs.is_empty(), "endpoint set must not be empty");
        StoreEndpoints { addrs, dialer: default_dialer() }
    }

    /// Route every link opened through this endpoint set via an
    /// explicit dialer (e.g. a `comms::netem::NetemDialer`).
    pub fn with_dialer(mut self, dialer: Arc<dyn Dialer>) -> Self {
        self.dialer = dialer;
        self
    }

    pub fn dialer(&self) -> Arc<dyn Dialer> {
        self.dialer.clone()
    }

    /// Open a store client to `addr` through this set's dialer.
    pub fn dial(&self, addr: SocketAddr, timeout: Duration) -> Result<TcpStoreClient> {
        TcpStoreClient::connect_via(&*self.dialer, addr, timeout)
    }

    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    /// Best-guess primary without a discovery round-trip (used by
    /// latency-sensitive bursts like `establish`).
    pub fn primary_hint(&self) -> SocketAddr {
        self.addrs[0]
    }
}

impl From<SocketAddr> for StoreEndpoints {
    fn from(addr: SocketAddr) -> Self {
        StoreEndpoints::one(addr)
    }
}

// ---------------------------------------------------------------------------
// StoreSession (client side)
// ---------------------------------------------------------------------------

/// One node's replication status as reported by `ReplStatus`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplStatusInfo {
    pub role: StoreRole,
    pub applied: u64,
    pub epoch: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreRole {
    Primary,
    Replica,
}

/// Ask one connection for its replication status.
pub fn repl_status(c: &mut TcpStoreClient) -> Result<ReplStatusInfo> {
    match c.roundtrip(Request::ReplStatus)? {
        Response::Value(v) if v.len() == 17 => {
            let role =
                if v[0] == ROLE_PRIMARY { StoreRole::Primary } else { StoreRole::Replica };
            let applied = u64::from_le_bytes(v[1..9].try_into().expect("len checked"));
            let epoch = u64::from_le_bytes(v[9..17].try_into().expect("len checked"));
            Ok(ReplStatusInfo { role, applied, epoch })
        }
        other => bail!("unexpected ReplStatus response {other:?}"),
    }
}

static SESSION_NONCE: AtomicU64 = AtomicU64::new(1);

/// Session-owning store client: discovers the primary among its
/// endpoint set, then behaves like a `TcpStoreClient` whose every op
/// transparently survives a primary crash. Blocking waits are
/// replayed against the new primary; non-idempotent ops retry under a
/// stable `Dedup` id so a replay can never double-apply.
pub struct StoreSession {
    endpoints: StoreEndpoints,
    client: TcpStoreClient,
    primary: SocketAddr,
    ops: u64,
    dedup_base: u64,
    dedup_seq: u64,
    trace_ctx: Option<TraceCtx>,
}

impl StoreSession {
    /// Connect, retrying discovery for up to 10s (covers a plane that
    /// is mid-failover when the session starts).
    pub fn connect(endpoints: StoreEndpoints) -> Result<Self> {
        Self::connect_within(endpoints, FAILOVER_PATIENCE)
    }

    /// One discovery pass, no retry loop — the building block
    /// `connect` and the heartbeat emitters' bounded backoff wrap.
    pub fn try_connect(endpoints: &StoreEndpoints) -> Result<Self> {
        let (primary, client) = discover(endpoints)?;
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let nonce = SESSION_NONCE.fetch_add(1, Ordering::Relaxed);
        let mut seed = [0u8; 16];
        seed[..8].copy_from_slice(&nanos.to_le_bytes());
        seed[8..].copy_from_slice(&nonce.to_le_bytes());
        Ok(StoreSession {
            endpoints: endpoints.clone(),
            client,
            primary,
            ops: 0,
            dedup_base: crate::util::fnv1a(&seed),
            dedup_seq: 0,
            trace_ctx: None,
        })
    }

    /// Connect with an explicit discovery deadline. Retry delays are
    /// jittered per session, so many clients re-joining a plane at
    /// once (e.g. after a partition heals) spread their discovery
    /// probes instead of stampeding the promoted primary.
    pub fn connect_within(endpoints: StoreEndpoints, patience: Duration) -> Result<Self> {
        let deadline = Instant::now() + patience;
        let salt = SESSION_NONCE.fetch_add(1, Ordering::Relaxed);
        let mut attempt = 0u32;
        loop {
            match Self::try_connect(&endpoints) {
                Ok(s) => return Ok(s),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    attempt += 1;
                    std::thread::sleep(jittered(
                        Duration::from_millis(25),
                        salt,
                        attempt,
                    ));
                }
            }
        }
    }

    /// The primary this session currently talks to.
    pub fn primary_addr(&self) -> SocketAddr {
        self.primary
    }

    pub fn endpoints(&self) -> &StoreEndpoints {
        &self.endpoints
    }

    /// Logical ops acknowledged by the store for this session —
    /// counts like `TcpStoreClient::ops_sent` (batched sub-ops
    /// individually; dedup envelopes are free), so protocol message
    /// budgets are unchanged by the session layer.
    pub fn ops_sent(&self) -> u64 {
        self.ops
    }

    /// Stamp (or clear) the trace context on every outgoing frame;
    /// survives failover (re-stamped onto the replacement
    /// connection).
    pub fn set_trace_ctx(&mut self, ctx: Option<TraceCtx>) {
        self.trace_ctx = ctx;
        self.client.set_trace_ctx(ctx);
    }

    fn next_dedup_id(&mut self) -> u64 {
        self.dedup_seq += 1;
        self.dedup_base.wrapping_add(self.dedup_seq)
    }

    /// Tear down the current connection and rediscover the primary.
    /// The retry delay is jittered by the session's dedup base (one
    /// stable salt per session), so a fleet of sessions orphaned by
    /// the same primary crash fans its reconnects out over the base
    /// interval instead of synchronizing on the promoted node.
    fn fail_over(&mut self) -> Result<()> {
        let deadline = Instant::now() + FAILOVER_PATIENCE;
        let mut attempt = 0u32;
        loop {
            match discover(&self.endpoints) {
                Ok((primary, mut client)) => {
                    client.set_trace_ctx(self.trace_ctx);
                    self.primary = primary;
                    self.client = client;
                    return Ok(());
                }
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    attempt += 1;
                    std::thread::sleep(jittered(
                        Duration::from_millis(50),
                        self.dedup_base,
                        attempt,
                    ));
                }
            }
        }
    }

    /// Retry core for non-blocking ops: NotPrimary or an IO error
    /// triggers failover; anything else is the answer.
    fn call(&mut self, req: Request) -> Result<Response> {
        let mut last_err: Option<anyhow::Error> = None;
        for _ in 0..SESSION_RETRIES {
            match self.client.roundtrip(req.clone()) {
                Ok(Response::NotPrimary) => self.fail_over()?,
                Ok(resp) => {
                    self.ops += 1;
                    return Ok(resp);
                }
                Err(e) => {
                    last_err = Some(e);
                    self.fail_over()?;
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("store session: retries exhausted")))
    }

    /// Retry core for blocking waits: additionally treats a
    /// `NotFound` release (the dying server's shutdown broadcast) as
    /// a failover trigger, replaying the parked wait against the new
    /// primary.
    fn call_wait(&mut self, req: Request) -> Result<Response> {
        let mut last_err: Option<anyhow::Error> = None;
        for _ in 0..SESSION_RETRIES {
            self.client.set_read_window(Some(Duration::from_secs(300)))?;
            match self.client.roundtrip(req.clone()) {
                Ok(Response::NotPrimary) | Ok(Response::NotFound) => self.fail_over()?,
                Ok(resp) => {
                    self.ops += 1;
                    return Ok(resp);
                }
                Err(e) => {
                    last_err = Some(e);
                    self.fail_over()?;
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("store session: wait retries exhausted")))
    }

    pub fn hello(&mut self, client_id: u64) -> Result<()> {
        match self.call(Request::Hello { client_id })? {
            Response::HelloAck => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn set(&mut self, key: &str, value: &[u8]) -> Result<()> {
        match self.call(Request::Set { key: key.into(), value: value.into() })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn get(&mut self, key: &str) -> Result<Option<Bytes>> {
        match self.call(Request::Get { key: key.into() })? {
            Response::Value(v) => Ok(Some(v)),
            Response::NotFound => Ok(None),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Non-idempotent: retried under a stable dedup id, so a replay
    /// after failover returns the cached counter instead of adding
    /// twice.
    pub fn add(&mut self, key: &str, delta: i64) -> Result<i64> {
        let id = self.next_dedup_id();
        let req = Request::Dedup {
            id,
            op: Box::new(Request::Add { key: key.into(), delta }),
        };
        match self.call(req)? {
            Response::Counter(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn count(&mut self) -> Result<u64> {
        match self.call(Request::Count)? {
            Response::CountIs(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Block until `key` is published — replayed against the new
    /// primary if the one this session parked on dies.
    pub fn wait(&mut self, key: &str) -> Result<Bytes> {
        match self.call_wait(Request::Wait { key: key.into() })? {
            Response::Value(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Epoch-fenced wait, failover-transparent like [`Self::wait`].
    pub fn wait_epoch(&mut self, key: &str, epoch: u64) -> Result<FencedWait> {
        match self.call_wait(Request::WaitEpoch { key: key.into(), epoch })? {
            Response::Value(v) => Ok(FencedWait::Value(v)),
            Response::EpochFenced { current } => Ok(FencedWait::Superseded { current }),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn advance_epoch(&mut self, to: u64) -> Result<u64> {
        match self.call(Request::AdvanceEpoch { to })? {
            Response::Counter(v) => Ok(v as u64),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn advertise_restore(
        &mut self,
        epoch: u64,
        tag: u64,
        addr: &str,
    ) -> Result<Option<u64>> {
        let req = Request::AdvertiseRestore { epoch, tag, addr: addr.into() };
        match self.call(req)? {
            Response::Ok => Ok(None),
            Response::EpochFenced { current } => Ok(Some(current)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn claim_restore(&mut self, epoch: u64, tag: u64) -> Result<FencedWait> {
        match self.call_wait(Request::ClaimRestore { epoch, tag })? {
            Response::Value(v) => Ok(FencedWait::Value(v)),
            Response::EpochFenced { current } => Ok(FencedWait::Superseded { current }),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn abort_epoch_unless(
        &mut self,
        unless_key: &str,
        tombstone_key: &str,
        tombstone: &[u8],
        to: u64,
    ) -> Result<bool> {
        let req = Request::AbortEpoch {
            unless_key: unless_key.into(),
            tombstone_key: tombstone_key.into(),
            tombstone: tombstone.to_vec(),
            to,
        };
        match self.call(req)? {
            Response::Counter(v) => Ok(v == 1),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn heartbeat(
        &mut self,
        rank: u64,
        incarnation: u64,
        step_tag: i64,
        device_code: i64,
    ) -> Result<()> {
        let req = Request::Heartbeat { rank, incarnation, step_tag, device_code };
        match self.call(req)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn del_prefix(&mut self, prefix: &str) -> Result<i64> {
        match self.call(Request::DelPrefix { prefix: prefix.into() })? {
            Response::Counter(v) => Ok(v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<Snapshot> {
        match self.call(Request::Stats)? {
            Response::Value(v) => Snapshot::parse(&v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Fetch the heartbeat beat table (`Beats` wire op),
    /// failover-transparent. Replicas serve it too — a promoted
    /// standby rebuilds lease state from these real beats instead of
    /// only the derived `ctl/leases` keys.
    pub fn beats(&mut self) -> Result<Vec<BeatRecord>> {
        match self.call(Request::Beats)? {
            Response::Value(v) => decode_beats(&v),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Pipelined batch, failover-transparent. A batch containing any
    /// `Add` is wrapped in a `Dedup` envelope whose id is stable
    /// across retries: if the primary dies after executing the batch
    /// but before the ack arrives, the replay returns the replicated
    /// cached responses — no double-applied counter, no lost publish.
    pub fn batch(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let n = reqs.len();
        let blocking = reqs.iter().any(Request::is_blocking);
        let wait_pos: Vec<usize> = reqs
            .iter()
            .enumerate()
            .filter(|(_, r)| r.is_blocking())
            .map(|(i, _)| i)
            .collect();
        let needs_dedup = reqs.iter().any(|r| matches!(r, Request::Add { .. }));
        let req = if needs_dedup {
            let id = self.next_dedup_id();
            Request::Dedup { id, op: Box::new(Request::Batch(reqs)) }
        } else {
            Request::Batch(reqs)
        };
        let mut last_err: Option<anyhow::Error> = None;
        for _ in 0..SESSION_RETRIES {
            if blocking {
                self.client.set_read_window(Some(Duration::from_secs(300)))?;
            }
            match self.client.roundtrip(req.clone()) {
                Ok(Response::Multi(rs)) => {
                    // a blocking sub-op released by the dying server's
                    // shutdown broadcast answers NotFound: replay the
                    // whole batch against the new primary
                    if wait_pos
                        .iter()
                        .any(|&i| rs.get(i) == Some(&Response::NotFound))
                    {
                        self.fail_over()?;
                        continue;
                    }
                    if rs.len() > n {
                        bail!("batch returned {} responses for {n} ops", rs.len());
                    }
                    self.ops += rs.len() as u64;
                    return Ok(rs);
                }
                Ok(Response::NotPrimary) => self.fail_over()?,
                Ok(other) => bail!("unexpected batch response {other:?}"),
                Err(e) => {
                    last_err = Some(e);
                    self.fail_over()?;
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("store session: batch retries exhausted")))
    }
}

/// Probe every endpoint: an existing primary wins immediately;
/// otherwise the most advanced reachable replica — max `(epoch,
/// applied)`, ties broken by endpoint order so concurrent discoverers
/// elect the same node — is promoted. The epoch is the fence: a
/// replica behind on epoch can never be chosen over one that has seen
/// the newer epoch, so a failed-over plane never serves a stale
/// epoch.
fn discover(eps: &StoreEndpoints) -> Result<(SocketAddr, TcpStoreClient)> {
    let mut best: Option<(u64, u64, usize)> = None;
    for (i, &addr) in eps.addrs().iter().enumerate() {
        let Ok(mut c) = eps.dial(addr, PROBE_CONNECT) else {
            continue;
        };
        let Ok(st) = repl_status(&mut c) else { continue };
        if st.role == StoreRole::Primary {
            return Ok((addr, c));
        }
        let better = match best {
            None => true,
            Some((e, a, _)) => (st.epoch, st.applied) > (e, a),
        };
        if better {
            best = Some((st.epoch, st.applied, i));
        }
    }
    let Some((_, _, i)) = best else {
        bail!("no reachable store endpoint in {:?}", eps.addrs());
    };
    let addr = eps.addrs()[i];
    let mut c = eps.dial(addr, PROBE_CONNECT)?;
    let peers: Vec<String> = eps
        .addrs()
        .iter()
        .enumerate()
        .filter(|(j, _)| *j != i)
        .map(|(_, a)| a.to_string())
        .collect();
    match c.roundtrip(Request::Promote { peers })? {
        Response::Ok => Ok((addr, c)),
        other => bail!("unexpected Promote response {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// ReplicaSet (in-process plane harness)
// ---------------------------------------------------------------------------

/// An in-process replicated coordination plane: one primary plus N
/// replicas, wired together at start. The controller's rebuild plane,
/// the failover chaos drivers, and the replicated-mode store bench
/// all run on one of these. `replicas == 0` degenerates to a plain
/// un-replicated primary with zero added overhead.
pub struct ReplicaSet {
    primary: Option<TcpStoreServer>,
    replicas: Vec<TcpStoreServer>,
    addrs: Vec<SocketAddr>,
}

impl ReplicaSet {
    pub fn start(replicas: usize) -> Result<Self> {
        let primary = TcpStoreServer::start()?;
        let mut reps = Vec::new();
        for _ in 0..replicas {
            let s = TcpStoreServer::start()?;
            s.set_replica();
            reps.push(s);
        }
        let peer_addrs: Vec<SocketAddr> = reps.iter().map(|r| r.addr()).collect();
        primary.promote(&peer_addrs);
        let mut addrs = vec![primary.addr()];
        addrs.extend(peer_addrs);
        Ok(ReplicaSet { primary: Some(primary), replicas: reps, addrs })
    }

    /// The full endpoint set (includes a killed primary's address —
    /// sessions skip dead endpoints during discovery).
    pub fn endpoints(&self) -> StoreEndpoints {
        StoreEndpoints::new(self.addrs.clone())
    }

    /// Address of the original primary slot (the legacy single-addr
    /// call sites' view of the plane).
    pub fn addr(&self) -> SocketAddr {
        self.addrs[0]
    }

    pub fn primary_server(&self) -> Option<&TcpStoreServer> {
        self.primary.as_ref()
    }

    pub fn replica_servers(&self) -> &[TcpStoreServer] {
        &self.replicas
    }

    /// Crash the primary (drops the server: listener closes, parked
    /// waiters release, the replication shipper drains and stops).
    /// Returns its address, or None if already killed.
    pub fn kill_primary(&mut self) -> Option<SocketAddr> {
        self.primary.take().map(|p| p.addr())
    }

    /// Crash one replica (drops its server). The dead address stays in
    /// the endpoint set — sessions skip unreachable endpoints — so the
    /// plane's identity is unchanged, only its quorum shrinks.
    pub fn kill_replica(&mut self, i: usize) -> Option<SocketAddr> {
        if i < self.replicas.len() {
            Some(self.replicas.remove(i).addr())
        } else {
            None
        }
    }

    /// Start a fresh replica and re-attach it to the live primary:
    /// snapshot install at the log high-water, then live tail replay
    /// (the kill-then-rejoin path of DESIGN.md §13). The rejoined
    /// node binds a new port, appended to the endpoint set.
    pub fn rejoin_replica(&mut self) -> Result<SocketAddr> {
        let primary = self
            .primary
            .as_ref()
            .ok_or_else(|| anyhow!("no live primary to rejoin"))?;
        let s = TcpStoreServer::start()?;
        s.set_replica();
        primary.attach_replica(s.addr())?;
        let addr = s.addr();
        self.replicas.push(s);
        self.addrs.push(addr);
        Ok(addr)
    }

    /// A fresh failover-capable session onto this plane.
    pub fn session(&self) -> Result<StoreSession> {
        StoreSession::connect(self.endpoints())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wait_parked(server: &TcpStoreServer, n: i64) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.metrics_snapshot().gauge("store.parked_waiters") < n {
            assert!(Instant::now() < deadline, "waiters never parked");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn endpoints_basics() {
        let a: SocketAddr = "127.0.0.1:1001".parse().unwrap();
        let b: SocketAddr = "127.0.0.1:1002".parse().unwrap();
        let eps = StoreEndpoints::new(vec![a, b]);
        assert_eq!(eps.addrs(), &[a, b]);
        assert_eq!(eps.primary_hint(), a);
        assert_eq!(StoreEndpoints::from(a), StoreEndpoints::one(a));
    }

    #[test]
    fn dedup_map_is_fifo_bounded() {
        let mut m = DedupMap::new();
        for id in 0..(DEDUP_CAP as u64 + 10) {
            m.insert(id, vec![id as u8]);
        }
        assert_eq!(m.len(), DEDUP_CAP);
        assert_eq!(m.get(0), None, "oldest entries evicted");
        assert!(m.get(DEDUP_CAP as u64 + 9).is_some());
        // re-insert of a live id neither grows nor re-orders
        m.insert(DEDUP_CAP as u64 + 9, vec![1]);
        assert_eq!(m.len(), DEDUP_CAP);
    }

    #[test]
    fn session_works_against_single_unreplicated_server() {
        let server = TcpStoreServer::start().unwrap();
        let mut s = StoreSession::connect(server.endpoints()).unwrap();
        assert_eq!(s.primary_addr(), server.addr());
        s.set("k", b"v").unwrap();
        assert_eq!(s.get("k").unwrap().as_deref(), Some(&b"v"[..]));
        assert_eq!(s.add("n", 3).unwrap(), 3);
        assert_eq!(s.add("n", 4).unwrap(), 7);
        assert_eq!(s.ops_sent(), 4);
    }

    #[test]
    fn quorum_acked_writes_are_on_the_replica_by_ack_time() {
        let set = ReplicaSet::start(1).unwrap();
        let mut s = set.session().unwrap();
        s.set("a", b"1").unwrap();
        s.add("ctr", 5).unwrap();
        s.advance_epoch(3).unwrap();
        // the ack required the replica's append: read it back directly
        let replica = &set.replica_servers()[0];
        let mut rc = TcpStoreClient::connect(replica.addr()).unwrap();
        assert_eq!(rc.get("a").unwrap().as_deref(), Some(&b"1"[..]));
        assert_eq!(replica.epoch(), 3);
        // and the replica refuses mutations
        assert_eq!(
            rc.roundtrip(Request::Set { key: "x".into(), value: b"v".to_vec() }).unwrap(),
            Response::NotPrimary
        );
        assert_eq!(
            rc.roundtrip(Request::Wait { key: "x".into() }).unwrap(),
            Response::NotPrimary
        );
    }

    #[test]
    fn session_discovers_primary_regardless_of_endpoint_order() {
        let set = ReplicaSet::start(2).unwrap();
        let mut addrs = set.endpoints().addrs().to_vec();
        addrs.reverse(); // replicas listed first
        let mut s = StoreSession::connect(StoreEndpoints::new(addrs)).unwrap();
        assert_eq!(s.primary_addr(), set.addr());
        s.set("k", b"v").unwrap();
        assert_eq!(s.get("k").unwrap().as_deref(), Some(&b"v"[..]));
    }

    #[test]
    fn dedup_replay_returns_cached_response_without_reexecution() {
        let server = TcpStoreServer::start().unwrap();
        let mut c = TcpStoreClient::connect(server.addr()).unwrap();
        let req = Request::Dedup {
            id: 42,
            op: Box::new(Request::Add { key: "ctr".into(), delta: 5 }),
        };
        assert_eq!(c.roundtrip(req.clone()).unwrap(), Response::Counter(5));
        // replay: cached answer, counter unchanged
        assert_eq!(c.roundtrip(req).unwrap(), Response::Counter(5));
        assert_eq!(c.add("ctr", 0).unwrap(), 5);
    }

    #[test]
    fn failover_preserves_quorum_acked_state_and_epoch_fence() {
        let mut set = ReplicaSet::start(1).unwrap();
        let mut s = set.session().unwrap();
        s.set("a", b"1").unwrap();
        s.advance_epoch(3).unwrap();
        set.kill_primary();
        // a fresh session discovers + promotes the surviving replica
        let mut s2 = set.session().unwrap();
        assert_eq!(s2.get("a").unwrap().as_deref(), Some(&b"1"[..]));
        // the fence survived: a wait fenced at an older epoch is
        // released as superseded, never served stale
        assert_eq!(
            s2.wait_epoch("absent", 2).unwrap(),
            FencedWait::Superseded { current: 3 }
        );
        // and the old session's next op transparently fails over too
        s.set("b", b"2").unwrap();
        assert_eq!(s2.get("b").unwrap().as_deref(), Some(&b"2"[..]));
    }

    #[test]
    fn failover_resumes_parked_wait_exactly_once() {
        let mut set = ReplicaSet::start(1).unwrap();
        let eps = set.endpoints();
        let waiter = std::thread::spawn(move || {
            let mut s = StoreSession::connect(eps).unwrap();
            s.wait("late").unwrap()
        });
        wait_parked(set.primary_server().unwrap(), 1);
        set.kill_primary();
        // publish on the failed-over plane: the parked wait must
        // resume against the new primary and see exactly this value
        let mut pub_s = set.session().unwrap();
        pub_s.set("late", b"v").unwrap();
        assert_eq!(&waiter.join().unwrap()[..], b"v");
        // the publish itself was not lost
        assert_eq!(pub_s.get("late").unwrap().as_deref(), Some(&b"v"[..]));
    }

    #[test]
    fn failover_mid_batch_rekey_is_exactly_once() {
        // the survivor re-key shape: batch([WaitEpoch(delta), Add(arrived)])
        let mut set = ReplicaSet::start(1).unwrap();
        let eps = set.endpoints();
        let survivor = std::thread::spawn(move || {
            let mut s = StoreSession::connect(eps).unwrap();
            s.batch(vec![
                Request::WaitEpoch { key: "rdzv/1/delta".into(), epoch: 1 },
                Request::Add { key: "rdzv/1/arrived".into(), delta: 1 },
            ])
            .unwrap()
        });
        wait_parked(set.primary_server().unwrap(), 1);
        set.kill_primary();
        let mut coord = set.session().unwrap();
        coord.set("rdzv/1/delta", b"plan").unwrap();
        let rs = survivor.join().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0], Response::Value(Bytes::from(&b"plan"[..])));
        assert_eq!(rs[1], Response::Counter(1));
        // exactly once: the replayed batch did not double-arrive
        assert_eq!(coord.add("rdzv/1/arrived", 0).unwrap(), 1);
    }

    #[test]
    fn fenced_prefix_rule_holds_across_failover() {
        let mut set = ReplicaSet::start(1).unwrap();
        let eps = set.endpoints();
        let survivor = std::thread::spawn(move || {
            let mut s = StoreSession::connect(eps).unwrap();
            s.batch(vec![
                Request::WaitEpoch { key: "rdzv/1/delta".into(), epoch: 1 },
                Request::Add { key: "rdzv/1/arrived".into(), delta: 1 },
            ])
            .unwrap()
        });
        wait_parked(set.primary_server().unwrap(), 1);
        set.kill_primary();
        // instead of publishing, the new primary's epoch moves on:
        // the replayed batch must fence and never run its Add tail
        let mut coord = set.session().unwrap();
        coord.advance_epoch(5).unwrap();
        let rs = survivor.join().unwrap();
        assert_eq!(rs, vec![Response::EpochFenced { current: 5 }]);
        assert_eq!(coord.add("rdzv/1/arrived", 0).unwrap(), 0, "fenced tail must not run");
    }

    #[test]
    fn degraded_plane_keeps_serving_after_losing_every_replica() {
        let mut set = ReplicaSet::start(1).unwrap();
        let mut s = set.session().unwrap();
        s.set("pre", b"1").unwrap();
        // crash the only replica: the primary must degrade to
        // un-replicated operation instead of wedging behind its peer
        set.replicas.clear();
        s.set("post", b"2").unwrap();
        assert_eq!(s.add("ctr", 1).unwrap(), 1);
        assert_eq!(s.get("post").unwrap().as_deref(), Some(&b"2"[..]));
    }

    #[test]
    fn session_batch_without_add_is_not_dedup_wrapped() {
        // heartbeat coalescing batches are idempotent: no envelope
        let server = TcpStoreServer::start().unwrap();
        let mut s = StoreSession::connect(server.endpoints()).unwrap();
        let rs = s
            .batch(vec![
                Request::Heartbeat { rank: 1, incarnation: 1, step_tag: 0, device_code: -1 },
                Request::Heartbeat { rank: 2, incarnation: 1, step_tag: 0, device_code: -1 },
            ])
            .unwrap();
        assert_eq!(rs, vec![Response::Ok, Response::Ok]);
        assert_eq!(s.ops_sent(), 2);
        assert_eq!(server.beats().len(), 2);
    }

    #[test]
    fn beats_are_readable_over_the_wire_from_replicas() {
        let set = ReplicaSet::start(1).unwrap();
        let mut s = set.session().unwrap();
        s.heartbeat(0, 1, 4, -1).unwrap();
        s.heartbeat(1, 2, 5, 3).unwrap();
        // the beat table is log-replicated; read it from the replica
        let mut rc = TcpStoreClient::connect(set.replica_servers()[0].addr()).unwrap();
        let mut beats = rc.beats().unwrap();
        beats.sort_by_key(|b| b.rank);
        assert_eq!(beats.len(), 2);
        assert_eq!(beats[0].incarnation, 1);
        assert_eq!(beats[0].step_tag, 4);
        assert_eq!(beats[1].device_code, 3);
        // freshness survives the age_ms round-trip
        assert!(beats[0].at.elapsed() < Duration::from_secs(5));
        // and the session API reads the same table with failover
        assert_eq!(s.beats().unwrap().len(), 2);
    }

    #[test]
    fn killed_replica_rejoins_and_catches_up_from_high_water() {
        let mut set = ReplicaSet::start(1).unwrap();
        let mut s = set.session().unwrap();
        s.set("pre", b"1").unwrap();
        assert_eq!(s.add("ctr", 2).unwrap(), 2);
        s.advance_epoch(2).unwrap();
        s.heartbeat(3, 1, 7, -1).unwrap();
        // crash the only replica: the plane degrades but keeps serving
        set.kill_replica(0).unwrap();
        s.set("while-dead", b"2").unwrap();
        // rejoin: snapshot install at the high-water + live tail replay
        let addr = set.rejoin_replica().unwrap();
        s.set("post", b"3").unwrap();
        let mut rc = TcpStoreClient::connect(addr).unwrap();
        // snapshot state, the write made while dead, and the post-rejoin
        // tail are all on the rejoined node
        assert_eq!(rc.get("pre").unwrap().as_deref(), Some(&b"1"[..]));
        assert_eq!(rc.get("while-dead").unwrap().as_deref(), Some(&b"2"[..]));
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if rc.get("post").unwrap().as_deref() == Some(&b"3"[..]) {
                break;
            }
            assert!(Instant::now() < deadline, "tail replay never landed");
            std::thread::sleep(Duration::from_millis(5));
        }
        let replica = set.replica_servers().last().unwrap();
        assert_eq!(replica.addr(), addr);
        assert_eq!(replica.epoch(), 2, "epoch travelled with the snapshot");
        assert_eq!(rc.beats().unwrap().len(), 1, "beat table travelled too");
        // the real proof: kill the primary and promote the rejoined
        // replica — counters, fences, and keys must all be intact
        set.kill_primary();
        let mut s2 = set.session().unwrap();
        assert_eq!(s2.get("pre").unwrap().as_deref(), Some(&b"1"[..]));
        assert_eq!(s2.add("ctr", 0).unwrap(), 2, "counter survived rejoin + failover");
        assert_eq!(
            s2.wait_epoch("absent", 1).unwrap(),
            FencedWait::Superseded { current: 2 }
        );
    }

    #[test]
    fn rejoin_without_live_primary_is_refused() {
        let mut set = ReplicaSet::start(1).unwrap();
        set.kill_primary();
        assert!(set.rejoin_replica().is_err());
    }
}
