//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(body) = item.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = args("run --size small --steps 100");
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("size"), Some("small"));
        assert_eq!(a.usize_or("steps", 0), 100);
    }

    #[test]
    fn parses_equals_form() {
        let a = args("--dp=4 --lr=0.001");
        assert_eq!(a.usize_or("dp", 0), 4);
        assert!((a.f64_or("lr", 0.0) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn bare_flag_is_true() {
        // A flag followed by a non-flag token consumes it as its value
        // (documented ambiguity — use `--flag=true` before positionals).
        let a = args("train --verbose");
        assert!(a.bool_or("verbose", false));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn trailing_bare_flag() {
        let a = args("--check");
        assert!(a.bool_or("check", false));
    }

    #[test]
    fn defaults_apply() {
        let a = args("");
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.str_or("mode", "x"), "x");
        assert!(!a.bool_or("flag", false));
    }
}
