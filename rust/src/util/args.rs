//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! args, plus the shared flag surface of the `bench` suites
//! ([`BenchFlags`], comma-separated count lists).

use std::collections::BTreeMap;

/// Shared flags of every `flashrecovery bench <suite>` invocation:
/// where to write the JSON report (`--json`, with `--out` kept as an
/// alias), the optional committed baseline to gate against, and the
/// gate ratio. `--gate`
/// works both bare (defaults to 1.5x) and valued (`--gate 1.3`);
/// gating only runs when `--baseline` is present.
#[derive(Debug, Clone)]
pub struct BenchFlags {
    /// Output path for the suite's JSON report.
    pub out: String,
    /// Committed baseline JSON to gate p50 regressions against.
    pub baseline: Option<String>,
    /// Max allowed p50 ratio vs the baseline.
    pub gate: f64,
}

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(body) = item.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) | None => default,
        }
    }

    /// Parse the shared bench flags with a per-suite default output
    /// path (see [`BenchFlags`]).
    pub fn bench_flags(&self, default_out: &str) -> BenchFlags {
        let out = self
            .get("json")
            .or_else(|| self.get("out"))
            .unwrap_or(default_out)
            .to_string();
        let gate = match self.get("gate") {
            None | Some("true") => 1.5,
            Some(v) => v.parse().unwrap_or(1.5),
        };
        BenchFlags {
            out,
            baseline: self.get("baseline").map(str::to_string),
            gate,
        }
    }

    /// Comma-separated count list, e.g. `--scales 64,256,1024`.
    /// `Ok(None)` when the flag is absent; an error on an empty or
    /// unparsable list.
    pub fn usize_list(&self, key: &str) -> anyhow::Result<Option<Vec<usize>>> {
        let Some(raw) = self.get(key) else {
            return Ok(None);
        };
        let v = raw
            .split(',')
            .map(str::trim)
            .filter(|x| !x.is_empty())
            .map(str::parse::<usize>)
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| anyhow::anyhow!("--{key} {raw:?}: {e}"))?;
        if v.is_empty() {
            anyhow::bail!("--{key} needs at least one value");
        }
        Ok(Some(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = args("run --size small --steps 100");
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("size"), Some("small"));
        assert_eq!(a.usize_or("steps", 0), 100);
    }

    #[test]
    fn parses_equals_form() {
        let a = args("--dp=4 --lr=0.001");
        assert_eq!(a.usize_or("dp", 0), 4);
        assert!((a.f64_or("lr", 0.0) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn bare_flag_is_true() {
        // A flag followed by a non-flag token consumes it as its value
        // (documented ambiguity — use `--flag=true` before positionals).
        let a = args("train --verbose");
        assert!(a.bool_or("verbose", false));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn trailing_bare_flag() {
        let a = args("--check");
        assert!(a.bool_or("check", false));
    }

    #[test]
    fn defaults_apply() {
        let a = args("");
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.str_or("mode", "x"), "x");
        assert!(!a.bool_or("flag", false));
    }

    #[test]
    fn bench_flags_unified_form() {
        // the `bench <suite>` surface: --json output, bare --gate
        let a = args("bench rebuild --json r.json --baseline b.json --gate");
        let f = a.bench_flags("default.json");
        assert_eq!(f.out, "r.json");
        assert_eq!(f.baseline.as_deref(), Some("b.json"));
        assert!((f.gate - 1.5).abs() < 1e-12);
    }

    #[test]
    fn bench_flags_out_alias_and_valued_gate() {
        // --out is an accepted alias for --json (bench-gate.yml uses
        // it), and --gate takes an explicit ratio
        let a = args("bench store --out s.json --baseline b.json --gate 1.3");
        let f = a.bench_flags("default.json");
        assert_eq!(f.out, "s.json");
        assert_eq!(f.baseline.as_deref(), Some("b.json"));
        assert!((f.gate - 1.3).abs() < 1e-12);
        // no baseline, no output flag -> suite default, no gating
        let f = args("bench store").bench_flags("default.json");
        assert_eq!(f.out, "default.json");
        assert!(f.baseline.is_none());
    }

    #[test]
    fn usize_list_parses_and_rejects() {
        let a = args("bench detect --scales 64,256,1024");
        assert_eq!(a.usize_list("scales").unwrap(), Some(vec![64, 256, 1024]));
        // trailing comma tolerated, empty and junk lists rejected
        assert_eq!(args("--scales 64,").usize_list("scales").unwrap(), Some(vec![64]));
        assert!(args("--scales=,").usize_list("scales").is_err());
        assert!(args("--scales nope").usize_list("scales").is_err());
        assert_eq!(args("bench").usize_list("scales").unwrap(), None);
    }
}
