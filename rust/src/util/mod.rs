//! Foundation utilities: deterministic RNG, JSON, CLI args, property
//! testing. These replace external crates (rand/serde/clap/proptest)
//! that are unavailable in the offline build environment.

pub mod args;
#[cfg(target_os = "linux")]
pub mod epoll;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;

pub use args::{Args, BenchFlags};
pub use json::Json;
pub use rng::Rng;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// FNV-1a over a byte slice — the identity hash for chaos scenario
/// specs and journal digests (the byte-at-a-time reference variant;
/// bulk data uses the word-wise [`hash::fnv1a`]). Kept as a top-level
/// alias so existing call sites stay one import away.
pub fn fnv1a(data: &[u8]) -> u64 {
    hash::fnv1a_bytes(data)
}

/// Create a unique temporary directory under the system temp dir
/// (tempfile crate substitute). The directory is NOT auto-deleted;
/// tests clean up explicitly or rely on /tmp hygiene.
pub fn temp_dir(prefix: &str) -> std::io::Result<PathBuf> {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let pid = std::process::id();
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .subsec_nanos();
    let dir = std::env::temp_dir().join(format!("{prefix}-{pid}-{nanos}-{n}"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// Locate the repo's `artifacts/` directory from tests/examples/benches,
/// which may run from the target dir. Checks `FLASHREC_ARTIFACTS`, then
/// walks up from the current dir and from CARGO_MANIFEST_DIR.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("FLASHREC_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.is_dir() {
            return Some(p);
        }
    }
    let mut candidates: Vec<PathBuf> = Vec::new();
    if let Ok(cwd) = std::env::current_dir() {
        candidates.push(cwd);
    }
    candidates.push(PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    for start in candidates {
        let mut dir: Option<&Path> = Some(start.as_path());
        while let Some(d) = dir {
            let art = d.join("artifacts");
            if art.join("manifest.json").is_file() {
                return Some(art);
            }
            dir = d.parent();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temp_dirs_are_unique() {
        let a = temp_dir("flashrec-test").unwrap();
        let b = temp_dir("flashrec-test").unwrap();
        assert_ne!(a, b);
        assert!(a.is_dir() && b.is_dir());
        std::fs::remove_dir_all(a).ok();
        std::fs::remove_dir_all(b).ok();
    }

    #[test]
    fn artifacts_dir_found_in_repo() {
        // `make artifacts` populates artifacts/; offline builds (no
        // python/jax toolchain) legitimately run without it.
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        };
        assert!(dir.join("manifest.json").is_file());
    }
}
