//! The repo's two hash flavours, in one place.
//!
//! * [`fnv1a`] — word-wise FNV-style mixing hash, 8 bytes per round.
//!   Byte-at-a-time FNV costs ~2 ms/MB, which dominates replica-restore
//!   encode at tens of MB of model state; this runs ~8x faster with the
//!   same bit-flip detection guarantees for our purposes. Used for bulk
//!   data: checkpoint files, state-stream chunks, `param_hash`.
//! * [`fnv1a_bytes`] — the byte-at-a-time reference FNV-1a. Feeding it
//!   a buffer in any segmentation yields the same value, so it is the
//!   stable *identity* hash for chaos specs and journal digests.
//!
//! Both previously lived as private copies (`checkpoint::fnv1a`, the
//! inline feed in `WorkerState::param_hash`); this module is the single
//! implementation they now share.

/// FNV-1a 64-bit offset basis — the seed both flavours start from.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Word-wise mixing hash (FNV-style, 8 bytes per round), resumable:
/// `fnv1a(b, fnv1a(a, FNV_OFFSET))` is well-defined, but — unlike the
/// byte-wise reference — depends on the segment boundaries when a
/// segment's length is not a multiple of 8. Producers and consumers
/// must therefore feed identical segmentation (the checkpoint codec and
/// the state-stream protocol both do).
pub fn fnv1a(data: &[u8], mut hash: u64) -> u64 {
    const K: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        hash = (hash ^ u64::from_le_bytes(c.try_into().unwrap())).wrapping_mul(K);
        hash ^= hash >> 29;
    }
    for b in chunks.remainder() {
        hash = (hash ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
    hash
}

/// Byte-at-a-time reference FNV-1a over a whole buffer (segmentation-
/// independent; the identity hash for specs and journals).
pub fn fnv1a_bytes(data: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for b in data {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// [`fnv1a`] over an f32 slice's exact little-endian bits *without*
/// materialising a byte copy: two floats per 8-byte round. Bit-for-bit
/// equal to `fnv1a(&le_bytes_of(data), hash)`, which is what the
/// replica-identity hashes (`Snapshot::content_hash`,
/// `WorkerState::param_hash`) feed per tensor.
pub fn fnv1a_f32(data: &[f32], mut hash: u64) -> u64 {
    const K: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut pairs = data.chunks_exact(2);
    for p in &mut pairs {
        let word = (p[0].to_bits() as u64) | ((p[1].to_bits() as u64) << 32);
        hash = (hash ^ word).wrapping_mul(K);
        hash ^= hash >> 29;
    }
    for x in pairs.remainder() {
        for b in x.to_le_bytes() {
            hash = (hash ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }
    hash
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_wise_is_resumable_at_word_boundaries() {
        let data: Vec<u8> = (0u8..64).collect();
        let whole = fnv1a(&data, FNV_OFFSET);
        let split = fnv1a(&data[16..], fnv1a(&data[..16], FNV_OFFSET));
        assert_eq!(whole, split);
    }

    #[test]
    fn word_wise_detects_single_bit_flips() {
        let mut data = vec![0xA5u8; 1024];
        let h = fnv1a(&data, FNV_OFFSET);
        data[500] ^= 0x10;
        assert_ne!(h, fnv1a(&data, FNV_OFFSET));
    }

    #[test]
    fn byte_wise_is_segmentation_independent() {
        let data: Vec<u8> = (0u8..37).collect();
        let whole = fnv1a_bytes(&data);
        // manual resume via the same recurrence
        let mut h = FNV_OFFSET;
        for b in &data {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        assert_eq!(whole, h);
    }

    #[test]
    fn empty_input_returns_seed() {
        assert_eq!(fnv1a(&[], 42), 42);
        assert_eq!(fnv1a_bytes(&[]), FNV_OFFSET);
        assert_eq!(fnv1a_f32(&[], 42), 42);
    }

    #[test]
    fn f32_flavour_matches_byte_flavour_exactly() {
        // even and odd lengths: the copy-free f32 path must be
        // bit-identical to hashing the tensor's LE byte image
        for n in [0usize, 1, 2, 7, 64, 101] {
            let data: Vec<f32> = (0..n).map(|i| (i as f32) * 1.5 - 3.25).collect();
            let mut bytes = Vec::with_capacity(n * 4);
            for x in &data {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            assert_eq!(
                fnv1a_f32(&data, FNV_OFFSET),
                fnv1a(&bytes, FNV_OFFSET),
                "mismatch at n={n}"
            );
        }
    }

    #[test]
    fn flavours_differ_but_both_spread() {
        let data = b"flashrecovery".to_vec();
        assert_ne!(fnv1a(&data, FNV_OFFSET), fnv1a_bytes(&data));
    }
}
