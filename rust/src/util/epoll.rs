//! Minimal epoll + eventfd binding — vendored under the offline
//! constraint (like `util/rng`): no `libc`, `mio` or `tokio`, just
//! direct `extern "C"` declarations against the system libc that
//! `std` already links. Only what the store reactor needs is bound:
//! level-triggered readiness registration, a bounded wait, and an
//! eventfd the reactor can be woken through from other threads
//! (publish wakeups, replication commit advance, shutdown).
//!
//! Linux-only by construction (`util/mod.rs` gates the module); on
//! other platforms the store falls back to the threaded core.

use std::io;
use std::os::raw::{c_int, c_uint, c_void};
use std::os::unix::io::RawFd;

/// Readable readiness (incoming bytes or a pending accept).
pub const EPOLLIN: u32 = 0x001;
/// Writable readiness (socket send buffer has room again).
pub const EPOLLOUT: u32 = 0x004;
/// Error condition — always reported, never needs registering.
pub const EPOLLERR: u32 = 0x008;
/// Peer hung up — always reported, never needs registering.
pub const EPOLLHUP: u32 = 0x010;
/// Peer closed its write half; must be registered explicitly so a
/// parked connection (no `EPOLLIN` interest) still reports its death.
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000;

/// `struct epoll_event` — packed on x86-64 (the kernel ABI quirk every
/// binding reproduces), naturally aligned elsewhere.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy, Default)]
pub struct EpollEvent {
    events: u32,
    data: u64,
}

impl EpollEvent {
    /// Readiness bits reported by the kernel (copied by value — the
    /// struct may be packed, so fields are never referenced in place).
    pub fn events(&self) -> u32 {
        self.events
    }

    /// The `u64` token registered with the fd.
    pub fn token(&self) -> u64 {
        self.data
    }
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(
        epfd: c_int,
        events: *mut EpollEvent,
        maxevents: c_int,
        timeout: c_int,
    ) -> c_int;
    fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
}

/// An epoll instance. Dropping it closes the epoll fd (registered fds
/// are owned elsewhere and deregister on their own close).
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let arg = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
        if unsafe { epoll_ctl(self.fd, op, fd, arg) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Register `fd` level-triggered with interest `events`, reported
    /// under `token`.
    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Change an existing registration's interest set.
    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    /// Remove a registration (idempotent at the caller's discretion —
    /// closing the fd also removes it, so errors are often ignorable).
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait up to `timeout_ms` for readiness; fills `events` and
    /// returns how many entries are valid. `EINTR` retries internally.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(
                    self.fd,
                    events.as_mut_ptr(),
                    events.len() as c_int,
                    timeout_ms,
                )
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

/// A nonblocking eventfd: the reactor registers it for `EPOLLIN` and
/// any thread can `wake()` the event loop out of `epoll_wait`.
pub struct WakeFd {
    fd: RawFd,
}

impl WakeFd {
    pub fn new() -> io::Result<WakeFd> {
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(WakeFd { fd })
    }

    pub fn raw_fd(&self) -> RawFd {
        self.fd
    }

    /// Make the fd readable (coalesces: n wakes before a drain still
    /// cost one readiness event).
    pub fn wake(&self) {
        let one: u64 = 1;
        // EAGAIN (counter saturated) is fine — the fd is still readable
        let _ = unsafe { write(self.fd, (&one as *const u64).cast::<c_void>(), 8) };
    }

    /// Consume pending wakes so level-triggered polling quiesces.
    pub fn drain(&self) {
        let mut buf: u64 = 0;
        let _ = unsafe { read(self.fd, (&mut buf as *mut u64).cast::<c_void>(), 8) };
    }
}

impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn wakefd_rouses_epoll_wait() {
        let ep = Epoll::new().unwrap();
        let wake = WakeFd::new().unwrap();
        ep.add(wake.raw_fd(), EPOLLIN, 7).unwrap();
        let mut evs = [EpollEvent::default(); 4];
        // nothing pending: times out empty
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
        wake.wake();
        wake.wake(); // coalesces
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].token(), 7);
        assert!(evs[0].events() & EPOLLIN != 0);
        wake.drain();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
    }

    #[test]
    fn socket_readiness_and_interest_changes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(listener.as_raw_fd(), EPOLLIN, 1).unwrap();

        let mut client = TcpStream::connect(addr).unwrap();
        let mut evs = [EpollEvent::default(); 4];
        let n = ep.wait(&mut evs, 2000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].token(), 1);

        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();
        ep.add(server_side.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 2).unwrap();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0, "no bytes yet");

        client.write_all(b"x").unwrap();
        let n = ep.wait(&mut evs, 2000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].token(), 2);
        assert!(evs[0].events() & EPOLLIN != 0);

        // writable interest reports immediately on an idle socket
        ep.modify(server_side.as_raw_fd(), EPOLLOUT, 2).unwrap();
        let n = ep.wait(&mut evs, 2000).unwrap();
        assert_eq!(n, 1);
        assert!(evs[0].events() & EPOLLOUT != 0);

        // peer close reports RDHUP/HUP even with read interest dropped
        ep.modify(server_side.as_raw_fd(), EPOLLRDHUP, 2).unwrap();
        drop(client);
        let n = ep.wait(&mut evs, 2000).unwrap();
        assert_eq!(n, 1);
        assert!(evs[0].events() & (EPOLLRDHUP | EPOLLHUP) != 0);

        ep.delete(server_side.as_raw_fd()).unwrap();
    }
}
