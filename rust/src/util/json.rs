//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Used for the artifact manifest, the shared-file ranktable, config
//! files, checkpoint metadata, and bench output. Supports the full JSON
//! grammar except for exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // -------------------------------------------------------- accessors

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Array(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------ constructors

    pub fn object() -> Json {
        Json::Object(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Object(o) = self {
            o.insert(key.to_string(), value.into());
        }
        self
    }

    // --------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------- rendering

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty-print with 2-space indentation.
    pub fn render_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            for _ in 0..d {
                out.push_str("  ");
            }
        };
        match self {
            Json::Array(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    pad(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Object(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    if i + 1 < o.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Array(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.render()).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("a").idx(0).as_i64(), Some(1));
    }

    #[test]
    fn missing_keys_are_null() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("nope").is_null());
        assert!(v.get("nope").get("deeper").is_null());
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "tru", "\"unterminated", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line\n\"quote\"\ttab\\slash".to_string());
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }

    #[test]
    fn builder_api() {
        let mut o = Json::object();
        o.set("n", 3usize).set("s", "x").set("arr", vec![1i64, 2]);
        let back = Json::parse(&o.render()).unwrap();
        assert_eq!(back.get("n").as_usize(), Some(3));
        assert_eq!(back.get("arr").idx(1).as_i64(), Some(2));
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(Json::parse(&v.render_pretty()).unwrap(), v);
    }

    #[test]
    fn reads_real_manifest_shape() {
        let text = r#"{"format":1,"models":{"tiny":{"config":{"seq":32},
            "params":[{"name":"embed","shape":[256,64],"dtype":"f32"}]}}}"#;
        let v = Json::parse(text).unwrap();
        let p = v.get("models").get("tiny").get("params").idx(0);
        assert_eq!(p.get("name").as_str(), Some("embed"));
        assert_eq!(p.get("shape").idx(1).as_usize(), Some(64));
    }

    #[test]
    fn large_ints_preserved() {
        let v = Json::parse("1234567890123").unwrap();
        assert_eq!(v.as_i64(), Some(1234567890123));
        assert_eq!(v.render(), "1234567890123");
    }
}
