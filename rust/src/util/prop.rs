//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over `cases` generated inputs derived from a
//! seeded RNG; on failure it reports the failing case index and seed so
//! the exact input can be replayed deterministically.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries lack the rpath to the parked
//! // libstdc++ this environment needs; the example is compile-checked)
//! use flashrecovery::util::prop;
//! prop::check("reverse twice is identity", 200, |rng| {
//!     let n = rng.below(50) as usize;
//!     let xs: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     prop::assert_eq_prop(&xs, &ys)
//! });
//! ```

use super::rng::Rng;

pub type PropResult = Result<(), String>;

/// Run `property` on `cases` seeded inputs; panic with a replayable
/// seed on the first failure.
pub fn check<F>(name: &str, cases: u32, mut property: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF1A5_4EC0u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with PROP_SEED={base} case seed {seed}): {msg}"
            );
        }
    }
}

pub fn assert_prop(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn assert_eq_prop<T: PartialEq + std::fmt::Debug>(a: &T, b: &T) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{a:?} != {b:?}"))
    }
}

pub fn assert_close(a: f64, b: f64, tol: f64) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("u64 xor self is zero", 100, |rng| {
            let x = rng.next_u64();
            assert_eq_prop(&(x ^ x), &0)
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_context() {
        check("demo", 10, |_| Err("always fails".to_string()));
    }

    #[test]
    fn assert_close_tolerates_small_error() {
        assert!(assert_close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(assert_close(1.0, 1.1, 1e-6).is_err());
    }
}
